"""Layer-2 train-step model: shapes, determinism, learning."""

import numpy as np
import jax.numpy as jnp

from compile.model import (
    CONFIGS,
    MICRO,
    NANO,
    forward,
    init_params,
    loss_fn,
    num_params,
    param_specs,
    synthetic_batch,
    train_step,
)


def test_param_specs_match_init():
    for cfg in CONFIGS.values():
        params = init_params(cfg, 0)
        specs = param_specs(cfg)
        assert len(params) == len(specs)
        for p, (_, shape) in zip(params, specs):
            assert p.shape == shape
        assert num_params(cfg) == sum(int(np.prod(s)) for _, s in specs)


def test_forward_shapes():
    cfg = NANO
    params = init_params(cfg, 1)
    tokens = synthetic_batch(cfg, 0)[:, :-1]
    logits = forward(cfg, params, tokens)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_initial_loss_near_uniform():
    cfg = NANO
    params = init_params(cfg, 2)
    loss = loss_fn(cfg, params, synthetic_batch(cfg, 0))
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.7


def test_train_step_deterministic():
    cfg = NANO
    p = init_params(cfg, 3)
    batch = synthetic_batch(cfg, 0)
    p1, l1 = train_step(cfg, p, batch)
    p2, l2 = train_step(cfg, p, batch)
    assert float(l1) == float(l2)
    for a, b in zip(p1, p2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_decreases_nano():
    cfg = NANO
    p = init_params(cfg, 0)
    losses = []
    for step in range(80):
        p, loss = train_step(cfg, p, synthetic_batch(cfg, step))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.8, (losses[0], losses[-1])


def test_loss_decreases_micro():
    cfg = MICRO
    p = init_params(cfg, 0)
    losses = []
    for step in range(40):
        p, loss = train_step(cfg, p, synthetic_batch(cfg, step))
        losses.append(float(loss))
    assert losses[-1] < losses[0], (losses[0], losses[-1])


def test_synthetic_batch_is_learnable_pattern():
    cfg = NANO
    b = np.asarray(synthetic_batch(cfg, 0))
    assert b.shape == (cfg.batch, cfg.seq_len + 1)
    assert b.dtype == np.int32
    assert b.min() >= 0 and b.max() < cfg.vocab
    # ~90% of transitions follow the affine chain.
    follows = (b[:, 1:] == (5 * b[:, :-1] + 1) % cfg.vocab).mean()
    assert follows > 0.75, follows

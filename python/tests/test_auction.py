"""Layer-2 auction solver vs scipy's exact Hungarian solver."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st
from scipy.optimize import linear_sum_assignment

from compile.auction import auction_assign


def solve(benefit, eps_final):
    a, prices = auction_assign(jnp.asarray(benefit), jnp.float32(eps_final))
    return np.asarray(a), np.asarray(prices)


def exact_value(benefit):
    r, c = linear_sum_assignment(-benefit)
    return benefit[r, c].sum()


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([2, 4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_exact_on_integer_benefits(n, seed):
    rng = np.random.default_rng(seed)
    b = rng.integers(0, 25, size=(n, n)).astype(np.float32)
    a, _ = solve(b, 1.0 / (n + 1))
    assert sorted(a.tolist()) == list(range(n)), "not a permutation"
    got = b[np.arange(n), a].sum()
    assert abs(got - exact_value(b)) < 1e-3


@settings(max_examples=12, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_exact_on_sixteenth_quantized(n, seed):
    # Migration costs are multiples of 1/16 (Algorithm 3's amortization).
    rng = np.random.default_rng(seed)
    b = (rng.integers(0, 33, size=(n, n)) / 16.0).astype(np.float32)
    a, _ = solve(b, (1.0 / 16.0) / (n + 1))
    got = b[np.arange(n), a].sum()
    assert abs(got - exact_value(b)) < 1e-3


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_near_optimal_on_floats(n, seed):
    rng = np.random.default_rng(seed)
    b = rng.uniform(0, 10, size=(n, n)).astype(np.float32)
    eps = 1e-3
    a, _ = solve(b, eps)
    got = b[np.arange(n), a].sum()
    assert got >= exact_value(b) - (n + 1) * eps - 1e-3


def test_negated_costs_give_min_cost_assignment():
    # The rust side feeds -cost as benefit.
    rng = np.random.default_rng(7)
    cost = rng.integers(0, 20, size=(8, 8)).astype(np.float32)
    a, _ = solve(-cost, 1.0 / 9)
    got = cost[np.arange(8), a].sum()
    r, c = linear_sum_assignment(cost)
    assert abs(got - cost[r, c].sum()) < 1e-3


def test_identity_on_diagonal_dominant():
    b = np.eye(8, dtype=np.float32) * 10.0
    a, _ = solve(b, 0.05)
    assert a.tolist() == list(range(8))


def test_prices_are_nonnegative_and_finite():
    rng = np.random.default_rng(11)
    b = rng.uniform(0, 5, size=(16, 16)).astype(np.float32)
    _, prices = solve(b, 0.01)
    assert np.all(np.isfinite(prices))
    assert np.all(prices >= -1e-6)

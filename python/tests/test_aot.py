"""AOT pipeline: lowered HLO text is well-formed and manifest-complete."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, auction, gp, model


def test_to_hlo_text_produces_entry():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((2, 2), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[2,2]" in text


def test_assignment_lowering_small():
    lowered = jax.jit(auction.auction_assign).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "while" in text  # the auction loop survives lowering


def test_gp_lowering():
    lowered = jax.jit(gp.gp_posterior).lower(
        jax.ShapeDtypeStruct((gp.N_MAX, 7), jnp.float32),
        jax.ShapeDtypeStruct((gp.N_MAX,), jnp.float32),
        jax.ShapeDtypeStruct((gp.N_MAX,), jnp.float32),
        jax.ShapeDtypeStruct((64, 7), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    # No LAPACK custom-calls (xla_extension 0.5.1 cannot run them).
    assert "lapack" not in text.lower()


def test_train_step_lowering_has_no_lapack_or_mosaic():
    cfg = model.NANO
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in model.param_specs(cfg)]
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    lowered = jax.jit(model.train_step, static_argnames=("cfg",)).lower(
        cfg, specs, tokens
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    lower = text.lower()
    assert "lapack" not in lower
    assert "tpu_custom_call" not in lower  # interpret=True keeps it pure HLO


def test_manifest_written(tmp_path):
    # Only the cheap artifacts to keep the test fast.
    manifest = {}
    aot.lower_gp(str(tmp_path), manifest)
    path = os.path.join(str(tmp_path), "manifest.json")
    with open(path, "w") as f:
        json.dump({"artifacts": manifest, "version": 1}, f)
    data = json.load(open(path))
    assert "gp" in data["artifacts"]
    entry = data["artifacts"]["gp"]
    assert os.path.exists(os.path.join(str(tmp_path), entry["file"]))
    assert entry["inputs"][0]["shape"] == [gp.N_MAX, 7]

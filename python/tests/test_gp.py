"""Layer-2 masked GP posterior vs a dense numpy reference."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.gp import gp_posterior, LENGTHSCALE, N_MAX, NOISE_VAR, SIGNAL_VAR


def rbf_np(a, b):
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    return SIGNAL_VAR * np.exp(-0.5 * d2 / LENGTHSCALE**2)


def reference(x, y, xq):
    n = len(x)
    k = rbf_np(x, x) + NOISE_VAR * np.eye(n)
    ym = y.mean()
    alpha = np.linalg.solve(k, y - ym)
    kq = rbf_np(x, xq)
    mean = ym + kq.T @ alpha
    l = np.linalg.cholesky(k)
    v = np.linalg.solve(l, kq)
    var = np.maximum(SIGNAL_VAR - (v * v).sum(0), 1e-12)
    return mean, var


def run(x, y, xq):
    n, d = x.shape
    xp = np.zeros((N_MAX, d), np.float32)
    xp[:n] = x
    yp = np.zeros((N_MAX,), np.float32)
    yp[:n] = y
    mask = np.zeros((N_MAX,), np.float32)
    mask[:n] = 1.0
    mean, var = gp_posterior(
        jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mask), jnp.asarray(xq)
    )
    return np.asarray(mean), np.asarray(var)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, N_MAX),
    d=st.integers(1, 7),
    m=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_numpy_reference(n, d, m, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.normal(size=(n,)).astype(np.float32)
    xq = rng.normal(size=(m, d)).astype(np.float32)
    mean, var = run(x, y, xq)
    mref, vref = reference(x, y, xq)
    # The artifact is fp32 while the reference solves in fp64; with many
    # near-duplicate 1-D points the kernel matrix is ill-conditioned, so
    # allow a few percent (the BO loop only needs rank ordering).
    np.testing.assert_allclose(mean, mref, rtol=3e-2, atol=5e-3)
    np.testing.assert_allclose(var, vref, rtol=5e-2, atol=5e-3)


def test_interpolates_observations():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(6, 3)).astype(np.float32)
    y = rng.normal(size=(6,)).astype(np.float32)
    mean, var = run(x, y, x)
    np.testing.assert_allclose(mean, y, atol=0.05)
    assert np.all(var < 0.05)


def test_reverts_to_prior_far_from_data():
    x = np.zeros((2, 2), np.float32)
    y = np.array([1.0, 3.0], np.float32)
    xq = np.full((1, 2), 100.0, np.float32)
    mean, var = run(x, y, xq)
    np.testing.assert_allclose(mean, [2.0], atol=1e-3)  # data mean
    np.testing.assert_allclose(var, [SIGNAL_VAR], rtol=1e-3)


def test_padding_is_inert():
    # Same data, different amounts of padding: identical posterior.
    rng = np.random.default_rng(3)
    x = rng.normal(size=(5, 4)).astype(np.float32)
    y = rng.normal(size=(5,)).astype(np.float32)
    xq = rng.normal(size=(8, 4)).astype(np.float32)
    m1, v1 = run(x, y, xq)
    # Poison the padded region: must not change the answer.
    xp = np.full((N_MAX, 4), 777.0, np.float32)
    xp[:5] = x
    yp = np.full((N_MAX,), -55.0, np.float32)
    yp[:5] = y
    mask = np.zeros((N_MAX,), np.float32)
    mask[:5] = 1.0
    m2, v2 = (
        np.asarray(t)
        for t in gp_posterior(
            jnp.asarray(xp), jnp.asarray(yp), jnp.asarray(mask), jnp.asarray(xq)
        )
    )
    np.testing.assert_allclose(m1, m2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(v1, v2, rtol=1e-4, atol=1e-5)

"""Layer-1 `top2` kernel vs the pure-jnp oracle — hypothesis shape sweep."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import top2_ref
from compile.kernels.top2 import top2


def check(values):
    b, i, s = top2(jnp.asarray(values))
    br, ir, sr = top2_ref(jnp.asarray(values))
    np.testing.assert_allclose(np.asarray(b), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr))


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 48),
    cols=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference_random(rows, cols, seed):
    rng = np.random.default_rng(seed)
    check(rng.normal(size=(rows, cols)).astype(np.float32))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_ties_are_consistent(seed):
    # Duplicated maxima: kernel and reference must pick the same argmax
    # (both use jnp.argmax's first-occurrence rule).
    rng = np.random.default_rng(seed)
    v = rng.integers(0, 3, size=(16, 16)).astype(np.float32)
    check(v)


def test_known_values():
    v = np.array([[1.0, 5.0, 3.0], [7.0, 2.0, 7.0]], np.float32)
    b, i, s = top2(jnp.asarray(v))
    assert b.tolist() == [5.0, 7.0]
    assert i.tolist() == [1, 0]  # first occurrence on the tie
    assert s.tolist() == [3.0, 7.0]


def test_single_column():
    v = np.array([[2.0], [3.0]], np.float32)
    b, i, s = top2(jnp.asarray(v))
    assert b.tolist() == [2.0, 3.0]
    assert s.tolist() == [2.0, 3.0]
    assert i.tolist() == [0, 0]


def test_negative_and_inf_values():
    v = np.array([[-1.0, -5.0], [np.float32(-np.inf), 0.0]], np.float32)
    check(v)


@pytest.mark.parametrize("block", [1, 2, 4, 8, 16])
def test_block_sizes_agree(block):
    rng = np.random.default_rng(0)
    v = rng.normal(size=(16, 12)).astype(np.float32)
    b, i, s = top2(jnp.asarray(v), block_rows=block)
    br, ir, sr = top2_ref(jnp.asarray(v))
    np.testing.assert_allclose(np.asarray(b), np.asarray(br))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr))


def test_uneven_rows_fall_back_to_smaller_block():
    rng = np.random.default_rng(1)
    v = rng.normal(size=(13, 9)).astype(np.float32)  # 13 is prime
    check(v)

"""Layer-1 fused attention kernel vs the pure-jnp oracle, incl. gradients."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.integers(1, 24),
    d=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_reference(b, h, t, d, seed):
    q = rand((b, h, t, d), seed)
    k = rand((b, h, t, d), seed + 1)
    v = rand((b, h, t, d), seed + 2)
    np.testing.assert_allclose(
        np.asarray(attention(q, k, v)),
        np.asarray(attention_ref(q, k, v)),
        rtol=1e-4,
        atol=1e-5,
    )


def test_causality():
    # Changing a *future* key/value must not change earlier outputs.
    q = rand((1, 1, 8, 4), 0)
    k = rand((1, 1, 8, 4), 1)
    v = rand((1, 1, 8, 4), 2)
    base = np.asarray(attention(q, k, v))
    k2 = k.at[0, 0, 7].set(99.0)
    v2 = v.at[0, 0, 7].set(-99.0)
    out = np.asarray(attention(q, k2, v2))
    np.testing.assert_allclose(out[0, 0, :7], base[0, 0, :7], rtol=1e-5)
    assert not np.allclose(out[0, 0, 7], base[0, 0, 7])


def test_first_position_attends_only_to_itself():
    q = rand((1, 1, 4, 4), 3)
    k = rand((1, 1, 4, 4), 4)
    v = rand((1, 1, 4, 4), 5)
    out = np.asarray(attention(q, k, v))
    np.testing.assert_allclose(out[0, 0, 0], np.asarray(v)[0, 0, 0], rtol=1e-5)


def test_gradients_match_reference():
    q = rand((2, 2, 8, 4), 6)
    k = rand((2, 2, 8, 4), 7)
    v = rand((2, 2, 8, 4), 8)
    f = lambda fn: lambda q, k, v: jnp.sum(jnp.tanh(fn(q, k, v)))
    g_kernel = jax.grad(f(attention), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f(attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_kernel, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_softmax_rows_mix_values_convexly():
    # With q = 0 the output is a uniform average of the visible values.
    t, d = 6, 3
    q = jnp.zeros((1, 1, t, d), jnp.float32)
    k = rand((1, 1, t, d), 9)
    v = rand((1, 1, t, d), 10)
    out = np.asarray(attention(q, k, v))[0, 0]
    vn = np.asarray(v)[0, 0]
    for i in range(t):
        np.testing.assert_allclose(out[i], vn[: i + 1].mean(axis=0), rtol=1e-4, atol=1e-5)

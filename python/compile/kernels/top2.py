"""Layer-1 Pallas kernel: per-row top-2 reduction.

This is the inner loop of the auction algorithm's bidding phase (the
data-parallel dual of the Hungarian method Tesserae uses for placement):
for every unassigned person (row) we need the best and second-best value
``v_ij = benefit_ij - price_j`` plus the argmax column. One kernel
invocation computes all three for a block of rows.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the value matrix is
tiled into row blocks resident in VMEM; the row-wise max/argmax reductions
vectorize on the VPU lanes; prices are broadcast once per block. On CPU we
run the kernel with ``interpret=True`` so it lowers to plain HLO that the
PJRT CPU client (and the rust `xla` crate) can execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows processed per grid step. 8 sublanes is the natural TPU tile height;
# any divisor of n works in interpret mode.
DEFAULT_BLOCK_ROWS = 8


def _top2_kernel(v_ref, best_ref, idx_ref, second_ref):
    """Kernel body: v_ref is a (block_rows, n) tile of the value matrix."""
    v = v_ref[...]
    n = v.shape[-1]
    idx = jnp.argmax(v, axis=-1)
    best = jnp.max(v, axis=-1)
    # Mask out the argmax column and reduce again for the runner-up.
    cols = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
    masked = jnp.where(cols == idx[:, None], -jnp.inf, v)
    second = jnp.max(masked, axis=-1)
    # Degenerate n == 1: there is no second column; mirror best.
    if n == 1:
        second = best
    best_ref[...] = best
    idx_ref[...] = idx.astype(jnp.int32)
    second_ref[...] = second


@functools.partial(jax.jit, static_argnames=("block_rows",))
def top2(values, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Per-row (best, argmax, second-best) of a 2-D float array.

    Returns ``(best, idx, second)`` with shapes ``(rows,)``.
    """
    rows, n = values.shape
    block = min(block_rows, rows)
    while rows % block != 0:  # interpret mode still wants an even grid
        block -= 1
    grid = (rows // block,)
    return pl.pallas_call(
        _top2_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block, n), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows,), values.dtype),
            jax.ShapeDtypeStruct((rows,), jnp.int32),
            jax.ShapeDtypeStruct((rows,), values.dtype),
        ],
        interpret=True,
    )(values)

"""Layer-1 Pallas kernel: fused causal self-attention.

Used by the Layer-2 train-step model (`compile/model.py`) so the real
compute executed by the rust coordinator's workers flows through a Pallas
kernel. One grid step handles one (batch, head) pair: the full (T, T)
score matrix lives in the kernel's scratch (VMEM on TPU), the causal mask
and softmax fuse with both matmuls (MXU work on TPU), and only the (T, D)
output tile is written back.

Runs with ``interpret=True`` so the lowered HLO executes on the CPU PJRT
client (real-TPU Mosaic lowering is compile-only in this environment).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref):
    # Block shape is (1, 1, T, D): one (batch, head) pair per grid step.
    q = q_ref[0, 0]  # (T, D)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    t, d = q.shape
    scores = jnp.dot(q, k.T) / jnp.sqrt(jnp.asarray(d, q.dtype))
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where(cols <= rows, scores, -jnp.inf)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    probs = jnp.exp(scores)
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    o_ref[0, 0] = jnp.dot(probs, v)


def _attention_fwd_pallas(q, k, v):
    b, h, t, d = q.shape
    grid = (b, h)
    spec = pl.BlockSpec((1, 1, t, d), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _attention_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, d), q.dtype),
        interpret=True,
    )(q, k, v)


def _probs(q, k):
    """Recompute the masked softmax probabilities (backward pass helper)."""
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    t = q.shape[-2]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    scores = jnp.where((cols <= rows)[None, None], scores, -jnp.inf)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores)
    return p / jnp.sum(p, axis=-1, keepdims=True)


@jax.custom_vjp
def attention(q, k, v):
    """Fused causal attention over (batch, heads, seq, head_dim) inputs.

    Forward runs the Pallas kernel; backward is the analytic softmax-
    attention VJP (flash-attention style recomputation: probabilities are
    rebuilt from q, k rather than saved).
    """
    return _attention_fwd_pallas(q, k, v)


def _attention_vjp_fwd(q, k, v):
    return _attention_fwd_pallas(q, k, v), (q, k, v)


def _attention_vjp_bwd(res, do):
    q, k, v = res
    d = q.shape[-1]
    p = _probs(q, k)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, do)
    dp = jnp.einsum("bhqd,bhkd->bhqk", do, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, k) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, q) * scale
    return dq, dk, dv


attention.defvjp(_attention_vjp_fwd, _attention_vjp_bwd)

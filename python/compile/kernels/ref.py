"""Pure-jnp correctness oracles for the Layer-1 Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between kernel and reference across shape/dtype sweeps.
"""

import jax.numpy as jnp


def top2_ref(values):
    """Reference per-row (best, argmax, second-best)."""
    idx = jnp.argmax(values, axis=-1)
    best = jnp.max(values, axis=-1)
    n = values.shape[-1]
    if n == 1:
        return best, idx.astype(jnp.int32), best
    cols = jnp.arange(n)[None, :]
    masked = jnp.where(cols == idx[:, None], -jnp.inf, values)
    second = jnp.max(masked, axis=-1)
    return best, idx.astype(jnp.int32), second


def attention_ref(q, k, v):
    """Reference causal attention: softmax(QKᵀ/√d + mask)V.

    Shapes: q, k, v are (batch, heads, seq, head_dim).
    """
    d = q.shape[-1]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    t = q.shape[-2]
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / jnp.sum(probs, axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)

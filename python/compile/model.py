"""Layer-2: a small GPT-style causal language model — fwd/bwd/SGD step.

The real-execution coordinator (`rust/src/coordinator/`) schedules *actual*
training jobs: each simulated GPU worker executes this train step through
PJRT on its share of a synthetic corpus, so scheduling, packing and
migration decisions act on genuine compute. Attention flows through the
Layer-1 Pallas kernel (`kernels/attention.py`).

Parameters are a flat, ordered list of arrays (a stable ABI for the HLO
interface); `param_specs` documents name/shape/dtype per entry and is
exported into the artifact manifest so the rust side can allocate, carry
and checkpoint parameter state without ever importing Python.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels.attention import attention


@dataclasses.dataclass(frozen=True)
class GptConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int
    batch: int
    lr: float = 0.5

    @property
    def head_dim(self):
        return self.d_model // self.n_heads


# The two job sizes the coordinator schedules ("models" of its cluster).
NANO = GptConfig(name="gpt-nano", vocab=256, d_model=64, n_heads=2, n_layers=2,
                 seq_len=32, batch=8)
MICRO = GptConfig(name="gpt-micro", vocab=512, d_model=128, n_heads=4, n_layers=4,
                  seq_len=32, batch=8)
CONFIGS = {c.name: c for c in (NANO, MICRO)}


def param_specs(cfg: GptConfig):
    """Ordered (name, shape) for the flat parameter list."""
    specs = [("tok_embed", (cfg.vocab, cfg.d_model)),
             ("pos_embed", (cfg.seq_len, cfg.d_model))]
    for i in range(cfg.n_layers):
        specs += [
            (f"l{i}.ln1_scale", (cfg.d_model,)),
            (f"l{i}.qkv", (cfg.d_model, 3 * cfg.d_model)),
            (f"l{i}.proj", (cfg.d_model, cfg.d_model)),
            (f"l{i}.ln2_scale", (cfg.d_model,)),
            (f"l{i}.mlp_up", (cfg.d_model, 4 * cfg.d_model)),
            (f"l{i}.mlp_down", (4 * cfg.d_model, cfg.d_model)),
        ]
    specs.append(("ln_f_scale", (cfg.d_model,)))
    return specs


def num_params(cfg: GptConfig) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_specs(cfg))


def init_params(cfg: GptConfig, seed):
    """Initialize the flat parameter list from a scalar seed (traceable)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_scale"):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in * 1.0)
            )
    return params


def _rmsnorm(x, scale):
    return x * scale / jnp.sqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def forward(cfg: GptConfig, params, tokens):
    """Logits over the next token; `tokens` is (batch, seq_len) int32."""
    it = iter(params)
    tok_embed = next(it)
    pos_embed = next(it)
    b, t = tokens.shape
    x = tok_embed[tokens] + pos_embed[None, :t, :]
    for _ in range(cfg.n_layers):
        ln1, qkv_w, proj_w, ln2, up_w, down_w = (next(it) for _ in range(6))
        h = _rmsnorm(x, ln1)
        qkv = h @ qkv_w  # (b, t, 3d)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(z):
            return z.reshape(b, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

        o = attention(heads(q), heads(k), heads(v))  # L1 Pallas kernel
        o = o.transpose(0, 2, 1, 3).reshape(b, t, cfg.d_model)
        x = x + o @ proj_w
        h = _rmsnorm(x, ln2)
        x = x + jax.nn.gelu(h @ up_w) @ down_w
    ln_f = next(it)
    x = _rmsnorm(x, ln_f)
    return x @ tok_embed.T  # tied head


def loss_fn(cfg: GptConfig, params, tokens):
    """Next-token cross-entropy over (batch, seq_len+1) token sequences."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(cfg: GptConfig, params, tokens):
    """One SGD step; returns (new_params..., loss)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
    new_params = [p - cfg.lr * g for p, g in zip(params, grads)]
    return new_params, loss


def synthetic_batch(cfg: GptConfig, seed):
    """A learnable synthetic batch: affine next-token chain with noise.

    x_{t+1} = (5·x_t + 1) mod V with 10% uniform corruption — a pattern a
    tiny model learns in a few hundred steps, so the coordinator's loss
    curves visibly descend.
    """
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    first = jax.random.randint(k1, (cfg.batch, 1), 0, cfg.vocab)
    seq = [first]
    for _ in range(cfg.seq_len):
        seq.append((5 * seq[-1] + 1) % cfg.vocab)
    tokens = jnp.concatenate(seq, axis=1)
    noise = jax.random.bernoulli(k2, 0.1, tokens.shape)
    rand = jax.random.randint(k3, tokens.shape, 0, cfg.vocab)
    return jnp.where(noise, rand, tokens).astype(jnp.int32)

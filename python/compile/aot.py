"""AOT driver: lower every Layer-2 graph to HLO *text* + a JSON manifest.

Run once at build time (`make artifacts`); the rust binary is self-contained
afterwards. HLO text — not a serialized HloModuleProto — is the interchange
format: jax ≥ 0.5 emits protos with 64-bit instruction ids that the xla
crate's xla_extension 0.5.1 rejects, while the text parser reassigns ids
(see /opt/xla-example/README.md).

Artifacts:
  assignment_{n}.hlo.txt   n ∈ {8..256}: the auction assignment solver
                           (L1 Pallas top2 inside an HLO while loop)
  gp.hlo.txt               masked GP posterior for the BO estimator
  init_{model}.hlo.txt     parameter initialization for the train models
  train_step_{model}.hlo.txt  fwd/bwd/SGD step (L1 Pallas attention)
  manifest.json            shapes/dtypes/metadata for the rust runtime
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import auction, gp, model

ASSIGNMENT_SIZES = [8, 16, 32, 64, 128, 256]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def io_entry(shape, dtype):
    return {"shape": list(shape), "dtype": dtype}


def lower_assignment(out_dir, manifest):
    for n in ASSIGNMENT_SIZES:
        lowered = jax.jit(auction.auction_assign).lower(
            spec((n, n), jnp.float32), spec((), jnp.float32)
        )
        path = f"assignment_{n}.hlo.txt"
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(to_hlo_text(lowered))
        manifest[f"assignment_{n}"] = {
            "file": path,
            "n": n,
            "inputs": [io_entry((n, n), "f32"), io_entry((), "f32")],
            "outputs": [io_entry((n,), "i32"), io_entry((n,), "f32")],
        }
        print(f"lowered assignment_{n}")


def lower_gp(out_dir, manifest):
    n, d, m = gp.N_MAX, 7, 64
    lowered = jax.jit(gp.gp_posterior).lower(
        spec((n, d), jnp.float32),
        spec((n,), jnp.float32),
        spec((n,), jnp.float32),
        spec((m, d), jnp.float32),
    )
    path = "gp.hlo.txt"
    with open(os.path.join(out_dir, path), "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["gp"] = {
        "file": path,
        "n_max": n,
        "dim": d,
        "num_queries": m,
        "lengthscale": gp.LENGTHSCALE,
        "signal_var": gp.SIGNAL_VAR,
        "noise_var": gp.NOISE_VAR,
        "inputs": [
            io_entry((n, d), "f32"),
            io_entry((n,), "f32"),
            io_entry((n,), "f32"),
            io_entry((m, d), "f32"),
        ],
        "outputs": [io_entry((m,), "f32"), io_entry((m,), "f32")],
    }
    print("lowered gp")


def lower_models(out_dir, manifest):
    for cfg in model.CONFIGS.values():
        specs = model.param_specs(cfg)
        param_shapes = [spec(s, jnp.float32) for _, s in specs]
        tokens = spec((cfg.batch, cfg.seq_len + 1), jnp.int32)

        init_lowered = jax.jit(
            model.init_params, static_argnames=("cfg",)
        ).lower(cfg, spec((), jnp.int32))
        init_path = f"init_{cfg.name}.hlo.txt"
        with open(os.path.join(out_dir, init_path), "w") as f:
            f.write(to_hlo_text(init_lowered))

        step_lowered = jax.jit(
            model.train_step, static_argnames=("cfg",)
        ).lower(cfg, param_shapes, tokens)
        step_path = f"train_step_{cfg.name}.hlo.txt"
        with open(os.path.join(out_dir, step_path), "w") as f:
            f.write(to_hlo_text(step_lowered))

        manifest[f"model_{cfg.name}"] = {
            "init_file": init_path,
            "train_step_file": step_path,
            "config": {
                "name": cfg.name,
                "vocab": cfg.vocab,
                "d_model": cfg.d_model,
                "n_heads": cfg.n_heads,
                "n_layers": cfg.n_layers,
                "seq_len": cfg.seq_len,
                "batch": cfg.batch,
                "lr": cfg.lr,
            },
            "num_params": model.num_params(cfg),
            "param_specs": [
                {"name": name, "shape": list(shape)} for name, shape in specs
            ],
            "tokens": io_entry((cfg.batch, cfg.seq_len + 1), "i32"),
        }
        print(f"lowered init/train_step for {cfg.name}")


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    lower_assignment(args.out, manifest)
    lower_gp(args.out, manifest)
    lower_models(args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"artifacts": manifest, "version": 1}, f, indent=2)
    print(f"wrote manifest with {len(manifest)} artifacts to {args.out}")


if __name__ == "__main__":
    main()

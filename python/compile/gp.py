"""Layer-2: Gaussian-process posterior for the BO throughput estimator.

Computes the RBF-kernel GP posterior mean/variance over a batch of query
points, with *masked padded* observations so a single AOT-compiled module
(fixed N_MAX observations) serves every BO iteration. Rust drives the BO
loop (expected-improvement argmax and the decision which point to profile
next); this module is the numeric core it calls through PJRT.

No LAPACK: `jnp.linalg.cholesky` lowers to a `lapack_potrf` custom-call the
xla_extension 0.5.1 CPU client cannot execute, so the Cholesky and the
triangular solves are written as `lax.fori_loop`s over pure jnp ops
(right-looking outer-product Cholesky; row-sweep substitution).

Hyperparameters are static and must match `estimator/gp.rs`:
lengthscale 0.6, signal variance 0.25, noise variance 1e-4.
"""

import jax
import jax.numpy as jnp

N_MAX = 64  # padded observation count
LENGTHSCALE = 0.6
SIGNAL_VAR = 0.25
NOISE_VAR = 1e-4


def _rbf(a, b):
    """RBF kernel matrix between row sets `a` (n,d) and `b` (m,d)."""
    d2 = jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
    return SIGNAL_VAR * jnp.exp(-0.5 * d2 / (LENGTHSCALE * LENGTHSCALE))


def _cholesky(a):
    """Right-looking Cholesky via fori_loop (SPD input, pure HLO ops)."""
    n = a.shape[0]
    idx = jnp.arange(n)

    def body(k, carry):
        a, l = carry
        pivot = jnp.sqrt(jnp.maximum(a[k, k], 1e-12))
        col = jnp.where(idx >= k, a[:, k] / pivot, 0.0)
        l = l.at[:, k].set(col)
        a = a - jnp.outer(col, col)
        return (a, l)

    _, l = jax.lax.fori_loop(0, n, body, (a, jnp.zeros_like(a)))
    return l


def _solve_lower(l, b):
    """Solve L Y = B for lower-triangular L; B is (n, m)."""
    n = l.shape[0]

    def body(i, y):
        yi = (b[i] - l[i] @ y) / l[i, i]
        return y.at[i].set(yi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


def _solve_lower_t(l, b):
    """Solve Lᵀ Y = B (back substitution)."""
    n = l.shape[0]

    def body(step, y):
        i = n - 1 - step
        yi = (b[i] - l[:, i] @ y) / l[i, i]
        return y.at[i].set(yi)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(b))


@jax.jit
def gp_posterior(x, y, mask, xq):
    """Masked GP posterior.

    Args:
      x:    (N_MAX, D) observation features (rows beyond the real count are
            arbitrary — they are masked out).
      y:    (N_MAX,) observation values.
      mask: (N_MAX,) 1.0 for real observations, 0.0 for padding.
      xq:   (M, D) query points.

    Returns:
      (mean (M,), var (M,)).
    """
    m = mask > 0.5
    count = jnp.maximum(jnp.sum(mask), 1.0)
    y_mean = jnp.sum(jnp.where(m, y, 0.0)) / count
    yc = jnp.where(m, y - y_mean, 0.0)

    k = _rbf(x, x)
    # Mask padded rows/cols: identity outside the real block keeps the
    # matrix SPD and makes padded entries inert.
    mm = m[:, None] & m[None, :]
    eye = jnp.eye(x.shape[0], dtype=x.dtype)
    k = jnp.where(mm, k, 0.0) + (NOISE_VAR * eye) + jnp.where(m, 0.0, 1.0)[:, None] * eye

    l = _cholesky(k)
    alpha = _solve_lower_t(l, _solve_lower(l, yc[:, None]))[:, 0]

    kq = _rbf(x, xq)  # (N_MAX, M)
    kq = jnp.where(m[:, None], kq, 0.0)
    mean = y_mean + kq.T @ alpha
    v = _solve_lower(l, kq)  # (N_MAX, M)
    var = jnp.maximum(SIGNAL_VAR - jnp.sum(v * v, axis=0), 1e-12)
    return mean, var

"""Layer-2: the ε-scaling auction assignment solver.

This is the JAX compute graph the rust coordinator loads via PJRT to solve
Tesserae's placement matching problems (migration node/GPU matching,
packing matching) on the hot path. The bidding phase's per-row top-2
reduction is the Layer-1 Pallas kernel (`kernels/top2.py`); the rest is
dense jnp so the whole solver lowers to a single HLO module with a
`while`-loop — no host round-trips per iteration.

Algorithm (Bertsekas' forward auction, Jacobi bidding):
  repeat until every person is assigned:
    values  = benefit - prices                 (dense)
    best/second/argmax per unassigned person   (Pallas top2 kernel)
    bid     = best - second + ε per bidder
    per object: take the highest bid, bump the price, evict the owner
  ε-scaling: run phases with ε shrinking ×1/4 down to ``eps_final``; with
  ε < resolution/(n+1) the final assignment is exactly optimal on
  resolution-quantized benefits (Bertsekas 1988).

Exported AOT at fixed sizes n ∈ {8,…,256}; the rust side pads smaller
problems into the next bucket with constant-benefit dummy rows/columns.
"""

import jax
import jax.numpy as jnp

from .kernels.top2 import top2

# Static number of ε-scaling phases (benefits are range-normalized below,
# so range/4 ÷ 4^6 ≈ 6e-5 < any practical eps_final).
NUM_PHASES = 7
# Iteration guard per phase — bounds the while loop on degenerate inputs.
MAX_ROUNDS_FACTOR = 400


def _phase(benefit, prices, eps, max_rounds):
    """One ε-phase: auction until every person holds an object."""
    n = benefit.shape[0]
    obj_ids = jnp.arange(n, dtype=jnp.int32)

    def cond(state):
        _, _, assignment, rounds = state
        return jnp.logical_and(jnp.any(assignment < 0), rounds < max_rounds)

    def body(state):
        prices, owner, assignment, rounds = state
        values = benefit - prices[None, :]
        bidder = assignment < 0  # only unassigned persons bid
        best, idx, second = top2(values)
        bid = best - second + eps

        # Scatter the bids onto objects: masked_bids[i, j] = bid_i if person
        # i is bidding on object j, else -inf. Each person bids on exactly
        # one object, so per-object winners are unique.
        onehot = jax.nn.one_hot(idx, n, dtype=bool)
        valid = bidder[:, None] & onehot
        masked_bids = jnp.where(valid, bid[:, None], -jnp.inf)
        top_bid = jnp.max(masked_bids, axis=0)  # per object
        winner = jnp.argmax(masked_bids, axis=0).astype(jnp.int32)
        has_bid = jnp.isfinite(top_bid)

        new_prices = jnp.where(has_bid, prices + top_bid, prices)

        # Evict previous owners of re-auctioned objects (out-of-bounds
        # indices are dropped, so objects without bids scatter nothing).
        evicted = jnp.where(has_bid, owner, n)  # person index or OOB
        evicted = jnp.where(evicted >= 0, evicted, n)
        evict_mask = (
            jnp.zeros((n,), bool).at[evicted].set(True, mode="drop")
        )
        assignment = jnp.where(evict_mask, -1, assignment)

        # Award objects to winners (winners were unassigned, so the evict
        # pass cannot have touched them).
        win_idx = jnp.where(has_bid, winner, n)
        assignment = assignment.at[win_idx].set(obj_ids, mode="drop")
        new_owner = jnp.where(has_bid, winner, owner)
        return (new_prices, new_owner, assignment, rounds + 1)

    owner = jnp.full((n,), -1, jnp.int32)
    assignment = jnp.full((n,), -1, jnp.int32)
    state = (prices, owner, assignment, jnp.int32(0))
    prices, _owner, assignment, _ = jax.lax.while_loop(cond, body, state)
    return prices, assignment


@jax.jit
def auction_assign(benefit, eps_final):
    """Solve max-benefit assignment; returns (assignment i32 (n,), prices).

    ``assignment[i] = j`` assigns person/row i to object/column j.
    """
    n = benefit.shape[0]
    rng = jnp.maximum(jnp.max(benefit) - jnp.min(benefit), 1e-6)
    max_rounds = jnp.int32(MAX_ROUNDS_FACTOR * n)
    prices = jnp.zeros((n,), benefit.dtype)
    assignment = jnp.full((n,), -1, jnp.int32)
    eps = jnp.maximum(rng * 0.25, eps_final)
    for _ in range(NUM_PHASES):
        prices, assignment = _phase(benefit, prices, eps, max_rounds)
        eps = jnp.maximum(eps * 0.25, eps_final)
    return assignment, prices

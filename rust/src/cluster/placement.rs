//! Placement plans: which jobs run on which GPUs in a scheduling round.
//!
//! A plan maps every GPU slot to the (≤ 2, per the CUDA-MPS packing cap of
//! §5) jobs sharing it. Plans are the inputs/outputs of the placement
//! policies: the no-packing allocator fills one, the packing policy adds
//! second tenants, and the migration policy relabels one plan's GPUs to
//! align with the previous round's plan.
//!
//! The plan is *dual-indexed*: alongside the per-GPU `slots` it maintains a
//! job → sorted-GPU-set index incrementally through every mutation, so the
//! hot-path queries (`gpus_of`, `jobs`, `job_gpu_map`, `migrations_from`)
//! are O(the job's GPUs) or O(active jobs) instead of O(total GPUs). The
//! simulator, the placement policies and the coordinator all lean on this;
//! [`PlacementPlan::validate`] cross-checks that both views agree.

use std::collections::{BTreeMap, BTreeSet};

use super::ClusterSpec;
use crate::jobs::JobId;

/// Maximum jobs sharing one GPU (the paper packs at most two, §5).
pub const MAX_JOBS_PER_GPU: usize = 2;

/// A round's placement: `slots[g]` = jobs on global GPU `g`, plus the
/// incrementally maintained reverse index job → sorted GPUs.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    slots: Vec<Vec<JobId>>,
    index: BTreeMap<JobId, Vec<usize>>,
}

impl PartialEq for PlacementPlan {
    /// Two plans are equal when their slot views agree (the index is a
    /// function of the slots' job sets, so comparing slots is sufficient
    /// and keeps equality identical to the pre-index behaviour).
    fn eq(&self, other: &PlacementPlan) -> bool {
        self.slots == other.slots
    }
}

impl PlacementPlan {
    pub fn new(total_gpus: usize) -> PlacementPlan {
        PlacementPlan {
            slots: vec![Vec::new(); total_gpus],
            index: BTreeMap::new(),
        }
    }

    pub fn num_gpus(&self) -> usize {
        self.slots.len()
    }

    pub fn jobs_on(&self, gpu: usize) -> &[JobId] {
        &self.slots[gpu]
    }

    /// Add `job` to each GPU in `gpus`. Panics if any slot is full or the
    /// job is already there — placement policies must not double-place.
    pub fn place(&mut self, job: JobId, gpus: &[usize]) {
        for &g in gpus {
            assert!(
                self.slots[g].len() < MAX_JOBS_PER_GPU,
                "gpu {g} already has {} tenants",
                self.slots[g].len()
            );
            assert!(!self.slots[g].contains(&job), "job {job} already on gpu {g}");
            self.slots[g].push(job);
            let held = self.index.entry(job).or_default();
            let pos = held
                .binary_search(&g)
                .expect_err("index/slot divergence: gpu already in job's set");
            held.insert(pos, g);
        }
    }

    /// Remove a job from every GPU it occupies. Returns the GPUs it held
    /// (sorted). O(the job's GPUs) via the index.
    pub fn remove(&mut self, job: JobId) -> Vec<usize> {
        let freed = self.index.remove(&job).unwrap_or_default();
        for &g in &freed {
            let slot = &mut self.slots[g];
            let pos = slot
                .iter()
                .position(|&j| j == job)
                .expect("index/slot divergence: job missing from slot");
            slot.remove(pos);
        }
        freed
    }

    /// The set of GPUs a job occupies (sorted). O(1) lookup into the index.
    pub fn gpus_of(&self, job: JobId) -> &[usize] {
        self.index.get(&job).map(Vec::as_slice).unwrap_or(&[])
    }

    /// All jobs present in the plan. O(active jobs).
    pub fn jobs(&self) -> BTreeSet<JobId> {
        self.index.keys().copied().collect()
    }

    /// Map job -> sorted GPU set, for the whole plan. This *is* the live
    /// index — O(1), no rebuild.
    pub fn job_gpu_map(&self) -> &BTreeMap<JobId, Vec<usize>> {
        &self.index
    }

    /// GPUs with fewer than `MAX_JOBS_PER_GPU` tenants.
    pub fn free_capacity(&self, gpu: usize) -> usize {
        MAX_JOBS_PER_GPU - self.slots[gpu].len()
    }

    /// GPUs that are completely empty.
    pub fn empty_gpus(&self) -> Vec<usize> {
        self.slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_empty())
            .map(|(g, _)| g)
            .collect()
    }

    /// Remove a set of jobs wholesale (e.g. jobs that finished or were
    /// preempted), returning how many slots were freed. O(Σ removed jobs'
    /// GPUs) via the index.
    pub fn remove_jobs(&mut self, jobs: &BTreeSet<JobId>) -> usize {
        let mut freed = 0;
        for &job in jobs {
            if let Some(gpus) = self.index.remove(&job) {
                for &g in &gpus {
                    let slot = &mut self.slots[g];
                    let pos = slot
                        .iter()
                        .position(|&j| j == job)
                        .expect("index/slot divergence: job missing from slot");
                    slot.remove(pos);
                    freed += 1;
                }
            }
        }
        freed
    }

    /// Relabel GPUs: `perm[new_gpu] = old_gpu` — the output of the migration
    /// policy. Produces the plan whose slot `perm[g]` holds what this plan
    /// had on `g`... i.e. the job sets move *with* the mapping so that
    /// slot `perm[g]` of the result equals slot `g` of `self`.
    pub fn relabeled(&self, new_gpu_of: &[usize]) -> PlacementPlan {
        assert_eq!(new_gpu_of.len(), self.slots.len());
        let mut out = PlacementPlan::new(self.slots.len());
        let mut seen = vec![false; self.slots.len()];
        for (g, &tgt) in new_gpu_of.iter().enumerate() {
            assert!(!seen[tgt], "relabel map is not a permutation");
            seen[tgt] = true;
            out.slots[tgt] = self.slots[g].clone();
        }
        // The index moves with the mapping: O(jobs × their GPUs · log).
        for (&job, gpus) in &self.index {
            let mut moved: Vec<usize> = gpus.iter().map(|&g| new_gpu_of[g]).collect();
            moved.sort_unstable();
            out.index.insert(job, moved);
        }
        out
    }

    /// Whether a (multi-GPU) job's placement is *consolidated* w.r.t. the
    /// topology: it occupies the minimum possible number of nodes, and its
    /// per-node GPU counts completely fill nodes except at most one.
    pub fn is_consolidated(&self, job: JobId, spec: &ClusterSpec) -> bool {
        let gpus = self.gpus_of(job);
        if gpus.len() <= 1 {
            return true;
        }
        let mut per_node: BTreeMap<usize, usize> = BTreeMap::new();
        for &g in gpus {
            *per_node.entry(spec.node_of(g)).or_default() += 1;
        }
        let min_nodes = gpus.len().div_ceil(spec.gpus_per_node);
        per_node.len() == min_nodes
    }

    /// Count of jobs whose GPU sets differ between `prev` and `self`,
    /// restricted to jobs present in both (Definition 1). O(active jobs ×
    /// their GPUs) via the two indexes.
    pub fn migrations_from(&self, prev: &PlacementPlan) -> usize {
        let mut count = 0;
        for (job, gpus) in &self.index {
            if let Some(prev_gpus) = prev.index.get(job) {
                if prev_gpus != gpus {
                    count += 1;
                }
            }
        }
        count
    }

    /// Sanity-check plan invariants (≤2 tenants, no duplicate tenancy) and
    /// cross-check that the incremental job→GPU index agrees with a
    /// from-scratch rebuild of the slots view.
    pub fn validate(&self) -> Result<(), String> {
        for (g, slot) in self.slots.iter().enumerate() {
            if slot.len() > MAX_JOBS_PER_GPU {
                return Err(format!("gpu {g} has {} tenants", slot.len()));
            }
            let set: BTreeSet<_> = slot.iter().collect();
            if set.len() != slot.len() {
                return Err(format!("gpu {g} lists a job twice"));
            }
        }
        let mut rebuilt: BTreeMap<JobId, Vec<usize>> = BTreeMap::new();
        for (g, slot) in self.slots.iter().enumerate() {
            for &j in slot {
                rebuilt.entry(j).or_default().push(g);
            }
        }
        if rebuilt != self.index {
            return Err(format!(
                "job->GPU index diverged from slots: index {:?} vs rebuilt {:?}",
                self.index, rebuilt
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;

    fn spec() -> ClusterSpec {
        ClusterSpec::new(2, 4, GpuType::A100)
    }

    #[test]
    fn place_remove_roundtrip() {
        let mut p = PlacementPlan::new(8);
        p.place(1, &[0, 1]);
        p.place(2, &[1]);
        assert_eq!(p.gpus_of(1), vec![0, 1]);
        assert_eq!(p.jobs_on(1), &[1, 2]);
        assert_eq!(p.free_capacity(1), 0);
        assert_eq!(p.remove(1), vec![0, 1]);
        assert_eq!(p.gpus_of(1), Vec::<usize>::new());
        assert_eq!(p.jobs_on(1), &[2]);
        p.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "tenants")]
    fn overpacking_panics() {
        let mut p = PlacementPlan::new(1);
        p.place(1, &[0]);
        p.place(2, &[0]);
        p.place(3, &[0]);
    }

    #[test]
    fn relabel_moves_job_sets() {
        // Paper §4.1 observation: plans {(0,1),(1,2),(2,2),(3,4)} and
        // {(0,4),(1,1),(2,2),(3,2)} align via 0->1, 1->3, 3->0 (2->2).
        let mut next = PlacementPlan::new(4);
        next.place(4, &[0]);
        next.place(1, &[1]);
        next.place(2, &[2, 3]);
        // Logical gpu g of `next` is realized on physical gpu perm[g]:
        // logical 0 (job 4) -> physical 3, logical 1 (job 1) -> 0,
        // logical 3 (job 2's second gpu) -> 1.
        let perm = vec![3, 0, 2, 1];
        let aligned = next.relabeled(&perm);
        aligned.validate().unwrap();
        let mut prev = PlacementPlan::new(4);
        prev.place(1, &[0]);
        prev.place(2, &[1, 2]);
        prev.place(4, &[3]);
        assert_eq!(aligned.migrations_from(&prev), 0);
    }

    #[test]
    #[should_panic(expected = "not a permutation")]
    fn bad_relabel_panics() {
        let p = PlacementPlan::new(2);
        p.relabeled(&[0, 0]);
    }

    #[test]
    fn consolidation_detection() {
        let s = spec();
        let mut p = PlacementPlan::new(8);
        p.place(1, &[0, 1]); // same node -> consolidated
        p.place(2, &[3, 4]); // spans nodes while fitting in one -> not
        p.place(3, &[0, 1, 2, 3, 4, 5, 6, 7]); // 8 GPUs must span both nodes
        assert!(p.is_consolidated(1, &s));
        assert!(!p.is_consolidated(2, &s));
        assert!(p.is_consolidated(3, &s));
    }

    #[test]
    fn migration_counting_ignores_entering_and_leaving_jobs() {
        let mut prev = PlacementPlan::new(4);
        prev.place(1, &[0]);
        prev.place(2, &[1]);
        let mut cur = PlacementPlan::new(4);
        cur.place(1, &[2]); // moved -> 1 migration
        cur.place(9, &[1]); // new job -> not a migration (Definition 1)
        assert_eq!(cur.migrations_from(&prev), 1);
    }

    #[test]
    fn remove_jobs_bulk() {
        let mut p = PlacementPlan::new(4);
        p.place(1, &[0, 1]);
        p.place(2, &[2]);
        p.place(3, &[2]);
        let gone: BTreeSet<JobId> = [1, 3].into_iter().collect();
        assert_eq!(p.remove_jobs(&gone), 3);
        assert_eq!(p.jobs().into_iter().collect::<Vec<_>>(), vec![2]);
        p.validate().unwrap();
    }

    #[test]
    fn job_gpu_map_sorted() {
        let mut p = PlacementPlan::new(4);
        p.place(7, &[3, 0]);
        let m = p.job_gpu_map();
        assert_eq!(m[&7], vec![0, 3]);
    }

    #[test]
    fn index_survives_unsorted_placement_and_partial_removal() {
        let mut p = PlacementPlan::new(6);
        p.place(1, &[5, 2, 0]);
        assert_eq!(p.gpus_of(1), vec![0, 2, 5]);
        p.place(2, &[2, 5]);
        p.validate().unwrap();
        assert_eq!(p.remove(1), vec![0, 2, 5]);
        assert_eq!(p.gpus_of(2), vec![2, 5]);
        p.validate().unwrap();
        // Removing a job not in the plan is a no-op.
        assert_eq!(p.remove(99), Vec::<usize>::new());
        assert_eq!(p.jobs().len(), 1);
    }

    #[test]
    fn equality_is_slot_equality() {
        let mut a = PlacementPlan::new(2);
        a.place(1, &[0]);
        let mut b = PlacementPlan::new(2);
        b.place(1, &[0]);
        assert_eq!(a, b);
        b.place(2, &[1]);
        assert_ne!(a, b);
    }
}

//! Cluster topology: nodes × GPUs, GPU types, and placement plans.

pub mod placement;

pub use placement::PlacementPlan;

/// GPU hardware generations the evaluation uses (§6: 40 GB A100 on
/// Perlmutter; 16 GB V100 on AWS p3.16xlarge for the adaptability study).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuType {
    A100,
    V100,
}

impl GpuType {
    pub fn name(&self) -> &'static str {
        match self {
            GpuType::A100 => "a100",
            GpuType::V100 => "v100",
        }
    }

    pub fn from_name(s: &str) -> Option<GpuType> {
        match s {
            "a100" => Some(GpuType::A100),
            "v100" => Some(GpuType::V100),
            _ => None,
        }
    }

    /// Device memory in GB.
    pub fn mem_gb(&self) -> f64 {
        match self {
            GpuType::A100 => 40.0,
            GpuType::V100 => 16.0,
        }
    }

    /// Relative compute speed (A100 = 1.0) used by the synthetic profiler.
    pub fn speed_factor(&self) -> f64 {
        match self {
            GpuType::A100 => 1.0,
            GpuType::V100 => 0.45,
        }
    }
}

/// Static cluster shape. GPUs are numbered globally, node-major:
/// GPU `g` lives on node `g / gpus_per_node`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClusterSpec {
    pub num_nodes: usize,
    pub gpus_per_node: usize,
    pub gpu_type: GpuType,
}

impl ClusterSpec {
    pub fn new(num_nodes: usize, gpus_per_node: usize, gpu_type: GpuType) -> ClusterSpec {
        assert!(num_nodes > 0 && gpus_per_node > 0);
        ClusterSpec {
            num_nodes,
            gpus_per_node,
            gpu_type,
        }
    }

    /// The paper's physical testbed: 8 nodes × 4 A100 (32 GPUs).
    pub fn perlmutter_32() -> ClusterSpec {
        ClusterSpec::new(8, 4, GpuType::A100)
    }

    /// The paper's simulation cluster: 80 GPUs (20 nodes × 4).
    pub fn sim_80() -> ClusterSpec {
        ClusterSpec::new(20, 4, GpuType::A100)
    }

    /// The scalability cluster: 256 GPUs (32 nodes × 8).
    pub fn scale_256() -> ClusterSpec {
        ClusterSpec::new(32, 8, GpuType::A100)
    }

    pub fn total_gpus(&self) -> usize {
        self.num_nodes * self.gpus_per_node
    }

    pub fn node_of(&self, gpu: usize) -> usize {
        debug_assert!(gpu < self.total_gpus());
        gpu / self.gpus_per_node
    }

    /// Global GPU ids of a node.
    pub fn gpus_of_node(&self, node: usize) -> std::ops::Range<usize> {
        debug_assert!(node < self.num_nodes);
        node * self.gpus_per_node..(node + 1) * self.gpus_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_indexing() {
        let c = ClusterSpec::perlmutter_32();
        assert_eq!(c.total_gpus(), 32);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(3), 0);
        assert_eq!(c.node_of(4), 1);
        assert_eq!(c.node_of(31), 7);
        assert_eq!(c.gpus_of_node(2), 8..12);
    }

    #[test]
    fn gpu_types() {
        assert_eq!(GpuType::A100.mem_gb(), 40.0);
        assert_eq!(GpuType::V100.mem_gb(), 16.0);
        assert!(GpuType::V100.speed_factor() < GpuType::A100.speed_factor());
        assert_eq!(GpuType::from_name("v100"), Some(GpuType::V100));
        assert_eq!(GpuType::from_name("h100"), None);
    }

    #[test]
    fn preset_shapes() {
        assert_eq!(ClusterSpec::sim_80().total_gpus(), 80);
        assert_eq!(ClusterSpec::scale_256().total_gpus(), 256);
    }
}

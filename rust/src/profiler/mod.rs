//! Synthetic throughput / memory profiler.
//!
//! The paper profiles every model and model pair on real A100/V100 GPUs
//! (§5 "Profiling"). We do not have that hardware, so this module is the
//! documented substitution (DESIGN.md §2): a *structured* analytic model of
//! isolated throughput, packed throughput and per-GPU memory that preserves
//! the behaviours the placement policies react to:
//!
//! * data-parallel jobs scale near-linearly with small efficiency loss;
//! * pipeline-parallel throughput is bottlenecked by the max-load stage;
//! * packing two jobs on a GPU slows both in proportion to the partner's
//!   compute intensity;
//! * contention is heavier on the *front* GPUs of a pipeline job (data
//!   loading / embedding colocate there), so front-light pipeline splits —
//!   like the paper's GPT3-3B (3,3,3,4,4,5,5,5) — win under packing while
//!   losing slightly in isolation (Fig. 8);
//! * 1F1B pipeline schedules hold more in-flight activations on earlier
//!   stages, so packing a memory-hungry partner with a *default* PP split
//!   can OOM where a front-light split fits (Fig. 8's VGG-19 case);
//! * V100s are slower and have 16 GB instead of 40 GB, shrinking packing
//!   opportunities (Fig. 12(b)).
//!
//! All throughputs carry a small deterministic jitter (profiling noise) and
//! an optional *decision noise* `n_p` (Fig. 16): the scheduler sees noisy
//! values while the simulator advances jobs with the true ones.

use crate::cluster::GpuType;
use crate::jobs::{ModelKind, ParallelismStrategy};
use crate::util::rng::Pcg64;

/// A job's compute configuration for profiling purposes.
pub type JobCfg<'a> = (ModelKind, &'a ParallelismStrategy);

/// Synthetic profiler for one GPU type.
#[derive(Debug, Clone)]
pub struct Profiler {
    pub gpu: GpuType,
    /// Deterministic profiling jitter amplitude (fraction, e.g. 0.05).
    pub jitter: f64,
    /// Decision noise `n_p` of Fig. 16 — applied only by the `profiled_*`
    /// accessors the scheduler uses, never by the `true_*` ones.
    pub noise_p: f64,
    seed: u64,
    /// Independent stream for decision noise so adding noise never perturbs
    /// the underlying true profile.
    noise_seed: u64,
}

/// In-flight activation growth per earlier pipeline stage (1F1B).
const PP_ACT_GROWTH: f64 = 0.35;
/// Front-of-pipeline contention shape: w(g) runs 1.3 (front) -> 0.7 (back).
const CONTENTION_FRONT: f64 = 1.3;
const CONTENTION_BACK: f64 = 0.7;

impl Profiler {
    pub fn new(gpu: GpuType, seed: u64) -> Profiler {
        Profiler {
            gpu,
            jitter: 0.05,
            noise_p: 0.0,
            seed,
            noise_seed: seed,
        }
    }

    /// A copy whose *scheduler-visible* throughputs carry noise `n_p`.
    pub fn with_decision_noise(&self, noise_p: f64, seed: u64) -> Profiler {
        Profiler {
            noise_p,
            noise_seed: self.seed ^ seed.rotate_left(17),
            ..self.clone()
        }
    }

    // ---------------------------------------------------------------- memory

    /// Per-GPU memory (GB) of a job on GPU index `g` (0-based within the
    /// job's GPU set of size `n`).
    pub fn mem_on_gpu(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32, g: u32) -> f64 {
        let act = model.activation_mem_gb();
        let mm = model.model_mem_gb();
        match strategy {
            ParallelismStrategy::DataParallel => mm + act,
            ParallelismStrategy::TensorParallel => mm / n as f64 + act + 0.5,
            ParallelismStrategy::Pipeline(split) => {
                let layers: u32 = split.iter().sum();
                let s_g = split[g as usize] as f64;
                let avg = layers as f64 / n as f64;
                let model_part = mm * s_g / layers as f64;
                // 1F1B: stage g holds ~(n-g) in-flight microbatches, and the
                // activation volume scales with the stage's layer share.
                let act_part = act * (s_g / avg) * (1.0 + PP_ACT_GROWTH * (n - 1 - g) as f64);
                model_part + act_part
            }
        }
    }

    /// Worst-case per-GPU memory across the job's GPUs.
    pub fn mem_per_gpu_max(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        (0..n)
            .map(|g| self.mem_on_gpu(model, strategy, n, g))
            .fold(0.0, f64::max)
    }

    /// Whether a job fits on this GPU type in isolation.
    pub fn fits_isolated(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> bool {
        self.mem_per_gpu_max(model, strategy, n) <= self.gpu.mem_gb()
    }

    /// Whether two jobs can share every GPU of an `n`-GPU set without OOM.
    pub fn fits_packed(&self, a: JobCfg, b: JobCfg, n: u32) -> bool {
        (0..n).all(|g| {
            self.mem_on_gpu(a.0, a.1, n, g) + self.mem_on_gpu(b.0, b.1, n, g)
                <= self.gpu.mem_gb()
        })
    }

    // ------------------------------------------------------------ throughput

    /// True isolated throughput (iterations/s) of a job over `n` GPUs.
    /// Returns 0.0 if the configuration does not fit in memory.
    pub fn true_isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        if !self.fits_isolated(model, strategy, n) {
            return 0.0;
        }
        let base = model.base_tput_a100() * self.gpu.speed_factor();
        let nf = n as f64;
        let log2n = nf.log2();
        let scale = match strategy {
            ParallelismStrategy::DataParallel => {
                let eff = if model.is_llm() { 0.92f64 } else { 0.95 };
                nf * eff.powf(log2n)
            }
            ParallelismStrategy::TensorParallel => nf * 0.75f64.powf(log2n),
            ParallelismStrategy::Pipeline(split) => {
                let layers: f64 = split.iter().sum::<u32>() as f64;
                let max_stage = split.iter().copied().max().unwrap_or(1) as f64;
                let balance = (layers / nf) / max_stage; // avg / max
                nf * balance * 0.93
            }
        };
        base * scale * self.jitter_factor(&[model as u64, strategy.tag(), n as u64, 1])
    }

    /// Best isolated (strategy, throughput) over the candidate set — the
    /// normalization denominator Fig. 8 uses.
    pub fn best_isolated(&self, model: ModelKind, n: u32) -> (ParallelismStrategy, f64) {
        ParallelismStrategy::candidates(model, n)
            .into_iter()
            .map(|s| {
                let t = self.true_isolated_tput(model, &s, n);
                (s, t)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .expect("non-empty candidate set")
    }

    /// Per-GPU compute load of a job on GPU `g` (relative units).
    fn load_on_gpu(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32, g: u32) -> f64 {
        let c = model.compute_intensity();
        match strategy {
            ParallelismStrategy::Pipeline(split) => {
                let layers: f64 = split.iter().sum::<u32>() as f64;
                let avg = layers / n as f64;
                c * split[g as usize] as f64 / avg
            }
            _ => c,
        }
    }

    /// Position-dependent contention weight along the job's GPU set.
    fn contention(g: u32, n: u32) -> f64 {
        if n <= 1 {
            return (CONTENTION_FRONT + CONTENTION_BACK) / 2.0;
        }
        let frac = g as f64 / (n - 1) as f64;
        CONTENTION_FRONT + (CONTENTION_BACK - CONTENTION_FRONT) * frac
    }

    /// Retention of job `a`'s throughput when packed with `b` (fraction of
    /// its own isolated throughput at the same strategy).
    fn retention(&self, a: JobCfg, b: JobCfg, n: u32) -> f64 {
        match a.1 {
            ParallelismStrategy::Pipeline(split) => {
                // Bottleneck stage shifts under position-dependent contention.
                let iso_max = split.iter().copied().max().unwrap_or(1) as f64;
                let packed_max = (0..n)
                    .map(|g| {
                        let interference =
                            self.load_on_gpu(b.0, b.1, n, g) * Self::contention(g, n);
                        split[g as usize] as f64 * (1.0 + interference)
                    })
                    .fold(0.0, f64::max);
                iso_max / packed_max
            }
            _ => {
                // Uniform-load jobs: average contention over the GPU set.
                let avg_interference = (0..n)
                    .map(|g| self.load_on_gpu(b.0, b.1, n, g) * Self::contention(g, n))
                    .sum::<f64>()
                    / n as f64;
                let softener = 0.4 + 0.6 * a.0.compute_intensity();
                1.0 / (1.0 + avg_interference * softener)
            }
        }
    }

    /// True packed throughputs `(tput_a, tput_b)` when `a` and `b` share an
    /// `n`-GPU set; `None` if the pair OOMs on any GPU.
    pub fn true_packed_tput(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        if !self.fits_packed(a, b, n) {
            return None;
        }
        let iso_a = self.true_isolated_tput(a.0, a.1, n);
        let iso_b = self.true_isolated_tput(b.0, b.1, n);
        if iso_a == 0.0 || iso_b == 0.0 {
            return None;
        }
        let ta = iso_a * self.retention(a, b, n).min(1.0);
        let tb = iso_b * self.retention(b, a, n).min(1.0);
        let j = self.jitter_factor(&[
            a.0 as u64,
            a.1.tag(),
            b.0 as u64,
            b.1.tag(),
            n as u64,
        ]);
        Some((ta * j, tb * j))
    }

    /// True *normalized* packed pair throughput: each job's packed
    /// throughput divided by its best isolated throughput (§4.2). The sum of
    /// the two values is Algorithm 4's edge weight.
    pub fn true_normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        let (ta, tb) = self.true_packed_tput(a, b, n)?;
        let (_, best_a) = self.best_isolated(a.0, n);
        let (_, best_b) = self.best_isolated(b.0, n);
        Some((ta / best_a, tb / best_b))
    }

    // ------------------------------------------------- scheduler-visible view

    /// Scheduler-visible packed pair (adds decision noise `n_p`, Fig. 16).
    pub fn profiled_normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        let (na, nb) = self.true_normalized_pair(a, b, n)?;
        if self.noise_p == 0.0 {
            return Some((na, nb));
        }
        let f = self.noise_factor(&[
            a.0 as u64,
            a.1.tag(),
            b.0 as u64,
            b.1.tag(),
            n as u64,
        ]);
        Some((na * f, nb * f))
    }

    /// Scheduler-visible isolated throughput.
    pub fn profiled_isolated_tput(
        &self,
        model: ModelKind,
        strategy: &ParallelismStrategy,
        n: u32,
    ) -> f64 {
        let t = self.true_isolated_tput(model, strategy, n);
        if self.noise_p == 0.0 {
            t
        } else {
            t * self.noise_factor(&[model as u64, strategy.tag(), n as u64, 7])
        }
    }

    // ---------------------------------------------------------------- noise

    fn keyed_rng(&self, key: &[u64], salt: u64) -> Pcg64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64 ^ self.seed ^ salt;
        for &k in key {
            h ^= k.wrapping_add(0x9e37_79b9_7f4a_7c15);
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Pcg64::new(h)
    }

    /// Deterministic profiling jitter in [1-jitter, 1+jitter].
    fn jitter_factor(&self, key: &[u64]) -> f64 {
        if self.jitter == 0.0 {
            return 1.0;
        }
        let mut r = self.keyed_rng(key, 0xa5a5);
        r.range_f64(1.0 - self.jitter, 1.0 + self.jitter)
    }

    /// Fig. 16 noise in [1-n_p, 1+n_p].
    fn noise_factor(&self, key: &[u64]) -> f64 {
        let mut r = self.keyed_rng(key, 0x5a5a ^ self.noise_seed);
        r.range_f64((1.0 - self.noise_p).max(0.0), 1.0 + self.noise_p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::ModelKind::*;

    fn a100() -> Profiler {
        Profiler::new(GpuType::A100, 42)
    }

    fn dp() -> ParallelismStrategy {
        ParallelismStrategy::DataParallel
    }

    #[test]
    fn dp_scales_sublinearly() {
        let p = a100();
        let t1 = p.true_isolated_tput(ResNet50, &dp(), 1);
        let t8 = p.true_isolated_tput(ResNet50, &dp(), 8);
        assert!(t8 > 5.0 * t1, "t8={t8} t1={t1}");
        assert!(t8 < 8.0 * t1 * 1.1, "t8={t8} t1={t1}");
    }

    #[test]
    fn default_pp_beats_frontlight_in_isolation() {
        let p = a100();
        let even = ParallelismStrategy::default_pp(Gpt3_3B, 8);
        let t_even = p.true_isolated_tput(Gpt3_3B, &even, 8);
        let fl = ParallelismStrategy::Pipeline(vec![3, 3, 3, 4, 4, 5, 5, 5]);
        let t_fl = p.true_isolated_tput(Gpt3_3B, &fl, 8);
        assert!(t_even > t_fl, "even {t_even} vs front-light {t_fl}");
    }

    #[test]
    fn frontlight_wins_under_packing() {
        // Fig. 8's core effect: the best PP split under packing is not the
        // default even split.
        let p = a100();
        let even = ParallelismStrategy::default_pp(Gpt3_3B, 8);
        let fl = ParallelismStrategy::Pipeline(vec![3, 3, 3, 4, 4, 5, 5, 5]);
        let partner = (ResNet50, &dp());
        let (even_n, _) = p
            .true_normalized_pair((Gpt3_3B, &even), partner, 8)
            .unwrap();
        let (fl_n, _) = p.true_normalized_pair((Gpt3_3B, &fl), partner, 8).unwrap();
        assert!(fl_n > even_n, "front-light {fl_n} <= even {even_n}");
    }

    #[test]
    fn vgg_with_default_pp_3b_oom_but_frontlight_fits() {
        // Fig. 8's OOM case: VGG-19 packed with GPT3-3B under the default PP
        // split OOMs on 40 GB A100s; a front-light split fits.
        let p = a100();
        let even = ParallelismStrategy::default_pp(Gpt3_3B, 8);
        let fl = ParallelismStrategy::Pipeline(vec![3, 3, 3, 4, 4, 5, 5, 5]);
        let vgg = (Vgg19, &dp());
        assert!(p.true_packed_tput((Gpt3_3B, &even), vgg, 8).is_none());
        assert!(p.true_packed_tput((Gpt3_3B, &fl), vgg, 8).is_some());
    }

    #[test]
    fn v100_reduces_packing_opportunities() {
        // Fig. 12(b): on 16 GB V100s many pairs that pack on A100 OOM.
        let a = a100();
        let v = Profiler::new(GpuType::V100, 42);
        let pairs = [
            ((ResNet50, dp()), (Vgg19, dp())),
            ((Dcgan, dp()), (Vgg19, dp())),
            ((PointNet, dp()), (ResNet50, dp())),
        ];
        let packable = |p: &Profiler| {
            pairs
                .iter()
                .filter(|((m1, s1), (m2, s2))| p.fits_packed((*m1, s1), (*m2, s2), 1))
                .count()
        };
        assert!(packable(&a) > packable(&v), "{} vs {}", packable(&a), packable(&v));
        // And V100 is simply slower.
        assert!(
            v.true_isolated_tput(ResNet50, &dp(), 1) < a.true_isolated_tput(ResNet50, &dp(), 1)
        );
    }

    #[test]
    fn packing_light_jobs_is_beneficial() {
        // PointNet (compute-light) packs well: combined normalized
        // throughput exceeds 1.0.
        let p = a100();
        let (na, nb) = p
            .true_normalized_pair((PointNet, &dp()), (Dcgan, &dp()), 1)
            .unwrap();
        assert!(na + nb > 1.0, "sum {}", na + nb);
        // Two VGGs (compute-heavy) barely gain.
        let (va, vb) = p
            .true_normalized_pair((Vgg19, &dp()), (Vgg19, &dp()), 1)
            .unwrap();
        assert!(va + vb < na + nb);
    }

    #[test]
    fn retention_is_a_fraction() {
        let p = a100();
        for m in ModelKind::ALL {
            if let Some((ta, tb)) = p.true_packed_tput((m, &dp()), (ResNet50, &dp()), 1) {
                let ia = p.true_isolated_tput(m, &dp(), 1);
                let ib = p.true_isolated_tput(ResNet50, &dp(), 1);
                assert!(ta <= ia * 1.1 && ta > 0.0);
                assert!(tb <= ib * 1.1 && tb > 0.0);
            }
        }
    }

    #[test]
    fn decision_noise_only_affects_profiled_view() {
        let p = a100().with_decision_noise(1.0, 7);
        let a = (PointNet, dp());
        let b = (Dcgan, dp());
        let truth = p.true_normalized_pair((a.0, &a.1), (b.0, &b.1), 1).unwrap();
        let clean = Profiler::new(GpuType::A100, 42)
            .true_normalized_pair((a.0, &a.1), (b.0, &b.1), 1)
            .unwrap();
        assert_eq!(truth, clean);
        let noisy = p
            .profiled_normalized_pair((a.0, &a.1), (b.0, &b.1), 1)
            .unwrap();
        assert_ne!(noisy, truth);
    }

    #[test]
    fn noise_is_deterministic() {
        let p = a100().with_decision_noise(0.5, 9);
        let a = (PointNet, dp());
        let b = (Dcgan, dp());
        let x = p.profiled_normalized_pair((a.0, &a.1), (b.0, &b.1), 1);
        let y = p.profiled_normalized_pair((a.0, &a.1), (b.0, &b.1), 1);
        assert_eq!(x, y);
    }

    #[test]
    fn gpt3_3b_dp_infeasible_on_v100() {
        let v = Profiler::new(GpuType::V100, 1);
        assert!(!v.fits_isolated(Gpt3_3B, &dp(), 4));
        assert_eq!(v.true_isolated_tput(Gpt3_3B, &dp(), 4), 0.0);
        // But some pipeline split fits.
        let (best, t) = v.best_isolated(Gpt3_3B, 8);
        assert!(t > 0.0, "no feasible strategy found: {}", best.name());
    }

    #[test]
    fn best_isolated_prefers_feasible_fastest() {
        let p = a100();
        let (s, t) = p.best_isolated(Gpt3_3B, 8);
        assert!(t > 0.0);
        // For LLMs at 8 GPUs the winner should not be TP (heavy comm).
        assert_ne!(s, ParallelismStrategy::TensorParallel);
    }
}

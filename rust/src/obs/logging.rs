//! Leveled stderr logging behind `obs::log!`, honoring `TESSERAE_LOG`.
//!
//! Replaces the ad-hoc `eprintln!` progress prints: by default only
//! `error` and `warn` reach stderr (so `cargo test` output stays quiet),
//! `TESSERAE_LOG=info` or `=debug` turns on progress chatter, and
//! `TESSERAE_LOG=off` silences everything. Independent of the telemetry
//! enable flag — a checkpoint-write failure warns even when no one is
//! tracing.

use std::fmt;
use std::sync::OnceLock;

/// Env knob: `off`/`error`/`warn`/`info`/`debug` (or `0`..`4`).
pub const LOG_ENV: &str = "TESSERAE_LOG";

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Number of enabled levels: 0 = off, 1 = error only, ... 4 = everything.
fn parse_threshold(raw: Option<&str>) -> u8 {
    match raw.map(|s| s.trim().to_ascii_lowercase()).as_deref() {
        Some("off") | Some("none") | Some("0") => 0,
        Some("error") | Some("1") => 1,
        Some("warn") | Some("warning") | Some("2") => 2,
        Some("info") | Some("3") => 3,
        Some("debug") | Some("trace") | Some("4") => 4,
        // Unset or unrecognized: errors + warnings.
        _ => 2,
    }
}

fn threshold() -> u8 {
    static THRESHOLD: OnceLock<u8> = OnceLock::new();
    *THRESHOLD.get_or_init(|| parse_threshold(std::env::var(LOG_ENV).ok().as_deref()))
}

/// Whether `level` currently prints (cheap after first call: one static
/// read, no env access).
pub fn level_enabled(level: Level) -> bool {
    (level as u8) < threshold()
}

/// Backend of `obs::log!`: format and print to stderr if enabled.
pub fn log(level: Level, target: &str, args: fmt::Arguments<'_>) {
    if !level_enabled(level) {
        return;
    }
    eprintln!("[{}] {target}: {args}", level.tag());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn threshold_parsing() {
        assert_eq!(parse_threshold(None), 2);
        assert_eq!(parse_threshold(Some("garbage")), 2);
        assert_eq!(parse_threshold(Some("off")), 0);
        assert_eq!(parse_threshold(Some("ERROR")), 1);
        assert_eq!(parse_threshold(Some("warn")), 2);
        assert_eq!(parse_threshold(Some("info")), 3);
        assert_eq!(parse_threshold(Some("debug")), 4);
        assert_eq!(parse_threshold(Some(" 3 ")), 3);
    }

    #[test]
    fn severity_ordering_matches_thresholds() {
        // At the default threshold (2), warn prints and info does not.
        assert!((Level::Error as u8) < 2);
        assert!((Level::Warn as u8) < 2);
        assert!((Level::Info as u8) >= 2);
        assert!((Level::Debug as u8) >= 2);
        assert!(Level::Error < Level::Warn && Level::Warn < Level::Debug);
    }

    #[test]
    fn log_macro_compiles_at_every_level() {
        // Output may or may not print depending on the env; the test is
        // that the macro paths type-check and run without panicking.
        crate::obs_log!(error, "e {}", 1);
        crate::obs_log!(warn, "w {}", 2);
        crate::obs_log!(info, "i {}", 3);
        crate::obs_log!(debug, "d {x}", x = 4);
    }
}

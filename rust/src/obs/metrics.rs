//! Process-wide metrics registry: named counters, gauges and log-bucket
//! histograms behind one [`MetricsSnapshot`].
//!
//! Write sites are coarse by design — the instrumented layers publish
//! per-round aggregates (e.g. the whole `MatchingServiceStats` struct
//! once per round), not per-item increments — so a `Mutex<BTreeMap>` per
//! kind is plenty and keeps the code std-only. Every write is gated on
//! [`crate::obs::enabled`]; when telemetry is off the registry is never
//! touched and scheduling behavior cannot depend on it.
//!
//! # Scoped namespaces
//!
//! The registry is process-global, so two sweep cells (or a sweep cell and
//! a concurrent test) writing the same series names would bleed into each
//! other's snapshots. [`scope`] pushes a thread-local prefix — every write
//! from that thread lands under `<prefix>.<name>` until the guard drops —
//! and [`MetricsSnapshot::scoped`] / [`reset_scope`] read back or clear
//! exactly one prefix's series. The prefix is per *thread*: work handed to
//! the shared worker pool does not inherit it, so code that publishes from
//! pool workers (the sharded coordinator's `shard.<id>.*` series) writes
//! explicit prefixed names instead.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::marker::PhantomData;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::obs;
use crate::util::json::Json;
use crate::util::stats::Histogram;

struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    gauges: Mutex<BTreeMap<String, f64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        histograms: Mutex::new(BTreeMap::new()),
    })
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

thread_local! {
    /// Accumulated scope prefix for this thread, including trailing dots
    /// (`"cell3."`, or `"a.b."` when scopes nest). Empty = unscoped.
    static SCOPE: RefCell<String> = const { RefCell::new(String::new()) };
}

/// Run `f` with the thread's scoped key for `name` — allocation-free on
/// the (overwhelmingly common) unscoped path.
fn with_key<R>(name: &str, f: impl FnOnce(&str) -> R) -> R {
    SCOPE.with(|s| {
        let s = s.borrow();
        if s.is_empty() {
            f(name)
        } else {
            f(&format!("{}{name}", *s))
        }
    })
}

/// Prefix every metric written by *this thread* with `<prefix>.` until the
/// returned guard drops. Scopes nest (`a` then `b` yields `a.b.<name>`).
/// The guard is `!Send`: a scope belongs to the thread that opened it.
pub fn scope(prefix: &str) -> ScopeGuard {
    assert!(!prefix.is_empty(), "metric scope prefix must be non-empty");
    SCOPE.with(|s| {
        let mut s = s.borrow_mut();
        let prev_len = s.len();
        s.push_str(prefix);
        s.push('.');
        ScopeGuard {
            prev_len,
            _not_send: PhantomData,
        }
    })
}

/// RAII for [`scope`]: restores the thread's previous prefix on drop.
pub struct ScopeGuard {
    prev_len: usize,
    _not_send: PhantomData<*const ()>,
}

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        SCOPE.with(|s| s.borrow_mut().truncate(self.prev_len));
    }
}

/// Remove every series under `<prefix>.` from the registry, leaving all
/// other series untouched — the per-cell isolation primitive for sweeps
/// that reuse a scope name.
pub fn reset_scope(prefix: &str) {
    let pat = format!("{prefix}.");
    let reg = registry();
    lock(&reg.counters).retain(|k, _| !k.starts_with(&pat));
    lock(&reg.gauges).retain(|k, _| !k.starts_with(&pat));
    lock(&reg.histograms).retain(|k, _| !k.starts_with(&pat));
}

/// Add `delta` to the named monotonic counter. No-op when telemetry is
/// disabled.
pub fn counter_add(name: &str, delta: u64) {
    if !obs::enabled() || delta == 0 {
        return;
    }
    with_key(name, |key| {
        let mut m = lock(&registry().counters);
        match m.get_mut(key) {
            Some(v) => *v += delta,
            None => {
                m.insert(key.to_string(), delta);
            }
        }
    });
}

/// Set the named gauge to its latest value. No-op when disabled.
pub fn gauge_set(name: &str, value: f64) {
    if !obs::enabled() {
        return;
    }
    with_key(name, |key| {
        let mut m = lock(&registry().gauges);
        match m.get_mut(key) {
            Some(v) => *v = value,
            None => {
                m.insert(key.to_string(), value);
            }
        }
    });
}

/// Record one observation into the named histogram. No-op when disabled.
pub fn observe(name: &str, value: f64) {
    if !obs::enabled() {
        return;
    }
    with_key(name, |key| {
        let mut m = lock(&registry().histograms);
        match m.get_mut(key) {
            Some(h) => h.record(value),
            None => {
                let mut h = Histogram::new();
                h.record(value);
                m.insert(key.to_string(), h);
            }
        }
    });
}

/// Copy the registry's current state. Works regardless of the enabled
/// flag (reading never perturbs anything).
pub fn snapshot() -> MetricsSnapshot {
    let reg = registry();
    MetricsSnapshot {
        counters: lock(&reg.counters).clone(),
        gauges: lock(&reg.gauges).clone(),
        histograms: lock(&reg.histograms).clone(),
    }
}

/// Clear the registry (benches/tests isolating runs).
pub fn reset() {
    let reg = registry();
    lock(&reg.counters).clear();
    lock(&reg.gauges).clear();
    lock(&reg.histograms).clear();
}

/// A point-in-time copy of the registry, serializable into simulator
/// reports, checkpoint cells and `BENCH_*.json` artifacts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl MetricsSnapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Total number of named series (the bench telemetry arm's "metric
    /// count").
    pub fn series_count(&self) -> usize {
        self.counters.len() + self.gauges.len() + self.histograms.len()
    }

    /// The series written under `scope(prefix)`, with the prefix stripped:
    /// one cell's isolated view of a shared registry.
    pub fn scoped(&self, prefix: &str) -> MetricsSnapshot {
        let pat = format!("{prefix}.");
        let strip = |m: &BTreeMap<String, u64>| {
            m.iter()
                .filter_map(|(k, v)| Some((k.strip_prefix(&pat)?.to_string(), *v)))
                .collect()
        };
        MetricsSnapshot {
            counters: strip(&self.counters),
            gauges: self
                .gauges
                .iter()
                .filter_map(|(k, v)| Some((k.strip_prefix(&pat)?.to_string(), *v)))
                .collect(),
            histograms: self
                .histograms
                .iter()
                .filter_map(|(k, v)| Some((k.strip_prefix(&pat)?.to_string(), v.clone())))
                .collect(),
        }
    }

    /// What happened since `earlier`: counters subtract (saturating, so a
    /// reset in between degrades to the later value), gauges keep their
    /// latest value, histograms bucket-diff. Series absent from `earlier`
    /// pass through whole.
    pub fn delta_since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| {
                let base = earlier.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(base))
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, v)| match earlier.histograms.get(k) {
                Some(base) => (k.clone(), v.diff(base)),
                None => (k.clone(), v.clone()),
            })
            .collect();
        MetricsSnapshot {
            counters,
            gauges: self.gauges.clone(),
            histograms,
        }
    }

    /// Serialize as `{counters: {...}, gauges: {...}, histograms:
    /// {name: {count, mean, p50, p95, p99, min, max, sum}}}`.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v)))
                .collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::num(h.count() as f64)),
                            ("mean", Json::num(h.mean())),
                            ("p50", Json::num(h.percentile(50.0))),
                            ("p95", Json::num(h.percentile(95.0))),
                            ("p99", Json::num(h.percentile(99.0))),
                            ("min", Json::num(h.min())),
                            ("max", Json::num(h.max())),
                            ("sum", Json::num(h.sum())),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_are_inert_when_disabled() {
        let _guard = obs::enabled_guard(false);
        let before = snapshot();
        counter_add("test.metrics.disabled", 7);
        gauge_set("test.metrics.disabled.g", 1.0);
        observe("test.metrics.disabled.h", 0.5);
        let after = snapshot();
        assert!(!after.counters.contains_key("test.metrics.disabled"));
        assert!(!after.gauges.contains_key("test.metrics.disabled.g"));
        assert!(!after.histograms.contains_key("test.metrics.disabled.h"));
        // Nothing else changed either (we hold the toggle lock, so no
        // concurrent test can be enabled right now).
        assert_eq!(before, after);
    }

    #[test]
    fn counters_gauges_histograms_round_trip() {
        let _guard = obs::enabled_guard(true);
        counter_add("test.metrics.c", 2);
        counter_add("test.metrics.c", 3);
        gauge_set("test.metrics.g", 1.5);
        gauge_set("test.metrics.g", 2.5);
        observe("test.metrics.h", 0.010);
        observe("test.metrics.h", 0.020);
        let snap = snapshot();
        assert!(snap.counters["test.metrics.c"] >= 5);
        assert_eq!(snap.gauges["test.metrics.g"], 2.5);
        let h = &snap.histograms["test.metrics.h"];
        assert!(h.count() >= 2);
        assert!(h.max() >= 0.020);

        let json = snap.to_json();
        let text = json.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed
            .get("counters")
            .and_then(|c| c.get("test.metrics.c"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            >= 5.0);
        assert!(parsed
            .get("histograms")
            .and_then(|h| h.get("test.metrics.h"))
            .and_then(|h| h.get("p99"))
            .is_some());
    }

    #[test]
    fn delta_since_subtracts_counters_and_diffs_histograms() {
        let _guard = obs::enabled_guard(true);
        counter_add("test.metrics.delta", 10);
        observe("test.metrics.delta.h", 1.0);
        let base = snapshot();
        counter_add("test.metrics.delta", 4);
        observe("test.metrics.delta.h", 2.0);
        observe("test.metrics.delta.h", 2.0);
        let now = snapshot();
        let d = now.delta_since(&base);
        assert_eq!(d.counters["test.metrics.delta"], 4);
        assert_eq!(d.histograms["test.metrics.delta.h"].count(), 2);
        // A no-change delta is all zeros.
        let z = now.delta_since(&now);
        assert_eq!(z.counters["test.metrics.delta"], 0);
        assert!(z.histograms["test.metrics.delta.h"].is_empty());
    }

    #[test]
    fn scoped_writes_prefix_and_extract() {
        let _guard = obs::enabled_guard(true);
        {
            let _s = scope("test.mscope.outer");
            counter_add("c", 3);
            gauge_set("g", 7.5);
            observe("h", 0.25);
            {
                let _inner = scope("nested");
                counter_add("c", 1);
            }
        }
        // Scope closed: unprefixed again.
        counter_add("test.mscope.plain", 1);
        let snap = snapshot();
        assert_eq!(snap.counters["test.mscope.outer.c"], 3);
        assert_eq!(snap.gauges["test.mscope.outer.g"], 7.5);
        assert_eq!(snap.counters["test.mscope.outer.nested.c"], 1);
        assert!(snap.counters.contains_key("test.mscope.plain"));
        assert!(!snap.counters.contains_key("c"), "scope leaked a bare key");

        let cell = snap.scoped("test.mscope.outer");
        assert_eq!(cell.counters["c"], 3);
        assert_eq!(cell.gauges["g"], 7.5);
        assert_eq!(cell.histograms["h"].count(), 1);
        assert_eq!(cell.counters["nested.c"], 1);
        assert!(!cell.counters.contains_key("test.mscope.plain"));
        reset_scope("test.mscope.outer");
    }

    #[test]
    fn concurrent_scopes_do_not_bleed() {
        // The delta test the satellite asks for: two concurrent "sweep
        // cells" on separate threads write the *same* series names under
        // different scopes; each cell's scoped snapshot sees only its own
        // values.
        let _guard = obs::enabled_guard(true);
        let cells = ["test.mscope.cell_a", "test.mscope.cell_b"];
        let handles: Vec<_> = cells
            .iter()
            .enumerate()
            .map(|(i, &name)| {
                std::thread::spawn(move || {
                    let _s = scope(name);
                    for _ in 0..50 {
                        counter_add("rounds", 1 + i as u64);
                        observe("round.total_s", 0.001 * (i + 1) as f64);
                    }
                    gauge_set("jobs", 10.0 * (i + 1) as f64);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let snap = snapshot();
        let a = snap.scoped("test.mscope.cell_a");
        let b = snap.scoped("test.mscope.cell_b");
        assert_eq!(a.counters["rounds"], 50);
        assert_eq!(b.counters["rounds"], 100);
        assert_eq!(a.gauges["jobs"], 10.0);
        assert_eq!(b.gauges["jobs"], 20.0);
        assert_eq!(a.histograms["round.total_s"].count(), 50);
        assert_eq!(b.histograms["round.total_s"].count(), 50);
        for c in cells {
            reset_scope(c);
        }
        let after = snapshot();
        assert!(after.scoped("test.mscope.cell_a").is_empty());
        assert!(after.scoped("test.mscope.cell_b").is_empty());
    }

    #[test]
    fn reset_scope_leaves_other_series_alone() {
        let _guard = obs::enabled_guard(true);
        {
            let _s = scope("test.mscope.reset_me");
            counter_add("c", 1);
        }
        {
            let _s = scope("test.mscope.keep_me");
            counter_add("c", 2);
        }
        reset_scope("test.mscope.reset_me");
        let snap = snapshot();
        assert!(!snap.counters.contains_key("test.mscope.reset_me.c"));
        assert_eq!(snap.counters["test.mscope.keep_me.c"], 2);
        reset_scope("test.mscope.keep_me");
    }
}

//! Process-wide metrics registry: named counters, gauges and log-bucket
//! histograms behind one [`MetricsSnapshot`].
//!
//! Write sites are coarse by design — the instrumented layers publish
//! per-round aggregates (e.g. the whole `MatchingServiceStats` struct
//! once per round), not per-item increments — so a `Mutex<BTreeMap>` per
//! kind is plenty and keeps the code std-only. Every write is gated on
//! [`crate::obs::enabled`]; when telemetry is off the registry is never
//! touched and scheduling behavior cannot depend on it.

use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::obs;
use crate::util::json::Json;
use crate::util::stats::Histogram;

struct Registry {
    counters: Mutex<BTreeMap<&'static str, u64>>,
    gauges: Mutex<BTreeMap<&'static str, f64>>,
    histograms: Mutex<BTreeMap<&'static str, Histogram>>,
}

fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(|| Registry {
        counters: Mutex::new(BTreeMap::new()),
        gauges: Mutex::new(BTreeMap::new()),
        histograms: Mutex::new(BTreeMap::new()),
    })
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Add `delta` to the named monotonic counter. No-op when telemetry is
/// disabled.
pub fn counter_add(name: &'static str, delta: u64) {
    if !obs::enabled() || delta == 0 {
        return;
    }
    *lock(&registry().counters).entry(name).or_insert(0) += delta;
}

/// Set the named gauge to its latest value. No-op when disabled.
pub fn gauge_set(name: &'static str, value: f64) {
    if !obs::enabled() {
        return;
    }
    lock(&registry().gauges).insert(name, value);
}

/// Record one observation into the named histogram. No-op when disabled.
pub fn observe(name: &'static str, value: f64) {
    if !obs::enabled() {
        return;
    }
    lock(&registry().histograms)
        .entry(name)
        .or_insert_with(Histogram::new)
        .record(value);
}

/// Copy the registry's current state. Works regardless of the enabled
/// flag (reading never perturbs anything).
pub fn snapshot() -> MetricsSnapshot {
    let reg = registry();
    MetricsSnapshot {
        counters: lock(&reg.counters)
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect(),
        gauges: lock(&reg.gauges)
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect(),
        histograms: lock(&reg.histograms)
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect(),
    }
}

/// Clear the registry (benches/tests isolating runs).
pub fn reset() {
    let reg = registry();
    lock(&reg.counters).clear();
    lock(&reg.gauges).clear();
    lock(&reg.histograms).clear();
}

/// A point-in-time copy of the registry, serializable into simulator
/// reports, checkpoint cells and `BENCH_*.json` artifacts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

impl MetricsSnapshot {
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Total number of named series (the bench telemetry arm's "metric
    /// count").
    pub fn series_count(&self) -> usize {
        self.counters.len() + self.gauges.len() + self.histograms.len()
    }

    /// What happened since `earlier`: counters subtract (saturating, so a
    /// reset in between degrades to the later value), gauges keep their
    /// latest value, histograms bucket-diff. Series absent from `earlier`
    /// pass through whole.
    pub fn delta_since(&self, earlier: &MetricsSnapshot) -> MetricsSnapshot {
        let counters = self
            .counters
            .iter()
            .map(|(k, &v)| {
                let base = earlier.counters.get(k).copied().unwrap_or(0);
                (k.clone(), v.saturating_sub(base))
            })
            .collect();
        let histograms = self
            .histograms
            .iter()
            .map(|(k, v)| match earlier.histograms.get(k) {
                Some(base) => (k.clone(), v.diff(base)),
                None => (k.clone(), v.clone()),
            })
            .collect();
        MetricsSnapshot {
            counters,
            gauges: self.gauges.clone(),
            histograms,
        }
    }

    /// Serialize as `{counters: {...}, gauges: {...}, histograms:
    /// {name: {count, mean, p50, p95, p99, min, max, sum}}}`.
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v as f64)))
                .collect(),
        );
        let gauges = Json::Obj(
            self.gauges
                .iter()
                .map(|(k, &v)| (k.clone(), Json::num(v)))
                .collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("count", Json::num(h.count() as f64)),
                            ("mean", Json::num(h.mean())),
                            ("p50", Json::num(h.percentile(50.0))),
                            ("p95", Json::num(h.percentile(95.0))),
                            ("p99", Json::num(h.percentile(99.0))),
                            ("min", Json::num(h.min())),
                            ("max", Json::num(h.max())),
                            ("sum", Json::num(h.sum())),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_are_inert_when_disabled() {
        let _guard = obs::enabled_guard(false);
        let before = snapshot();
        counter_add("test.metrics.disabled", 7);
        gauge_set("test.metrics.disabled.g", 1.0);
        observe("test.metrics.disabled.h", 0.5);
        let after = snapshot();
        assert!(!after.counters.contains_key("test.metrics.disabled"));
        assert!(!after.gauges.contains_key("test.metrics.disabled.g"));
        assert!(!after.histograms.contains_key("test.metrics.disabled.h"));
        // Nothing else changed either (we hold the toggle lock, so no
        // concurrent test can be enabled right now).
        assert_eq!(before, after);
    }

    #[test]
    fn counters_gauges_histograms_round_trip() {
        let _guard = obs::enabled_guard(true);
        counter_add("test.metrics.c", 2);
        counter_add("test.metrics.c", 3);
        gauge_set("test.metrics.g", 1.5);
        gauge_set("test.metrics.g", 2.5);
        observe("test.metrics.h", 0.010);
        observe("test.metrics.h", 0.020);
        let snap = snapshot();
        assert!(snap.counters["test.metrics.c"] >= 5);
        assert_eq!(snap.gauges["test.metrics.g"], 2.5);
        let h = &snap.histograms["test.metrics.h"];
        assert!(h.count() >= 2);
        assert!(h.max() >= 0.020);

        let json = snap.to_json();
        let text = json.to_string_compact();
        let parsed = Json::parse(&text).unwrap();
        assert!(parsed
            .get("counters")
            .and_then(|c| c.get("test.metrics.c"))
            .and_then(Json::as_f64)
            .unwrap_or(0.0)
            >= 5.0);
        assert!(parsed
            .get("histograms")
            .and_then(|h| h.get("test.metrics.h"))
            .and_then(|h| h.get("p99"))
            .is_some());
    }

    #[test]
    fn delta_since_subtracts_counters_and_diffs_histograms() {
        let _guard = obs::enabled_guard(true);
        counter_add("test.metrics.delta", 10);
        observe("test.metrics.delta.h", 1.0);
        let base = snapshot();
        counter_add("test.metrics.delta", 4);
        observe("test.metrics.delta.h", 2.0);
        observe("test.metrics.delta.h", 2.0);
        let now = snapshot();
        let d = now.delta_since(&base);
        assert_eq!(d.counters["test.metrics.delta"], 4);
        assert_eq!(d.histograms["test.metrics.delta.h"].count(), 2);
        // A no-change delta is all zeros.
        let z = now.delta_since(&now);
        assert_eq!(z.counters["test.metrics.delta"], 0);
        assert!(z.histograms["test.metrics.delta.h"].is_empty());
    }
}

//! Span recording: RAII guards writing begin/end events into per-thread
//! buffers, a process-wide sink they drain into, and Chrome trace-event
//! JSON export.
//!
//! Data flow: [`SpanGuard::begin`]/`drop` push one completed [`SpanEvent`]
//! into a thread-local buffer (no lock, no syscall). Buffers flush into
//! the global sink when they hit capacity and when their thread exits —
//! worker-pool threads are scoped (`std::thread::scope`), so by the time a
//! pipeline stage returns, every worker event has landed in the sink.
//! [`drain_events`] (called once per round by the pipeline driver) empties
//! the sink plus the calling thread's own buffer, optionally retaining a
//! copy for `--trace-out` export ([`set_retain`] / [`take_trace`]).
//!
//! Everything is bounded: per-thread buffers flush at [`TLS_FLUSH_AT`],
//! the sink and the retained trace stop growing at [`SINK_CAP`] /
//! [`RETAIN_CAP`] (dropped events are counted, never silently lost).

use std::cell::RefCell;
use std::io;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// A structured span argument value.
#[derive(Debug, Clone, PartialEq)]
pub enum ArgValue {
    U64(u64),
    I64(i64),
    F64(f64),
    Bool(bool),
    Str(String),
}

impl From<u64> for ArgValue {
    fn from(v: u64) -> Self {
        ArgValue::U64(v)
    }
}
impl From<usize> for ArgValue {
    fn from(v: usize) -> Self {
        ArgValue::U64(v as u64)
    }
}
impl From<u32> for ArgValue {
    fn from(v: u32) -> Self {
        ArgValue::U64(v as u64)
    }
}
impl From<i64> for ArgValue {
    fn from(v: i64) -> Self {
        ArgValue::I64(v)
    }
}
impl From<i32> for ArgValue {
    fn from(v: i32) -> Self {
        ArgValue::I64(v as i64)
    }
}
impl From<f64> for ArgValue {
    fn from(v: f64) -> Self {
        ArgValue::F64(v)
    }
}
impl From<bool> for ArgValue {
    fn from(v: bool) -> Self {
        ArgValue::Bool(v)
    }
}
impl From<&str> for ArgValue {
    fn from(v: &str) -> Self {
        ArgValue::Str(v.to_string())
    }
}
impl From<String> for ArgValue {
    fn from(v: String) -> Self {
        ArgValue::Str(v)
    }
}

impl ArgValue {
    pub(crate) fn to_json(&self) -> Json {
        match self {
            ArgValue::U64(v) => Json::num(*v as f64),
            ArgValue::I64(v) => Json::num(*v as f64),
            ArgValue::F64(v) => Json::num(*v),
            ArgValue::Bool(v) => Json::Bool(*v),
            ArgValue::Str(v) => Json::str(v),
        }
    }
}

/// One completed span: a named interval on one thread, with structured
/// args. Timestamps are microseconds since the process-wide epoch (first
/// telemetry use), the unit Chrome trace events use natively.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanEvent {
    pub name: &'static str,
    /// Dense process-local thread id (not the OS tid).
    pub tid: u64,
    pub start_us: u64,
    pub dur_us: u64,
    pub args: Vec<(&'static str, ArgValue)>,
}

impl SpanEvent {
    /// Plain serialization for flight-recorder dumps (the Chrome exporter
    /// has its own richer row shape).
    pub fn to_json(&self) -> Json {
        let args = Json::Obj(
            self.args
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("name", Json::str(self.name)),
            ("tid", Json::num(self.tid as f64)),
            ("ts_us", Json::num(self.start_us as f64)),
            ("dur_us", Json::num(self.dur_us as f64)),
            ("args", args),
        ])
    }
}

/// Per-thread buffer size that triggers a flush into the global sink.
pub const TLS_FLUSH_AT: usize = 1024;
/// Sink bound: beyond this many undrained events, new ones are dropped
/// (and counted) rather than growing without limit.
pub const SINK_CAP: usize = 1 << 20;
/// Retained-trace bound for `--trace-out` (≈2M events ≈ a few hundred MB
/// of JSON — far beyond any round count we trace in practice).
pub const RETAIN_CAP: usize = 2 << 20;

static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(0);
static RECORDED: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static RETAIN: AtomicBool = AtomicBool::new(false);

fn sink() -> &'static Mutex<Vec<SpanEvent>> {
    static SINK: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(Vec::new()))
}

fn trace_store() -> &'static Mutex<Vec<SpanEvent>> {
    static TRACE: OnceLock<Mutex<Vec<SpanEvent>>> = OnceLock::new();
    TRACE.get_or_init(|| Mutex::new(Vec::new()))
}

fn thread_names() -> &'static Mutex<Vec<(u64, String)>> {
    static NAMES: OnceLock<Mutex<Vec<(u64, String)>>> = OnceLock::new();
    NAMES.get_or_init(|| Mutex::new(Vec::new()))
}

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Microseconds since the process-wide telemetry epoch.
fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

struct ThreadBuf {
    tid: u64,
    events: Vec<SpanEvent>,
}

impl ThreadBuf {
    fn new() -> ThreadBuf {
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("worker-{tid}"));
        lock(thread_names()).push((tid, name));
        ThreadBuf {
            tid,
            events: Vec::new(),
        }
    }

    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut sink = lock(sink());
        let room = SINK_CAP.saturating_sub(sink.len());
        if room >= self.events.len() {
            sink.append(&mut self.events);
        } else {
            let overflow = self.events.len() - room;
            sink.extend(self.events.drain(..room));
            self.events.clear();
            DROPPED.fetch_add(overflow as u64, Ordering::Relaxed);
        }
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf::new());
}

fn push_event(event: SpanEvent) {
    RECORDED.fetch_add(1, Ordering::Relaxed);
    // Thread teardown can outlive the TLS buffer; drop the event then
    // rather than re-initializing (scoped pool workers flush on exit
    // long before that point).
    let _ = BUF.try_with(|buf| {
        let mut buf = buf.borrow_mut();
        buf.events.push(event);
        if buf.events.len() >= TLS_FLUSH_AT {
            buf.flush();
        }
    });
}

/// RAII span: created by `obs::span!`, records one [`SpanEvent`] covering
/// its lifetime when dropped. Only ever constructed when
/// [`crate::obs::enabled`] — the macro does the gating.
pub struct SpanGuard {
    name: &'static str,
    start_us: u64,
    args: Vec<(&'static str, ArgValue)>,
}

impl SpanGuard {
    pub fn begin(name: &'static str, args: Vec<(&'static str, ArgValue)>) -> SpanGuard {
        SpanGuard {
            name,
            start_us: now_us(),
            args,
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let end = now_us();
        push_event(SpanEvent {
            name: self.name,
            tid: BUF.try_with(|b| b.borrow().tid).unwrap_or(u64::MAX),
            start_us: self.start_us,
            dur_us: end.saturating_sub(self.start_us),
            args: std::mem::take(&mut self.args),
        });
    }
}

/// Total spans recorded since process start (monotonic; survives drains).
/// The bench telemetry arm reports this as its span count.
pub fn recorded_total() -> u64 {
    RECORDED.load(Ordering::Relaxed)
}

/// Spans dropped at the sink/trace caps (0 in healthy runs).
pub fn dropped_total() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// When retain mode is on (the `--trace-out` flag), every drained event
/// is also appended to a process-wide trace for final export.
pub fn set_retain(on: bool) {
    RETAIN.store(on, Ordering::SeqCst);
}

/// Drain all completed spans: the global sink plus the calling thread's
/// own buffer. Worker threads under `std::thread::scope` have exited (and
/// therefore flushed) by the time the pipeline driver calls this, so a
/// per-round drain observes the whole round. Returns events in flush
/// order (grouped by thread, not globally time-sorted — the Chrome
/// exporter doesn't need sorting).
pub fn drain_events() -> Vec<SpanEvent> {
    let mut events = {
        let mut sink = lock(sink());
        std::mem::take(&mut *sink)
    };
    let _ = BUF.try_with(|buf| {
        let mut buf = buf.borrow_mut();
        events.append(&mut buf.events);
    });
    if RETAIN.load(Ordering::Relaxed) && !events.is_empty() {
        let mut trace = lock(trace_store());
        let room = RETAIN_CAP.saturating_sub(trace.len());
        if room < events.len() {
            DROPPED.fetch_add((events.len() - room) as u64, Ordering::Relaxed);
        }
        trace.extend(events.iter().take(room).cloned());
    }
    events
}

/// Take the retained trace accumulated since [`set_retain`]`(true)`.
pub fn take_trace() -> Vec<SpanEvent> {
    std::mem::take(&mut *lock(trace_store()))
}

/// Render events as a Chrome trace-event document (the JSON Object
/// Format: `{"traceEvents": [...]}` with `ph:"X"` complete events and
/// `ph:"M"` thread-name metadata), loadable in Perfetto and
/// `chrome://tracing`.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    let mut rows = Vec::with_capacity(events.len() + 8);
    let mut seen_tids: Vec<u64> = events.iter().map(|e| e.tid).collect();
    seen_tids.sort_unstable();
    seen_tids.dedup();
    {
        let names = lock(thread_names());
        for &tid in &seen_tids {
            let name = names
                .iter()
                .find(|(t, _)| *t == tid)
                .map(|(_, n)| n.clone())
                .unwrap_or_else(|| format!("worker-{tid}"));
            rows.push(Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("args", Json::obj(vec![("name", Json::str(&name))])),
            ]));
        }
    }
    for e in events {
        let args = Json::Obj(
            e.args
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_json()))
                .collect(),
        );
        rows.push(Json::obj(vec![
            ("name", Json::str(e.name)),
            ("cat", Json::str("tesserae")),
            ("ph", Json::str("X")),
            ("ts", Json::num(e.start_us as f64)),
            ("dur", Json::num(e.dur_us as f64)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(e.tid as f64)),
            ("args", args),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::arr(rows)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

/// Write `events` to `path` as Chrome trace-event JSON.
pub fn write_chrome_trace(path: impl AsRef<Path>, events: &[SpanEvent]) -> io::Result<()> {
    std::fs::write(path, chrome_trace_json(events).to_string_compact())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn spans_cross_threads_into_one_drain() {
        let _guard = obs::enabled_guard(true);
        drain_events();
        std::thread::scope(|scope| {
            for i in 0..3u64 {
                scope.spawn(move || {
                    crate::obs_span!("test.worker", { chunk: i });
                });
            }
            crate::obs_span!("test.caller");
        });
        let events = drain_events();
        let workers = events.iter().filter(|e| e.name == "test.worker").count();
        let callers = events.iter().filter(|e| e.name == "test.caller").count();
        assert_eq!(workers, 3, "all scoped-worker spans must flush on exit");
        assert_eq!(callers, 1);
        let tids: std::collections::BTreeSet<u64> = events
            .iter()
            .filter(|e| e.name == "test.worker")
            .map(|e| e.tid)
            .collect();
        assert_eq!(tids.len(), 3, "each worker thread gets its own tid");
    }

    #[test]
    fn chrome_trace_round_trips_as_json() {
        let events = vec![
            SpanEvent {
                name: "round",
                tid: 0,
                start_us: 10,
                dur_us: 500,
                args: vec![("jobs", ArgValue::U64(64)), ("label", ArgValue::from("x"))],
            },
            SpanEvent {
                name: "estimate",
                tid: 0,
                start_us: 12,
                dur_us: 100,
                args: vec![],
            },
        ];
        let doc = chrome_trace_json(&events);
        let text = doc.to_string_compact();
        let parsed = Json::parse(&text).expect("trace must be valid JSON");
        let rows = parsed
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        let round = rows
            .iter()
            .find(|r| r.get("name").and_then(Json::as_str) == Some("round"))
            .expect("round event present");
        assert_eq!(round.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(round.get("dur").and_then(Json::as_f64), Some(500.0));
        assert_eq!(
            round
                .get("args")
                .and_then(|a| a.get("jobs"))
                .and_then(Json::as_f64),
            Some(64.0)
        );
    }

    #[test]
    fn retain_mode_accumulates_for_export() {
        let _guard = obs::enabled_guard(true);
        drain_events();
        take_trace();
        set_retain(true);
        {
            crate::obs_span!("test.retained");
        }
        drain_events();
        set_retain(false);
        let trace = take_trace();
        assert!(
            trace.iter().any(|e| e.name == "test.retained"),
            "retained trace must include drained spans"
        );
    }
}

//! Flight-recorder telemetry: span tracing, a unified metrics registry,
//! and a bounded per-round flight recorder — std-only, zero external
//! dependencies.
//!
//! Three pieces:
//!
//! - **Span tracing** ([`span`]): `obs::span!("lp.repair", {job_window: n})`
//!   opens an RAII guard that records a begin/end pair with structured
//!   key/value args into a per-thread buffer. Completed spans are drained
//!   once per round and exportable as Chrome trace-event JSON
//!   (`--trace-out round.trace.json`, loadable in Perfetto or
//!   `chrome://tracing`), visualizing the full
//!   Estimate→Schedule→Pack→Migrate→Commit timeline including worker-pool
//!   lease/chunk activity.
//! - **Metrics registry** ([`metrics`]): process-wide named counters,
//!   gauges and log-bucket histograms ([`crate::util::stats::Histogram`])
//!   absorbing the scattered per-struct counters behind one
//!   [`MetricsSnapshot`] serialized into simulator reports, fig14b
//!   checkpoint cells and `BENCH_*.json` artifacts.
//! - **Flight recorder** ([`recorder`]): a bounded ring buffer of the last
//!   N rounds' spans + metric deltas, dumped to JSON when a parity or
//!   `validate()` cross-check fails — so failures in 3072-job sweeps come
//!   with evidence attached instead of requiring a rerun.
//!
//! # Determinism contract
//!
//! Telemetry is **off by default** and every recording site is gated on
//! one relaxed atomic load ([`enabled`]). Nothing recorded here ever feeds
//! back into a scheduling decision: spans and metrics are written, never
//! read, on the decision path. Placement plans are bit-identical with
//! telemetry on vs. off (enforced by property test) and the disabled
//! overhead is asserted < 2% in `bench_round_pipeline`'s telemetry arm.
//!
//! The leveled [`logging`] channel (`obs::log!(warn, ...)`,
//! `TESSERAE_LOG=debug`) is independent of [`enabled`]: warnings print
//! even when tracing is off.

pub mod logging;
pub mod metrics;
pub mod recorder;
pub mod span;

// The macros are `#[macro_export]`ed at the crate root (a macro_rules
// limitation); re-export them here so call sites read `obs::span!` /
// `obs::log!`. A macro and the module of the same name coexist — they
// live in different namespaces (the `std::vec` / `vec!` pattern).
pub use crate::obs_log as log;
pub use crate::obs_span as span;
pub use logging::Level;
pub use metrics::MetricsSnapshot;
pub use span::{ArgValue, SpanEvent, SpanGuard};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);
static GUARD_LOCK: OnceLock<Mutex<()>> = OnceLock::new();

/// Whether telemetry recording is on. This is the *only* check on the hot
/// path when telemetry is off: one relaxed load, no fence, no branch
/// beyond the skip.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn telemetry recording on or off process-wide (the `--trace-out`
/// flag and bench arms call this once at startup). Tests that toggle
/// repeatedly must use [`enabled_guard`] instead, which serializes.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::SeqCst);
}

/// Exclusive scoped enable/disable for tests and benches: takes a
/// process-global lock (so concurrent toggles cannot interleave), sets
/// the flag, and restores the previous value when the guard drops —
/// the same pattern as `WorkerPool::budget_override`.
pub fn enabled_guard(on: bool) -> EnabledGuard {
    let lock = GUARD_LOCK
        .get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|poisoned| poisoned.into_inner());
    let prev = ENABLED.swap(on, Ordering::SeqCst);
    EnabledGuard { prev, _lock: lock }
}

/// Guard from [`enabled_guard`]; restores the previous enabled state.
pub struct EnabledGuard {
    prev: bool,
    _lock: MutexGuard<'static, ()>,
}

impl Drop for EnabledGuard {
    fn drop(&mut self) {
        ENABLED.store(self.prev, Ordering::SeqCst);
    }
}

/// Open a telemetry span for the rest of the enclosing scope.
///
/// ```ignore
/// obs::span!("lp.repair");
/// obs::span!("matching.batch", { instances: n, workers: w });
/// ```
///
/// Expands to a `let` of an RAII guard, so the span closes when the
/// scope ends. When telemetry is disabled ([`crate::obs::enabled`] is
/// false) the cost is one relaxed atomic load — no allocation, no clock
/// read. Arg values go through [`crate::obs::ArgValue::from`]
/// (integers, floats, bools, strings).
#[macro_export]
macro_rules! obs_span {
    ($name:expr) => {
        let _obs_span_guard = if $crate::obs::enabled() {
            Some($crate::obs::SpanGuard::begin($name, ::std::vec::Vec::new()))
        } else {
            None
        };
    };
    ($name:expr, { $($key:ident : $val:expr),+ $(,)? }) => {
        let _obs_span_guard = if $crate::obs::enabled() {
            Some($crate::obs::SpanGuard::begin(
                $name,
                ::std::vec![$((stringify!($key), $crate::obs::ArgValue::from($val))),+],
            ))
        } else {
            None
        };
    };
}

/// Leveled logging honoring `TESSERAE_LOG` (error/warn/info/debug;
/// default `warn`, so progress chatter is quiet under `cargo test`).
///
/// ```ignore
/// obs::log!(warn, "fig2 checkpoint write failed: {e}");
/// obs::log!(info, "cell {key} done in {s:.1}s");
/// ```
#[macro_export]
macro_rules! obs_log {
    (error, $($fmt:tt)+) => {
        $crate::obs::logging::log(
            $crate::obs::Level::Error, module_path!(), format_args!($($fmt)+))
    };
    (warn, $($fmt:tt)+) => {
        $crate::obs::logging::log(
            $crate::obs::Level::Warn, module_path!(), format_args!($($fmt)+))
    };
    (info, $($fmt:tt)+) => {
        $crate::obs::logging::log(
            $crate::obs::Level::Info, module_path!(), format_args!($($fmt)+))
    };
    (debug, $($fmt:tt)+) => {
        $crate::obs::logging::log(
            $crate::obs::Level::Debug, module_path!(), format_args!($($fmt)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_sets_and_restores() {
        // Guards must be sequential, never nested: each holds the global
        // toggle lock for its lifetime (that lock is what serializes
        // telemetry tests against each other).
        {
            let _g = enabled_guard(true);
            assert!(enabled());
        }
        {
            let _g = enabled_guard(false);
            assert!(!enabled());
        }
    }

    #[test]
    fn span_macro_is_inert_when_disabled() {
        let _guard = enabled_guard(false);
        {
            crate::obs_span!("test.noop", { items: 3usize });
        }
        // Other test threads may have flushed unrelated events into the
        // sink; only *our* span must be absent.
        let drained = span::drain_events();
        assert!(
            drained.iter().all(|e| e.name != "test.noop"),
            "disabled span must record nothing"
        );
    }

    #[test]
    fn span_macro_records_when_enabled() {
        let _guard = enabled_guard(true);
        span::drain_events(); // discard anything pending from other tests
        {
            crate::obs_span!("test.outer", { items: 3usize, tag: "abc" });
            crate::obs_span!("test.inner");
        }
        let events = span::drain_events();
        let names: Vec<&str> = events.iter().map(|e| e.name).collect();
        assert!(names.contains(&"test.outer"), "got {names:?}");
        assert!(names.contains(&"test.inner"), "got {names:?}");
        let outer = events.iter().find(|e| e.name == "test.outer").unwrap();
        assert_eq!(outer.args.len(), 2);
        assert_eq!(outer.args[0].0, "items");
    }
}

//! Flight recorder: a bounded ring buffer of the last N rounds' spans and
//! metric deltas, dumped to JSON when an invariant check fails.
//!
//! The pipeline driver calls [`record_round`] once per round (only when
//! telemetry is enabled) with the round's drained spans and the metric
//! delta since the previous round. When a parity assert or a plan
//! `validate()` cross-check fails, [`dump_on_failure`] writes everything
//! the recorder holds to `TESSERAE_FLIGHT_OUT` (default
//! `tesserae-flight.json`), so a failure deep inside a 3072-job sweep
//! comes with the evidence attached instead of requiring a rerun.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::obs::metrics::MetricsSnapshot;
use crate::obs::span::SpanEvent;
use crate::util::json::Json;

/// Rounds retained, overridable via `TESSERAE_FLIGHT_ROUNDS`.
pub const DEFAULT_KEEP_ROUNDS: usize = 8;

/// Dump destination env override; default `tesserae-flight.json` in the
/// working directory.
pub const FLIGHT_OUT_ENV: &str = "TESSERAE_FLIGHT_OUT";

/// One recorded round: identity, wall clock, the round's spans, and what
/// the metrics registry accumulated during it.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: u64,
    /// Scheduler / call-site label ("tesserae-t", "sim", ...).
    pub label: String,
    pub total_s: f64,
    pub spans: Vec<SpanEvent>,
    pub metrics_delta: MetricsSnapshot,
}

impl RoundRecord {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("round", Json::num(self.round as f64)),
            ("label", Json::str(&self.label)),
            ("total_s", Json::num(self.total_s)),
            ("metrics_delta", self.metrics_delta.to_json()),
            (
                "spans",
                Json::arr(self.spans.iter().map(SpanEvent::to_json).collect()),
            ),
        ])
    }
}

fn ring() -> &'static Mutex<VecDeque<RoundRecord>> {
    static RING: OnceLock<Mutex<VecDeque<RoundRecord>>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(VecDeque::new()))
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn keep_rounds() -> usize {
    static KEEP: OnceLock<usize> = OnceLock::new();
    *KEEP.get_or_init(|| {
        std::env::var("TESSERAE_FLIGHT_ROUNDS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or(DEFAULT_KEEP_ROUNDS)
    })
}

/// Append one round, evicting the oldest beyond the retention window.
pub fn record_round(record: RoundRecord) {
    let mut ring = lock(ring());
    while ring.len() >= keep_rounds() {
        ring.pop_front();
    }
    ring.push_back(record);
}

/// Rounds currently held (tests / bench reporting).
pub fn rounds_recorded() -> usize {
    lock(ring()).len()
}

/// The most recently recorded round, if any (tests / report embedding).
pub fn latest_round() -> Option<RoundRecord> {
    lock(ring()).back().cloned()
}

/// All held rounds, oldest first (tests / report embedding).
pub fn rounds() -> Vec<RoundRecord> {
    lock(ring()).iter().cloned().collect()
}

/// Drop everything held (benches/tests isolating runs).
pub fn clear() {
    lock(ring()).clear();
}

/// Serialize the recorder's current contents.
pub fn to_json(context: &str) -> Json {
    let ring = lock(ring());
    Json::obj(vec![
        ("context", Json::str(context)),
        ("rounds_held", Json::num(ring.len() as f64)),
        (
            "rounds",
            Json::arr(ring.iter().map(RoundRecord::to_json).collect()),
        ),
    ])
}

/// Dump the flight record because an invariant failed. Returns the path
/// written, or `None` when there is nothing recorded (telemetry off) or
/// the write itself failed — the caller's panic must proceed regardless,
/// so this never returns an error.
pub fn dump_on_failure(context: &str) -> Option<PathBuf> {
    let path = PathBuf::from(
        std::env::var(FLIGHT_OUT_ENV).unwrap_or_else(|_| "tesserae-flight.json".to_string()),
    );
    dump_to(path, context)
}

/// As [`dump_on_failure`] but to an explicit path (tests, embedders).
/// Missing parent directories are created — `TESSERAE_FLIGHT_OUT` often
/// points into a per-run artifact directory that doesn't exist yet when
/// the failure fires.
pub fn dump_to(path: PathBuf, context: &str) -> Option<PathBuf> {
    if lock(ring()).is_empty() {
        return None;
    }
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            if let Err(e) = std::fs::create_dir_all(parent) {
                crate::obs_log!(
                    error,
                    "flight-record dump: could not create {}: {e}",
                    parent.display()
                );
                return None;
            }
        }
    }
    let doc = to_json(context);
    match std::fs::write(&path, doc.to_string_pretty()) {
        Ok(()) => {
            crate::obs_log!(
                error,
                "invariant failed ({context}); flight record of last {} rounds dumped to {}",
                rounds_recorded(),
                path.display()
            );
            Some(path)
        }
        Err(e) => {
            crate::obs_log!(error, "flight-record dump to {} failed: {e}", path.display());
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::ArgValue;

    fn record(round: u64) -> RoundRecord {
        RoundRecord {
            round,
            label: "test".to_string(),
            total_s: 0.001 * round as f64,
            spans: vec![SpanEvent {
                name: "estimate",
                tid: 0,
                start_us: 10 * round,
                dur_us: 5,
                args: vec![("jobs", ArgValue::U64(round))],
            }],
            metrics_delta: MetricsSnapshot::default(),
        }
    }

    #[test]
    fn ring_is_bounded_and_keeps_latest() {
        // The guard's global lock serializes these tests against each
        // other and against anything that records rounds while enabled.
        let _g = crate::obs::enabled_guard(false);
        clear();
        for r in 0..(DEFAULT_KEEP_ROUNDS as u64 + 5) {
            record_round(record(r));
        }
        assert_eq!(rounds_recorded(), keep_rounds().min(DEFAULT_KEEP_ROUNDS + 5));
        let doc = to_json("test");
        let rounds = doc.get("rounds").and_then(Json::as_arr).unwrap();
        let last = rounds.last().unwrap();
        assert_eq!(
            last.get("round").and_then(Json::as_f64),
            Some((DEFAULT_KEEP_ROUNDS + 4) as f64)
        );
        // Serialized spans carry their args through.
        assert!(doc
            .to_string_compact()
            .contains("\"name\":\"estimate\""));
        clear();
    }

    #[test]
    fn dump_on_failure_writes_a_parsable_file() {
        let _g = crate::obs::enabled_guard(false);
        let dir = std::env::temp_dir();
        let path = dir.join(format!("tesserae_flight_test_{}.json", std::process::id()));
        clear();
        record_round(record(3));
        let written = dump_to(path, "unit-test parity mismatch");
        let written = written.expect("dump path");
        let text = std::fs::read_to_string(&written).unwrap();
        let doc = Json::parse(&text).expect("flight dump must be valid JSON");
        assert_eq!(
            doc.get("context").and_then(Json::as_str),
            Some("unit-test parity mismatch")
        );
        assert!(doc.get("rounds").and_then(Json::as_arr).unwrap().len() == 1);
        let _ = std::fs::remove_file(&written);
        clear();
    }

    #[test]
    fn dump_to_creates_missing_parent_directories() {
        let _g = crate::obs::enabled_guard(false);
        let dir = std::env::temp_dir().join(format!(
            "tesserae_flight_nested_{}/deep/run-7",
            std::process::id()
        ));
        let path = dir.join("flight.json");
        // Start from a clean slate so create_dir_all really has to work.
        let _ = std::fs::remove_dir_all(
            std::env::temp_dir().join(format!("tesserae_flight_nested_{}", std::process::id())),
        );
        clear();
        record_round(record(1));
        let written = dump_to(path.clone(), "nested-dir dump").expect("dump path");
        assert_eq!(written, path);
        let text = std::fs::read_to_string(&written).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(
            std::env::temp_dir().join(format!("tesserae_flight_nested_{}", std::process::id())),
        );
        clear();
    }

    #[test]
    fn empty_recorder_dumps_nothing() {
        let _g = crate::obs::enabled_guard(false);
        clear();
        assert!(dump_on_failure("nothing recorded").is_none());
    }
}

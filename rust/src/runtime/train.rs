//! Train-step execution: the real compute behind the coordinator's jobs.
//!
//! A [`TrainSession`] owns a job's parameter state (as raw `f32` buffers —
//! the portable form that crosses worker threads and doubles as the
//! checkpoint format whose size migration costs are measured on) and the
//! compiled `init` / `train_step` executables for its model size.

use anyhow::{anyhow, Result};

use crate::util::json::Json;
use crate::util::rng::Pcg64;

use super::{execute_tuple, literal_f32, literal_i32, Runtime};

/// Static description of one exported model size (from the manifest).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub num_params: usize,
    /// Per-tensor shapes, in ABI order.
    pub param_shapes: Vec<Vec<usize>>,
    pub init_file: String,
    pub train_step_file: String,
}

impl ModelSpec {
    pub fn from_manifest(entry: &Json) -> Result<ModelSpec> {
        let cfg = entry.require("config").map_err(|e| anyhow!("{e}"))?;
        let get = |v: &Json, k: &str| -> Result<usize> {
            v.require(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_usize()
                .ok_or_else(|| anyhow!("{k} must be an integer"))
        };
        let param_shapes = entry
            .require("param_specs")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("param_specs must be an array"))?
            .iter()
            .map(|s| {
                Ok(s.require("shape")
                    .map_err(|e| anyhow!("{e}"))?
                    .as_arr()
                    .ok_or_else(|| anyhow!("shape must be an array"))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect())
            })
            .collect::<Result<Vec<Vec<usize>>>>()?;
        let s = |k: &str| -> Result<String> {
            Ok(entry
                .require(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("{k} must be a string"))?
                .to_string())
        };
        Ok(ModelSpec {
            name: cfg
                .require("name")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .unwrap_or("?")
                .to_string(),
            vocab: get(cfg, "vocab")?,
            seq_len: get(cfg, "seq_len")?,
            batch: get(cfg, "batch")?,
            num_params: get(entry, "num_params")?,
            param_shapes,
            init_file: s("init_file")?,
            train_step_file: s("train_step_file")?,
        })
    }

    /// Total checkpoint size in bytes (f32 params).
    pub fn checkpoint_bytes(&self) -> usize {
        self.num_params * 4
    }
}

/// A job's portable parameter state.
#[derive(Debug, Clone)]
pub struct ParamState {
    /// One flat f32 buffer per parameter tensor, ABI order.
    pub tensors: Vec<Vec<f32>>,
}

impl ParamState {
    /// Element-wise average of replica states (the coordinator's
    /// round-granular data-parallel reduction).
    pub fn average(replicas: &[ParamState]) -> ParamState {
        assert!(!replicas.is_empty());
        let mut out = replicas[0].clone();
        for r in &replicas[1..] {
            for (o, t) in out.tensors.iter_mut().zip(&r.tensors) {
                for (a, b) in o.iter_mut().zip(t) {
                    *a += *b;
                }
            }
        }
        let k = replicas.len() as f32;
        for t in &mut out.tensors {
            for a in t {
                *a /= k;
            }
        }
        out
    }
}

/// Compiled executables + helpers for one model size (thread-local).
pub struct TrainSession {
    pub spec: ModelSpec,
    init_exe: xla::PjRtLoadedExecutable,
    step_exe: xla::PjRtLoadedExecutable,
}

impl TrainSession {
    pub fn load(rt: &Runtime, model_name: &str) -> Result<TrainSession> {
        let entry = rt.manifest.artifact(&format!("model_{model_name}"))?;
        let spec = ModelSpec::from_manifest(entry)?;
        Ok(TrainSession {
            init_exe: rt.compile_file(&spec.init_file)?,
            step_exe: rt.compile_file(&spec.train_step_file)?,
            spec,
        })
    }

    /// Run the AOT `init` computation.
    pub fn init_params(&self, seed: i32) -> Result<ParamState> {
        let outs = execute_tuple(&self.init_exe, &[xla::Literal::scalar(seed)])?;
        let tensors = outs
            .iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow!("param read: {e:?}")))
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamState { tensors })
    }

    /// One SGD step on a token batch; returns the loss.
    pub fn step(&self, params: &mut ParamState, tokens: &[i32]) -> Result<f32> {
        let want = self.spec.batch * (self.spec.seq_len + 1);
        if tokens.len() != want {
            return Err(anyhow!("token batch {} != {}", tokens.len(), want));
        }
        let mut inputs = Vec::with_capacity(params.tensors.len() + 1);
        for (t, shape) in params.tensors.iter().zip(&self.spec.param_shapes) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            inputs.push(literal_f32(t, &dims)?);
        }
        inputs.push(literal_i32(
            tokens,
            &[self.spec.batch as i64, (self.spec.seq_len + 1) as i64],
        )?);
        let outs = execute_tuple(&self.step_exe, &inputs)?;
        if outs.len() != params.tensors.len() + 1 {
            return Err(anyhow!(
                "train_step returned {} outputs, expected {}",
                outs.len(),
                params.tensors.len() + 1
            ));
        }
        for (t, l) in params.tensors.iter_mut().zip(&outs[..outs.len() - 1]) {
            *t = l.to_vec::<f32>().map_err(|e| anyhow!("param read: {e:?}"))?;
        }
        let loss = outs[outs.len() - 1]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss read: {e:?}"))?[0];
        Ok(loss)
    }

    /// Synthetic learnable batch matching `model.synthetic_batch`: an
    /// affine next-token chain `x' = (5x + 1) mod V` with 10% corruption.
    pub fn synthetic_batch(&self, rng: &mut Pcg64) -> Vec<i32> {
        let v = self.spec.vocab as i64;
        let mut out = Vec::with_capacity(self.spec.batch * (self.spec.seq_len + 1));
        for _ in 0..self.spec.batch {
            let mut x = rng.below(v as u64) as i64;
            out.push(x as i32);
            for _ in 0..self.spec.seq_len {
                x = (5 * x + 1) % v;
                let tok = if rng.f64() < 0.1 {
                    rng.below(v as u64) as i64
                } else {
                    x
                };
                out.push(tok as i32);
            }
        }
        out
    }
}

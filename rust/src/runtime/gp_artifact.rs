//! The AOT GP-posterior artifact: the numeric core of the Bayesian-
//! optimization throughput estimator, executed through PJRT. Shapes are
//! fixed (N_MAX padded observations, 64 queries, 7 features); hyper-
//! parameters match `estimator/gp.rs` so the native GP is a drop-in
//! correctness oracle.

use anyhow::{anyhow, Result};

use super::{execute_tuple, literal_f32, Runtime};

/// Handle to the compiled GP artifact (thread-local; not `Send`).
pub struct GpArtifact {
    exe: xla::PjRtLoadedExecutable,
    pub n_max: usize,
    pub dim: usize,
    pub num_queries: usize,
}

impl GpArtifact {
    pub fn load(rt: &Runtime) -> Result<GpArtifact> {
        let entry = rt.manifest.artifact("gp")?;
        let file = entry
            .require("file")
            .map_err(|e| anyhow!("{e}"))?
            .as_str()
            .ok_or_else(|| anyhow!("gp file must be a string"))?;
        let n_max = entry
            .require("n_max")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("n_max must be an integer"))?;
        let dim = entry
            .require("dim")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("dim must be an integer"))?;
        let num_queries = entry
            .require("num_queries")
            .map_err(|e| anyhow!("{e}"))?
            .as_usize()
            .ok_or_else(|| anyhow!("num_queries must be an integer"))?;
        Ok(GpArtifact {
            exe: rt.compile_file(file)?,
            n_max,
            dim,
            num_queries,
        })
    }

    /// Posterior mean/variance at `queries` given `observations`.
    /// Observations beyond `n_max` are rejected; queries are processed in
    /// chunks of the artifact's fixed query batch (padded with zeros).
    pub fn posterior(
        &self,
        observations: &[(Vec<f64>, f64)],
        queries: &[Vec<f64>],
    ) -> Result<Vec<(f64, f64)>> {
        if observations.is_empty() {
            return Err(anyhow!("GP needs at least one observation"));
        }
        if observations.len() > self.n_max {
            return Err(anyhow!(
                "{} observations exceed the artifact's N_MAX={}",
                observations.len(),
                self.n_max
            ));
        }
        // Pack padded observation tensors.
        let mut x = vec![0.0f32; self.n_max * self.dim];
        let mut y = vec![0.0f32; self.n_max];
        let mut mask = vec![0.0f32; self.n_max];
        for (i, (feat, val)) in observations.iter().enumerate() {
            if feat.len() != self.dim {
                return Err(anyhow!("feature dim {} != {}", feat.len(), self.dim));
            }
            for (j, f) in feat.iter().enumerate() {
                x[i * self.dim + j] = *f as f32;
            }
            y[i] = *val as f32;
            mask[i] = 1.0;
        }

        let mut out = Vec::with_capacity(queries.len());
        for chunk in queries.chunks(self.num_queries) {
            let mut xq = vec![0.0f32; self.num_queries * self.dim];
            for (i, q) in chunk.iter().enumerate() {
                if q.len() != self.dim {
                    return Err(anyhow!("query dim {} != {}", q.len(), self.dim));
                }
                for (j, f) in q.iter().enumerate() {
                    xq[i * self.dim + j] = *f as f32;
                }
            }
            let outs = execute_tuple(
                &self.exe,
                &[
                    literal_f32(&x, &[self.n_max as i64, self.dim as i64])?,
                    literal_f32(&y, &[self.n_max as i64])?,
                    literal_f32(&mask, &[self.n_max as i64])?,
                    literal_f32(&xq, &[self.num_queries as i64, self.dim as i64])?,
                ],
            )?;
            let mean = outs[0]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("mean read: {e:?}"))?;
            let var = outs[1]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("var read: {e:?}"))?;
            for i in 0..chunk.len() {
                out.push((mean[i] as f64, var[i] as f64));
            }
        }
        Ok(out)
    }
}

//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them from the L3 hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! The `xla` crate's client is `Rc`-based (not `Send`), so anything that
//! must be shared across threads (the [`assignment::AotAssignmentEngine`],
//! the coordinator's workers) owns its client on a dedicated thread and
//! speaks over channels.

pub mod assignment;
pub mod gp_artifact;
pub mod train;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

pub use assignment::AotAssignmentEngine;
pub use gp_artifact::GpArtifact;
pub use train::{ModelSpec, TrainSession};

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

/// Parsed `manifest.json` plus the artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    root: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            root,
        })
    }

    /// Locate the artifacts directory: `$TESSERAE_ARTIFACTS`, ./artifacts,
    /// or ../artifacts (tests run from the crate root).
    pub fn discover() -> Result<Manifest> {
        let candidates = [
            std::env::var("TESSERAE_ARTIFACTS").unwrap_or_default(),
            DEFAULT_ARTIFACTS_DIR.to_string(),
            format!("../{DEFAULT_ARTIFACTS_DIR}"),
        ];
        for c in candidates.iter().filter(|c| !c.is_empty()) {
            let dir = Path::new(c);
            if dir.join("manifest.json").exists() {
                return Manifest::load(dir);
            }
        }
        Err(anyhow!(
            "artifacts/manifest.json not found; run `make artifacts`"
        ))
    }

    pub fn artifact(&self, name: &str) -> Result<&Json> {
        self.root
            .require("artifacts")
            .map_err(|e| anyhow!("{e}"))?
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' missing from manifest"))
    }

    pub fn artifact_names(&self) -> Vec<String> {
        self.root
            .get("artifacts")
            .and_then(|a| a.as_obj())
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }

    pub fn file_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// A thread-local PJRT CPU runtime: compiles HLO-text files on demand.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(manifest: Manifest) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime { client, manifest })
    }

    pub fn discover() -> Result<Runtime> {
        Runtime::new(Manifest::discover()?)
    }

    /// Compile an HLO-text artifact file into a loaded executable.
    pub fn compile_file(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.file_path(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {file}: {e:?}"))
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    if expected != data.len() as i64 {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    if expected != data.len() as i64 {
        return Err(anyhow!("literal shape {dims:?} != data len {}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Execute and unpack the single tuple output of an AOT module.
pub fn execute_tuple(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let outs = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow!("execute: {e:?}"))?;
    let lit = outs[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal: {e:?}"))?;
    lit.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))
}

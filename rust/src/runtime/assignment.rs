//! The AOT assignment engine: Tesserae's matching problems solved by the
//! JAX/Pallas auction artifact through PJRT.
//!
//! A dedicated solver thread owns the (non-`Send`) PJRT client and the
//! size-bucketed executables; [`AotAssignmentEngine`] is a thin `Send +
//! Sync` handle that implements [`MatchingEngine`] by round-tripping cost
//! matrices over channels. Problems are padded into the smallest bucket
//! n ∈ {8,…,256}: dummy rows/columns carry benefit 0 against each other
//! and −BIG against real nodes, which preserves the optimum on the real
//! block.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::linalg::Matrix;
use crate::matching::{AssignmentResult, MatchingEngine};

use super::{execute_tuple, literal_f32, Manifest, Runtime};

/// Sizes the AOT artifacts were exported at (must match `aot.py`).
pub const BUCKETS: [usize; 6] = [8, 16, 32, 64, 128, 256];

struct Request {
    /// Benefit matrix, padded to a bucket size, row-major.
    benefit: Vec<f32>,
    n: usize,
    eps_final: f32,
    reply: Sender<Result<Vec<i32>>>,
}

/// `Send + Sync` handle to the solver thread.
pub struct AotAssignmentEngine {
    tx: Mutex<Sender<Request>>,
    /// ε target resolution for exactness on quantized costs.
    pub resolution: f64,
}

impl AotAssignmentEngine {
    /// Spawn the solver thread and compile every bucket.
    pub fn start(manifest: Manifest) -> Result<AotAssignmentEngine> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::Builder::new()
            .name("aot-assignment".into())
            .spawn(move || {
                let setup = (|| -> Result<BTreeMap<usize, xla::PjRtLoadedExecutable>> {
                    let rt = Runtime::new(manifest)?;
                    let mut exes = BTreeMap::new();
                    for n in BUCKETS {
                        let entry = rt.manifest.artifact(&format!("assignment_{n}"))?;
                        let file = entry
                            .require("file")
                            .map_err(|e| anyhow!("{e}"))?
                            .as_str()
                            .ok_or_else(|| anyhow!("file must be a string"))?;
                        exes.insert(n, rt.compile_file(file)?);
                    }
                    Ok(exes)
                })();
                let exes = match setup {
                    Ok(exes) => {
                        let _ = ready_tx.send(Ok(()));
                        exes
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    let result = solve_on_device(&exes, &req);
                    let _ = req.reply.send(result);
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("solver thread died during setup"))??;
        Ok(AotAssignmentEngine {
            tx: Mutex::new(tx),
            resolution: 1.0 / 16.0,
        })
    }

    /// Convenience: discover artifacts and start.
    pub fn discover() -> Result<AotAssignmentEngine> {
        AotAssignmentEngine::start(Manifest::discover()?)
    }
}

fn solve_on_device(
    exes: &BTreeMap<usize, xla::PjRtLoadedExecutable>,
    req: &Request,
) -> Result<Vec<i32>> {
    let exe = exes
        .get(&req.n)
        .ok_or_else(|| anyhow!("no artifact bucket for n={}", req.n))?;
    let n = req.n as i64;
    let benefit = literal_f32(&req.benefit, &[n, n])?;
    let eps = xla::Literal::scalar(req.eps_final);
    let outs = execute_tuple(exe, &[benefit, eps])?;
    let assignment = outs[0]
        .to_vec::<i32>()
        .map_err(|e| anyhow!("assignment read: {e:?}"))?;
    Ok(assignment)
}

impl MatchingEngine for AotAssignmentEngine {
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult {
        let n = cost.rows();
        assert_eq!(n, cost.cols(), "assignment needs a square matrix");
        if n == 0 {
            return AssignmentResult {
                row_to_col: vec![],
                cost: 0.0,
            };
        }
        let bucket = BUCKETS
            .iter()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| panic!("problem size {n} exceeds the largest AOT bucket"));

        // Benefit = -cost on the real block; dummy rows/cols pair with each
        // other at 0 and are forbidden (-BIG) against real nodes.
        let max_abs = cost
            .data()
            .iter()
            .fold(0.0f64, |acc, &x| acc.max(x.abs()))
            .max(1.0);
        let big = (max_abs * (bucket as f64 + 1.0)) as f32;
        let mut benefit = vec![0.0f32; bucket * bucket];
        for r in 0..bucket {
            for c in 0..bucket {
                let v = if r < n && c < n {
                    -cost.get(r, c) as f32
                } else if r >= n && c >= n {
                    0.0
                } else {
                    -big
                };
                benefit[r * bucket + c] = v;
            }
        }
        let eps_final = (self.resolution / (bucket as f64 + 1.0)) as f32;

        let (reply_tx, reply_rx) = channel();
        self.tx
            .lock()
            .expect("solver mutex poisoned")
            .send(Request {
                benefit,
                n: bucket,
                eps_final,
                reply: reply_tx,
            })
            .expect("solver thread gone");
        let assignment = reply_rx
            .recv()
            .expect("solver thread dropped reply")
            .expect("aot solve failed");

        let row_to_col: Vec<usize> = assignment[..n].iter().map(|&c| c as usize).collect();
        // Guard: the real block must map within itself.
        debug_assert!(row_to_col.iter().all(|&c| c < n), "padding leaked: {row_to_col:?}");
        let total = row_to_col
            .iter()
            .enumerate()
            .map(|(r, &c)| cost.get(r, c.min(n - 1)))
            .sum();
        AssignmentResult {
            row_to_col,
            cost: total,
        }
    }

    fn name(&self) -> &'static str {
        "aot-auction"
    }
}

//! Crash recovery for the always-on scheduler service (ISSUE 10): three
//! coordinated layers that keep a long-lived scheduler process useful
//! across crashes, hangs and persistently failing providers.
//!
//! - [`snapshot`]: crash-consistent, generation-numbered JSON snapshots
//!   of the simulator's hard state (committed plan, cursors, counters,
//!   scheduler stickiness). Soft state — `LpCache`, matching caches —
//!   is deliberately excluded and rebuilt cold on restore; cold-vs-warm
//!   bit-parity is already property-tested, which is what makes
//!   kill-and-restore bit-identical.
//! - [`watchdog`]: a cooperative per-stage deadline. A hung (as opposed
//!   to panicking) stage trips a typed [`watchdog::DeadlineExceeded`]
//!   panic at the next checkpoint, which the pipeline's catch-unwind
//!   converts into a degraded round with reason `deadline`.
//! - [`breaker`]: a circuit breaker over consecutive degraded rounds —
//!   trip, serve a greedy fallback for a cooldown window, half-open
//!   probe, close. Embedded per shard by `sharding::ShardedCoordinator`.

pub mod breaker;
pub mod snapshot;
pub mod watchdog;

pub use breaker::{BreakerConfig, BreakerScheduler, BreakerState, CircuitBreaker};
pub use snapshot::{SnapshotStore, RETAIN_GENERATIONS, SNAPSHOT_VERSION};
pub use watchdog::{DeadlineExceeded, StageGuard};

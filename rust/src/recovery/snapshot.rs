//! Crash-consistent scheduler-state snapshots.
//!
//! [`SnapshotStore`] persists one generation-numbered JSON document per
//! snapshotted round (`snapshot-<round>.json`, zero-padded so plain
//! directory order is generation order), written with the
//! write-temp / fsync / rename discipline from `util::checkpoint` so a
//! crash or power loss can never surface a zero-length or torn file. The
//! last two generations are retained: if the newest is corrupt (torn
//! rename is impossible, but disks lie), [`SnapshotStore::latest`] falls
//! back to its predecessor.
//!
//! The document *contents* are produced and consumed by the simulator's
//! snapshot codec — the store only guarantees durability, generation
//! ordering and corruption fallback.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::obs::metrics;
use crate::util::checkpoint::durable_write;
use crate::util::json::Json;

/// Bumped whenever the snapshot document shape changes incompatibly;
/// restore refuses mismatched versions rather than misreading them.
pub const SNAPSHOT_VERSION: u64 = 1;

/// Generations kept on disk (newest first); older ones are pruned after
/// each successful write.
pub const RETAIN_GENERATIONS: usize = 2;

/// A directory of generation-numbered snapshot documents.
pub struct SnapshotStore {
    dir: PathBuf,
}

impl SnapshotStore {
    /// Opens (creating if needed) the snapshot directory.
    pub fn new(dir: &Path) -> io::Result<SnapshotStore> {
        fs::create_dir_all(dir)?;
        Ok(SnapshotStore {
            dir: dir.to_path_buf(),
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path_for(&self, round: u64) -> PathBuf {
        self.dir.join(format!("snapshot-{round:08}.json"))
    }

    /// Durably write the snapshot for `round`, then prune generations
    /// beyond [`RETAIN_GENERATIONS`].
    pub fn write(&self, round: u64, doc: &Json) -> io::Result<PathBuf> {
        let path = self.path_for(round);
        durable_write(&path, &doc.to_string_pretty())?;
        metrics::counter_add("snapshot.writes", 1);
        self.prune();
        Ok(path)
    }

    /// Snapshot rounds present on disk, ascending.
    pub fn generations(&self) -> Vec<u64> {
        let mut rounds: Vec<u64> = match fs::read_dir(&self.dir) {
            Ok(entries) => entries
                .filter_map(|e| e.ok())
                .filter_map(|e| parse_round(&e.file_name().to_string_lossy()))
                .collect(),
            Err(_) => Vec::new(),
        };
        rounds.sort_unstable();
        rounds
    }

    /// The newest parseable snapshot, as `(round, document)`. Skips
    /// corrupt generations (unparseable JSON, wrong version) with a
    /// warning rather than failing the restore outright.
    pub fn latest(&self) -> Option<(u64, Json)> {
        for round in self.generations().into_iter().rev() {
            let path = self.path_for(round);
            match fs::read_to_string(&path).ok().and_then(|text| {
                let doc = Json::parse(&text).ok()?;
                let version = doc.get("version").and_then(Json::as_f64)? as u64;
                (version == SNAPSHOT_VERSION).then_some(doc)
            }) {
                Some(doc) => return Some((round, doc)),
                None => {
                    crate::obs_log!(
                        warn,
                        "skipping corrupt or incompatible snapshot {}",
                        path.display()
                    );
                }
            }
        }
        None
    }

    /// Best-effort removal of generations beyond the retention window.
    fn prune(&self) {
        let rounds = self.generations();
        if rounds.len() > RETAIN_GENERATIONS {
            for &round in &rounds[..rounds.len() - RETAIN_GENERATIONS] {
                let _ = fs::remove_file(self.path_for(round));
            }
        }
    }
}

fn parse_round(name: &str) -> Option<u64> {
    name.strip_prefix("snapshot-")?
        .strip_suffix(".json")?
        .parse()
        .ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tesserae-snap-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn doc(round: u64) -> Json {
        Json::obj(vec![
            ("version", Json::num(SNAPSHOT_VERSION as f64)),
            ("round", Json::num(round as f64)),
        ])
    }

    #[test]
    fn retains_last_two_generations_and_reads_newest() {
        let dir = tmp_dir("retain");
        let store = SnapshotStore::new(&dir).unwrap();
        for round in [2, 4, 6, 8] {
            store.write(round, &doc(round)).unwrap();
        }
        assert_eq!(store.generations(), vec![6, 8], "older generations pruned");
        let (round, loaded) = store.latest().expect("latest parses");
        assert_eq!(round, 8);
        assert_eq!(loaded.get("round").and_then(Json::as_f64), Some(8.0));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_newest_falls_back_to_predecessor() {
        let dir = tmp_dir("corrupt");
        let store = SnapshotStore::new(&dir).unwrap();
        store.write(3, &doc(3)).unwrap();
        store.write(5, &doc(5)).unwrap();
        fs::write(dir.join("snapshot-00000005.json"), "{ torn").unwrap();
        let (round, _) = store.latest().expect("falls back");
        assert_eq!(round, 3);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_mismatch_is_skipped() {
        let dir = tmp_dir("version");
        let store = SnapshotStore::new(&dir).unwrap();
        store.write(1, &doc(1)).unwrap();
        let stale = Json::obj(vec![
            ("version", Json::num(999.0)),
            ("round", Json::num(7.0)),
        ]);
        store.write(7, &stale).unwrap();
        let (round, _) = store.latest().expect("falls back past bad version");
        assert_eq!(round, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_store_has_no_latest() {
        let dir = tmp_dir("empty");
        let store = SnapshotStore::new(&dir).unwrap();
        assert!(store.latest().is_none());
        let _ = fs::remove_dir_all(&dir);
    }
}

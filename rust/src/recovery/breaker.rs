//! Degraded-round circuit breaker.
//!
//! A provider that fails every round would otherwise degrade silently
//! forever (the catch-unwind fallback keeps replaying the previous plan).
//! The breaker counts *consecutive* degraded rounds; at `trip_after` it
//! opens and a fallback greedy placer serves the next `cooldown_rounds`
//! rounds, after which one half-open probe round goes back to the real
//! provider — a clean probe closes the breaker, a degraded probe re-opens
//! it for another cooldown.
//!
//! [`BreakerScheduler`] wraps any [`Scheduler`] with this state machine;
//! `sharding::ShardedCoordinator` embeds one [`CircuitBreaker`] per shard
//! so a single flaky shard cannot thrash the whole cluster.

use std::collections::BTreeSet;
use std::time::Instant;

use crate::cluster::PlacementPlan;
use crate::obs::metrics;
use crate::schedulers::{DecisionTimings, RoundDecision, RoundInput, Scheduler};
use crate::util::json::Json;

/// Breaker tuning. The defaults trip after 3 consecutive degraded rounds
/// and serve 5 fallback rounds before the half-open probe.
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Consecutive degraded rounds that open the breaker.
    pub trip_after: u32,
    /// Rounds served by the fallback policy while open.
    pub cooldown_rounds: u64,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            trip_after: 3,
            cooldown_rounds: 5,
        }
    }
}

/// Closed → Open(cooldown) → HalfOpen probe → Closed / re-Open.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    /// Fallback rounds while `round < until_round`.
    Open { until_round: u64 },
    /// The next real-provider round decides: clean closes, degraded
    /// re-opens.
    HalfOpen,
}

/// The trip/cooldown/probe state machine. Deterministic: transitions
/// depend only on round numbers and degraded flags, never on wall time.
#[derive(Debug, Clone)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    /// Consecutive degraded rounds while closed.
    streak: u32,
    /// Lifetime trip count (for metrics / snapshots).
    trips: u64,
    /// Lifetime rounds served by the fallback.
    fallback_rounds: u64,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> CircuitBreaker {
        CircuitBreaker {
            cfg,
            state: BreakerState::Closed,
            streak: 0,
            trips: 0,
            fallback_rounds: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn trips(&self) -> u64 {
        self.trips
    }

    pub fn streak(&self) -> u32 {
        self.streak
    }

    /// Called *before* deciding round `round`: `true` means serve the
    /// fallback policy this round. An expired cooldown transitions to
    /// half-open and lets the real provider probe.
    pub fn use_fallback(&mut self, round: u64) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => false,
            BreakerState::Open { until_round } => {
                if round >= until_round {
                    self.state = BreakerState::HalfOpen;
                    false
                } else {
                    self.fallback_rounds += 1;
                    true
                }
            }
        }
    }

    /// Called *after* a real-provider round with its degraded flag.
    /// Must not be called for fallback rounds (`use_fallback` returned
    /// true).
    pub fn record(&mut self, round: u64, degraded: bool) {
        match self.state {
            BreakerState::Closed => {
                if degraded {
                    self.streak += 1;
                    if self.streak >= self.cfg.trip_after {
                        self.trip(round);
                    }
                } else {
                    self.streak = 0;
                }
            }
            BreakerState::HalfOpen => {
                if degraded {
                    self.trip(round);
                } else {
                    self.state = BreakerState::Closed;
                    self.streak = 0;
                }
            }
            // Fallback rounds bypass record(); nothing to count.
            BreakerState::Open { .. } => {}
        }
    }

    fn trip(&mut self, round: u64) {
        // Cooldown covers the next `cooldown_rounds` rounds; the round
        // after that is the half-open probe.
        self.state = BreakerState::Open {
            until_round: round + 1 + self.cfg.cooldown_rounds,
        };
        self.streak = 0;
        self.trips += 1;
        metrics::counter_add("breaker.trips", 1);
        crate::obs_log!(
            warn,
            "breaker tripped at round {round}: serving fallback for {} rounds",
            self.cfg.cooldown_rounds
        );
    }

    pub fn to_json(&self) -> Json {
        let (state, until) = match self.state {
            BreakerState::Closed => ("closed", 0),
            BreakerState::Open { until_round } => ("open", until_round),
            BreakerState::HalfOpen => ("half_open", 0),
        };
        Json::obj(vec![
            ("state", Json::str(state)),
            ("until_round", Json::num(until as f64)),
            ("streak", Json::num(self.streak as f64)),
            ("trips", Json::num(self.trips as f64)),
            ("fallback_rounds", Json::num(self.fallback_rounds as f64)),
        ])
    }

    /// Rebuild from [`to_json`] output; `cfg` is supplied by the caller
    /// (tuning is configuration, not state).
    pub fn from_json(cfg: BreakerConfig, doc: &Json) -> CircuitBreaker {
        let num = |k: &str| doc.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let state = match doc.get("state").and_then(Json::as_str) {
            Some("open") => BreakerState::Open {
                until_round: num("until_round") as u64,
            },
            Some("half_open") => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        };
        CircuitBreaker {
            cfg,
            state,
            streak: num("streak") as u32,
            trips: num("trips") as u64,
            fallback_rounds: num("fallback_rounds") as u64,
        }
    }
}

/// The fallback policy served while a breaker is open: keep every
/// surviving placement whose GPUs are all healthy, first-fit the rest on
/// empty healthy GPUs, no packing, no strategy search. Deliberately
/// simple — it cannot touch the code paths that tripped the breaker
/// (matching, LP, packing).
pub fn greedy_fallback_decision(input: &RoundInput) -> RoundDecision {
    let t0 = Instant::now();
    let mut plan = PlacementPlan::new(input.prev_plan.num_gpus());
    let healthy = |g: usize| input.health.is_none_or(|h| h.is_healthy(g));
    let active_ids: BTreeSet<_> = input.active.iter().map(|j| j.id).collect();

    // Survivors keep their GPUs (packed pairs included — both tenants
    // stay co-resident, which the slot capacity already permits).
    for (&job, gpus) in input.prev_plan.job_gpu_map() {
        if active_ids.contains(&job) && gpus.iter().all(|&g| healthy(g)) {
            plan.place(job, gpus);
        }
    }

    // First-fit the remaining active jobs on empty healthy GPUs, in
    // arrival order (the slice order the simulator hands us).
    let mut free: Vec<usize> = (0..plan.num_gpus())
        .filter(|&g| healthy(g) && plan.jobs_on(g).is_empty())
        .collect();
    for job in input.active {
        if !plan.gpus_of(job.id).is_empty() {
            continue;
        }
        let want = job.num_gpus as usize;
        if want == 0 || want > free.len() {
            continue;
        }
        let gpus: Vec<usize> = free.drain(..want).collect();
        plan.place(job.id, &gpus);
    }

    let migrations = plan.migrations_from(input.prev_plan);
    let timings = DecisionTimings {
        total_s: t0.elapsed().as_secs_f64(),
        ..Default::default()
    };
    RoundDecision {
        plan,
        // Empty: the simulator falls back to DataParallel for placed
        // jobs without an explicit strategy.
        strategies: Default::default(),
        packed_pairs: Vec::new(),
        migrations,
        degraded: false,
        timings,
    }
}

/// Wraps any scheduler with a [`CircuitBreaker`]: transparent
/// pass-through while closed (bit-identical to the bare scheduler), the
/// greedy fallback while open.
pub struct BreakerScheduler {
    inner: Box<dyn Scheduler>,
    breaker: CircuitBreaker,
}

impl BreakerScheduler {
    pub fn new(inner: Box<dyn Scheduler>, cfg: BreakerConfig) -> BreakerScheduler {
        BreakerScheduler {
            inner,
            breaker: CircuitBreaker::new(cfg),
        }
    }

    pub fn breaker(&self) -> &CircuitBreaker {
        &self.breaker
    }
}

impl Scheduler for BreakerScheduler {
    /// Delegates: wrapping must not change `SimResult.scheduler` labels.
    fn name(&self) -> String {
        self.inner.name()
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        if self.breaker.use_fallback(input.round) {
            metrics::counter_add("breaker.fallback_rounds", 1);
            return greedy_fallback_decision(input);
        }
        let decision = self.inner.decide(input);
        self.breaker.record(input.round, decision.degraded);
        decision
    }

    fn snapshot_state(&self) -> Option<Json> {
        Some(Json::obj(vec![
            ("breaker", self.breaker.to_json()),
            ("inner", self.inner.snapshot_state().unwrap_or(Json::Null)),
        ]))
    }

    fn restore_state(&mut self, state: &Json) {
        if let Some(b) = state.get("breaker") {
            self.breaker = CircuitBreaker::from_json(self.breaker.cfg, b);
        }
        match state.get("inner") {
            Some(Json::Null) | None => {}
            Some(inner) => self.inner.restore_state(inner),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn breaker() -> CircuitBreaker {
        CircuitBreaker::new(BreakerConfig {
            trip_after: 3,
            cooldown_rounds: 5,
        })
    }

    #[test]
    fn trips_only_on_consecutive_degradation() {
        let mut b = breaker();
        for r in 0..2 {
            b.record(r, true);
        }
        b.record(2, false); // streak reset
        for r in 3..5 {
            b.record(r, true);
        }
        assert_eq!(b.state(), BreakerState::Closed);
        b.record(5, true); // third consecutive
        assert_eq!(b.state(), BreakerState::Open { until_round: 11 });
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn cooldown_then_half_open_probe_closes_on_success() {
        let mut b = breaker();
        for r in 0..3 {
            b.record(r, true);
        }
        // Rounds 3..=7 are fallback; round 8 probes.
        for r in 3..8 {
            assert!(b.use_fallback(r), "round {r} should be fallback");
        }
        assert!(!b.use_fallback(8), "cooldown expired: probe the provider");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record(8, false);
        assert_eq!(b.state(), BreakerState::Closed);
    }

    #[test]
    fn degraded_probe_reopens_immediately() {
        let mut b = breaker();
        for r in 0..3 {
            b.record(r, true);
        }
        while b.use_fallback(b.cfg.cooldown_rounds + 10) {} // expire
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.record(9, true);
        assert_eq!(b.state(), BreakerState::Open { until_round: 15 });
        assert_eq!(b.trips(), 2);
    }

    #[test]
    fn json_round_trip_preserves_state() {
        let mut b = breaker();
        for r in 0..3 {
            b.record(r, true);
        }
        let doc = b.to_json();
        let restored = CircuitBreaker::from_json(b.cfg, &doc);
        assert_eq!(restored.state(), b.state());
        assert_eq!(restored.trips(), b.trips());
        assert_eq!(restored.streak(), b.streak());
        assert_eq!(restored.fallback_rounds, b.fallback_rounds);
    }

    #[test]
    fn greedy_fallback_keeps_survivors_and_first_fits_new_jobs() {
        use crate::cluster::{ClusterSpec, GpuType};
        use crate::jobs::ModelKind;
        use crate::policies::JobInfo;

        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let mut prev = PlacementPlan::new(8);
        prev.place(1, &[0, 1]);
        prev.place(2, &[2]);
        let job = |id: u64, n: u32| JobInfo {
            id,
            model: ModelKind::ResNet50,
            num_gpus: n,
            arrival_time: 0.0,
            attained_service: 0.0,
            total_iters: 1000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 0.0,
            iso_tput: 1.0,
        };
        // Job 2 departed; job 3 arrives wanting 2 GPUs.
        let active = vec![job(1, 2), job(3, 2)];
        let prev_ref = prev.clone();
        let input = RoundInput {
            now: 0.0,
            round: 4,
            active: &active,
            prev_plan: &prev_ref,
            spec: &spec,
            health: None,
        };
        let d = greedy_fallback_decision(&input);
        assert_eq!(d.plan.gpus_of(1), &[0, 1], "survivor keeps its GPUs");
        assert!(d.plan.gpus_of(2).is_empty(), "departed job dropped");
        assert_eq!(d.plan.gpus_of(3).len(), 2, "new job first-fit placed");
        assert!(!d.degraded);
        assert_eq!(d.migrations, d.plan.migrations_from(&prev_ref));
    }
}

//! Stage deadline watchdog: a per-stage soft time budget checked
//! cooperatively at worker-pool chunk boundaries and LP iteration
//! checkpoints.
//!
//! std-only means there is no way to kill a hung thread, so the budget is
//! enforced by the arming thread panicking from one of its own
//! checkpoints with a typed [`DeadlineExceeded`] payload; the existing
//! `pipeline::run_round` catch-unwind converts that into a degraded round
//! with reason `deadline` (distinct from `panic`, see
//! `pipeline::degraded_decision`).
//!
//! The armed deadline is **thread-local** on purpose:
//! - `WorkerPool` discards worker panic payloads (`join().expect`), so a
//!   deadline panic from a worker thread could never be classified.
//!   Workers never arm the TLS slot, which makes the pool-internal
//!   checkpoints no-ops on workers; only caller-thread checkpoints trip.
//! - Whole simulations run concurrently on pool workers
//!   (`run_sim_scenarios`), and POP runs nested `run_round`s on workers;
//!   a process-global deadline slot would cross-contaminate them. With
//!   TLS each top-level round arms its own slot and [`StageGuard`]
//!   save/restores the previous value for nesting.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Environment knob mirrored by `simulate --stage-deadline-ms` (read once
/// per process; the CLI setter takes precedence).
pub const DEADLINE_ENV: &str = "TESSERAE_STAGE_DEADLINE_MS";

/// Typed panic payload thrown by [`checkpoint`] when the armed stage
/// budget has elapsed. `degraded_decision` downcasts the caught payload
/// to this type to record the degraded round as `deadline` rather than
/// `panic`.
#[derive(Debug, Clone, Copy)]
pub struct DeadlineExceeded {
    pub stage: &'static str,
    pub budget_ms: u64,
}

#[derive(Clone, Copy)]
struct Armed {
    deadline: Instant,
    stage: &'static str,
    budget_ms: u64,
}

thread_local! {
    static ARMED: Cell<Option<Armed>> = const { Cell::new(None) };
}

const UNSET: u64 = u64::MAX;
const OFF: u64 = 0;

/// Process-global configured budget in milliseconds; `UNSET` falls back
/// to the environment variable, `OFF` disables the watchdog.
static DEADLINE_MS: AtomicU64 = AtomicU64::new(UNSET);

/// Configure the per-stage budget (CLI path). `None` disables the
/// watchdog even if [`DEADLINE_ENV`] is set.
pub fn set_stage_deadline_ms(ms: Option<u64>) {
    DEADLINE_MS.store(ms.unwrap_or(OFF), Ordering::Relaxed);
}

/// The effective per-stage budget: the CLI setter if called, else the
/// environment variable (cached on first read), else disabled.
pub fn stage_deadline_ms() -> Option<u64> {
    match DEADLINE_MS.load(Ordering::Relaxed) {
        UNSET => env_deadline_ms(),
        OFF => None,
        ms => Some(ms),
    }
}

fn env_deadline_ms() -> Option<u64> {
    static ENV: OnceLock<Option<u64>> = OnceLock::new();
    *ENV.get_or_init(|| {
        std::env::var(DEADLINE_ENV)
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .filter(|&ms| ms > 0)
    })
}

/// RAII guard for one armed stage; restores the previously armed deadline
/// (if any) on drop so nested rounds compose.
pub struct StageGuard {
    prev: Option<Armed>,
}

impl Drop for StageGuard {
    fn drop(&mut self) {
        ARMED.with(|a| a.set(self.prev));
    }
}

/// Arm the calling thread's deadline for `stage` using the configured
/// budget; `None` (and no guard) when the watchdog is disabled.
pub fn arm_stage(stage: &'static str) -> Option<StageGuard> {
    stage_deadline_ms().map(|ms| arm_stage_with(stage, Duration::from_millis(ms)))
}

/// Arm the calling thread's deadline for `stage` with an explicit budget
/// (test seam; bypasses the process-global configuration).
pub fn arm_stage_with(stage: &'static str, budget: Duration) -> StageGuard {
    let armed = Armed {
        deadline: Instant::now() + budget,
        stage,
        budget_ms: budget.as_millis() as u64,
    };
    StageGuard {
        prev: ARMED.with(|a| a.replace(Some(armed))),
    }
}

/// Cooperative check: panics with [`DeadlineExceeded`] when the calling
/// thread's armed stage budget has elapsed. A no-op on threads that never
/// armed (worker-pool workers, unconfigured runs) — safe to sprinkle in
/// hot loops; the disarmed path is one TLS read.
pub fn checkpoint() {
    ARMED.with(|a| {
        if let Some(armed) = a.get() {
            if Instant::now() >= armed.deadline {
                // Disarm before unwinding so cleanup code running during
                // the unwind cannot re-trip the same deadline.
                a.set(None);
                crate::obs::metrics::counter_add("watchdog.deadline_trips", 1);
                std::panic::panic_any(DeadlineExceeded {
                    stage: armed.stage,
                    budget_ms: armed.budget_ms,
                });
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn checkpoint_is_noop_when_disarmed() {
        checkpoint(); // must not panic on an unarmed thread
    }

    #[test]
    fn elapsed_budget_trips_with_typed_payload() {
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _g = arm_stage_with("pack", Duration::from_millis(0));
            checkpoint();
        }))
        .expect_err("zero budget must trip");
        let d = err
            .downcast_ref::<DeadlineExceeded>()
            .expect("payload must be DeadlineExceeded");
        assert_eq!(d.stage, "pack");
        assert_eq!(d.budget_ms, 0);
        // The guard's unwind drop restored the disarmed state.
        checkpoint();
    }

    #[test]
    fn generous_budget_does_not_trip() {
        let _g = arm_stage_with("schedule", Duration::from_secs(3600));
        for _ in 0..100 {
            checkpoint();
        }
    }

    #[test]
    fn nested_guards_restore_outer_deadline() {
        let _outer = arm_stage_with("estimate", Duration::from_secs(3600));
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _inner = arm_stage_with("migrate", Duration::from_millis(0));
            checkpoint();
        }))
        .expect_err("inner zero budget must trip");
        assert_eq!(
            err.downcast_ref::<DeadlineExceeded>().unwrap().stage,
            "migrate"
        );
        // Outer guard is armed again (restored by the inner drop during
        // unwind) and far from expiring.
        checkpoint();
    }

    #[test]
    fn worker_threads_do_not_inherit_the_deadline() {
        let _g = arm_stage_with("pack", Duration::from_millis(0));
        std::thread::scope(|s| {
            s.spawn(|| checkpoint()).join().expect("worker must not trip");
        });
    }
}

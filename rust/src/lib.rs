//! # Tesserae — scalable placement policies for deep-learning workloads
//!
//! Reproduction of *"Tesserae: Scalable Placement Policies for Deep Learning
//! Workloads"* (Bian et al., 2025) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! * **Layer 3 (this crate)** — the cluster scheduler: scheduling policies,
//!   the paper's graph-matching migration (Alg. 2/3/5) and packing (Alg. 4)
//!   placement policies, Gavel/POP LP baselines, a round-based cluster
//!   simulator, trace generators, the profiling/estimation stack, and a
//!   real-execution coordinator that trains actual (tiny) models through
//!   PJRT.
//! * **Layer 2 (python/compile, build-time)** — JAX graphs AOT-lowered to
//!   HLO text: the ε-scaling auction assignment solver, a Gaussian-process
//!   posterior for profiling-cost reduction, and a small GPT train step.
//! * **Layer 1 (python/compile/kernels, build-time)** — Pallas kernels
//!   (`top2` bidding reduction, fused causal attention) called from L2.
//!
//! See `DESIGN.md` (repo root) for the system inventory and the
//! per-experiment index, and `EXPERIMENTS.md` for paper-vs-measured
//! results and the perf baselines recorded in `BENCH_e2e_sim.json`.

pub mod cluster;
pub mod coordinator;
pub mod estimator;
pub mod experiments;
pub mod faults;
pub mod jobs;
pub mod linalg;
pub mod matching;
pub mod obs;
pub mod policies;
pub mod profiler;
pub mod recovery;
/// The PJRT-backed runtime needs the `xla` crate, which only exists in the
/// rust_pallas build image. The `pjrt` feature gates it; the default build
/// substitutes a std-only stub with the same API surface whose entry points
/// (`Manifest::discover`, …) report that artifacts are unavailable, so the
/// coordinator, benches and integration tests skip gracefully.
#[cfg(feature = "pjrt")]
pub mod runtime;
#[cfg(not(feature = "pjrt"))]
#[path = "runtime_stub.rs"]
pub mod runtime;
pub mod schedulers;
pub mod sharding;
pub mod simulator;
pub mod trace;
pub mod util;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}

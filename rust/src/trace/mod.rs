//! Workload traces: the Shockwave-like default trace and the Gavel-like
//! sensitivity trace (§6.1, §7.2), plus JSON (de)serialization so traces can
//! be generated once and replayed across schedulers.

use crate::jobs::{Job, JobId, ModelKind};
use crate::util::json::{Json, JsonError};
use crate::util::rng::Pcg64;

/// A workload trace: jobs sorted by arrival time.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub jobs: Vec<Job>,
}

/// Parameters shared by both generators.
#[derive(Debug, Clone)]
pub struct TraceParams {
    pub num_jobs: usize,
    /// Poisson arrival rate in jobs/hour (the paper uses 80).
    pub jobs_per_hour: f64,
    pub seed: u64,
}

impl Default for TraceParams {
    fn default() -> Self {
        TraceParams {
            num_jobs: 900,
            jobs_per_hour: 80.0,
            seed: 1,
        }
    }
}

/// Job size classes of the Shockwave trace. Durations are the *isolated*
/// runtimes the size buckets map to (seconds).
const SHOCKWAVE_SIZE_PROBS: [f64; 4] = [0.72, 0.2, 0.05, 0.03];
const SHOCKWAVE_DURATION_S: [(f64, f64); 4] = [
    (600.0, 3_600.0),       // Small
    (3_600.0, 14_400.0),    // Medium
    (14_400.0, 36_000.0),   // Large
    (36_000.0, 86_400.0),   // Extra Large
];
const SHOCKWAVE_GPU_PROBS: [f64; 4] = [0.6, 0.3, 0.09, 0.01];
const GPU_CHOICES: [u32; 4] = [1, 2, 4, 8];

/// Gavel trace distributions (§7.2): duration 10^[1.5,3] min w.p. 0.8,
/// 10^[3,4] min w.p. 0.2; GPUs 1/2/4/8 w.p. 0.7/0.1/0.15/0.05.
const GAVEL_GPU_PROBS: [f64; 4] = [0.7, 0.1, 0.15, 0.05];

impl Trace {
    /// Generate the default (Shockwave-like) trace.
    pub fn shockwave(params: &TraceParams) -> Trace {
        let mut rng = Pcg64::new(params.seed);
        let mut t = 0.0f64;
        let rate = params.jobs_per_hour / 3600.0;
        let mut jobs = Vec::with_capacity(params.num_jobs);
        for id in 0..params.num_jobs {
            t += rng.exponential(rate);
            let size = rng.weighted_choice(&SHOCKWAVE_SIZE_PROBS);
            let (lo, hi) = SHOCKWAVE_DURATION_S[size];
            let duration = rng.range_f64(lo, hi);
            let num_gpus = GPU_CHOICES[rng.weighted_choice(&SHOCKWAVE_GPU_PROBS)];
            jobs.push(make_job(id as JobId, t, duration, num_gpus, &mut rng));
        }
        Trace { jobs }
    }

    /// Generate the Gavel-like sensitivity trace (§7.2).
    pub fn gavel(params: &TraceParams) -> Trace {
        let mut rng = Pcg64::new(params.seed ^ 0x6a7e1);
        let mut t = 0.0f64;
        let rate = params.jobs_per_hour / 3600.0;
        let mut jobs = Vec::with_capacity(params.num_jobs);
        for id in 0..params.num_jobs {
            t += rng.exponential(rate);
            let duration_min = if rng.f64() < 0.8 {
                rng.log10_uniform(1.5, 3.0)
            } else {
                rng.log10_uniform(3.0, 4.0)
            };
            let num_gpus = GPU_CHOICES[rng.weighted_choice(&GAVEL_GPU_PROBS)];
            jobs.push(make_job(
                id as JobId,
                t,
                duration_min * 60.0,
                num_gpus,
                &mut rng,
            ));
        }
        Trace { jobs }
    }

    /// Jobs arriving in `(from, to]`.
    pub fn arrivals(&self, from: f64, to: f64) -> impl Iterator<Item = &Job> {
        self.jobs
            .iter()
            .filter(move |j| j.arrival_time > from && j.arrival_time <= to)
    }

    // ------------------------------------------------------------------ io

    pub fn to_json(&self) -> Json {
        Json::arr(
            self.jobs
                .iter()
                .map(|j| {
                    Json::obj(vec![
                        ("id", Json::num(j.id as f64)),
                        ("model", Json::str(j.model.name())),
                        ("num_gpus", Json::num(j.num_gpus as f64)),
                        ("arrival_time", Json::num(j.arrival_time)),
                        ("total_iters", Json::num(j.total_iters)),
                        ("batch_size", Json::num(j.batch_size as f64)),
                    ])
                })
                .collect(),
        )
    }

    /// Errors name the offending job index and key, so a 900-job trace
    /// with one bad field points straight at it.
    pub fn from_json(v: &Json) -> Result<Trace, JsonError> {
        let arr = v
            .as_arr()
            .ok_or_else(|| JsonError("trace must be an array".into()))?;
        let mut jobs = Vec::with_capacity(arr.len());
        for (idx, item) in arr.iter().enumerate() {
            let model_name = item
                .require("model")
                .map_err(|e| JsonError(format!("job #{idx}, key 'model': {}", e.0)))?
                .as_str()
                .ok_or_else(|| {
                    JsonError(format!("job #{idx}, key 'model': must be a string"))
                })?;
            let model = ModelKind::from_name(model_name).ok_or_else(|| {
                JsonError(format!("job #{idx}, key 'model': unknown model '{model_name}'"))
            })?;
            let f = |k: &str| -> Result<f64, JsonError> {
                item.require(k)
                    .map_err(|e| JsonError(format!("job #{idx}, key '{k}': {}", e.0)))?
                    .as_f64()
                    .ok_or_else(|| JsonError(format!("job #{idx}, key '{k}': must be a number")))
            };
            jobs.push(Job {
                id: f("id")? as JobId,
                model,
                num_gpus: f("num_gpus")? as u32,
                arrival_time: f("arrival_time")?,
                total_iters: f("total_iters")?,
                batch_size: f("batch_size")? as u32,
            });
        }
        Ok(Trace { jobs })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Errors carry the file path (and, through [`Trace::from_json`], the
    /// offending job and key) so a bad `--trace` argument is diagnosable
    /// from the message alone.
    pub fn load(path: &str) -> anyhow::Result<Trace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("trace file '{path}': {e}"))?;
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("trace file '{path}': {e}"))?;
        Trace::from_json(&doc).map_err(|e| anyhow::anyhow!("trace file '{path}': {e}"))
    }
}

/// Pick a model compatible with the GPU count and convert the sampled
/// isolated duration into total work (iterations).
fn make_job(id: JobId, arrival: f64, duration_s: f64, num_gpus: u32, rng: &mut Pcg64) -> Job {
    // LLMs only run as multi-GPU (>=4) jobs; small jobs draw from group 1.
    let model = if num_gpus >= 4 && rng.f64() < 0.35 {
        [ModelKind::Gpt3Medium, ModelKind::Gpt3Xl, ModelKind::Gpt3_3B]
            [rng.below(3) as usize]
    } else {
        [
            ModelKind::ResNet50,
            ModelKind::Vgg19,
            ModelKind::Dcgan,
            ModelKind::PointNet,
        ][rng.below(4) as usize]
    };
    let (lo, hi) = model.batch_size_range();
    let batch = if lo == hi {
        lo
    } else {
        // Power-of-two batch inside the range.
        let choices: Vec<u32> = (0..)
            .map(|k| lo << k)
            .take_while(|&b| b <= hi)
            .collect();
        choices[rng.below(choices.len() as u64) as usize]
    };
    // total work = isolated duration × isolated throughput on the reference
    // GPU at the job's scale (linear-model reference: N × single-GPU tput).
    let iso_tput = model.base_tput_a100() * num_gpus as f64;
    Job {
        id,
        model,
        num_gpus,
        arrival_time: arrival,
        total_iters: duration_s * iso_tput,
        batch_size: batch,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shockwave_distributions_roughly_match() {
        let t = Trace::shockwave(&TraceParams {
            num_jobs: 4000,
            jobs_per_hour: 80.0,
            seed: 3,
        });
        assert_eq!(t.jobs.len(), 4000);
        let one_gpu = t.jobs.iter().filter(|j| j.num_gpus == 1).count() as f64 / 4000.0;
        assert!((one_gpu - 0.6).abs() < 0.03, "1-GPU frac {one_gpu}");
        let eight = t.jobs.iter().filter(|j| j.num_gpus == 8).count() as f64 / 4000.0;
        assert!((eight - 0.01).abs() < 0.01, "8-GPU frac {eight}");
        // Arrival rate ~80/h.
        let span_h = t.jobs.last().unwrap().arrival_time / 3600.0;
        let rate = 4000.0 / span_h;
        assert!((rate - 80.0).abs() < 8.0, "rate {rate}");
        // Arrivals sorted.
        assert!(t.jobs.windows(2).all(|w| w[0].arrival_time <= w[1].arrival_time));
    }

    #[test]
    fn gavel_durations_span_decades() {
        let t = Trace::gavel(&TraceParams {
            num_jobs: 2000,
            jobs_per_hour: 80.0,
            seed: 5,
        });
        // Recover isolated durations from work/throughput.
        let durations: Vec<f64> = t
            .jobs
            .iter()
            .map(|j| j.total_iters / (j.model.base_tput_a100() * j.num_gpus as f64) / 60.0)
            .collect();
        let short = durations.iter().filter(|&&d| d < 1000.0).count() as f64 / 2000.0;
        assert!((short - 0.8).abs() < 0.05, "short frac {short}");
        assert!(durations.iter().cloned().fold(0.0, f64::max) > 1000.0);
        let one_gpu = t.jobs.iter().filter(|j| j.num_gpus == 1).count() as f64 / 2000.0;
        assert!((one_gpu - 0.7).abs() < 0.04);
    }

    #[test]
    fn llms_only_on_4plus_gpus() {
        let t = Trace::shockwave(&TraceParams {
            num_jobs: 3000,
            jobs_per_hour: 80.0,
            seed: 7,
        });
        for j in &t.jobs {
            if j.model.is_llm() {
                assert!(j.num_gpus >= 4, "LLM {} on {} GPUs", j.id, j.num_gpus);
                assert_eq!(j.batch_size, 512);
            }
        }
        // LLMs do appear.
        assert!(t.jobs.iter().any(|j| j.model.is_llm()));
    }

    #[test]
    fn json_roundtrip() {
        let t = Trace::shockwave(&TraceParams {
            num_jobs: 50,
            jobs_per_hour: 80.0,
            seed: 11,
        });
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace::gavel(&TraceParams {
            num_jobs: 20,
            jobs_per_hour: 80.0,
            seed: 13,
        });
        let path = std::env::temp_dir().join("tesserae_trace_test.json");
        let path = path.to_str().unwrap();
        t.save(path).unwrap();
        let back = Trace::load(path).unwrap();
        assert_eq!(back, t);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn malformed_trace_errors_name_job_and_key() {
        let t = Trace::shockwave(&TraceParams {
            num_jobs: 3,
            jobs_per_hour: 80.0,
            seed: 29,
        });
        // Corrupt job #1's arrival_time into a string.
        let mut doc = t.to_json();
        if let Json::Arr(items) = &mut doc {
            if let Json::Obj(fields) = &mut items[1] {
                fields.insert("arrival_time".to_string(), Json::str("soon"));
            }
        }
        let msg = Trace::from_json(&doc).unwrap_err().to_string();
        assert!(msg.contains("job #1"), "missing job index: {msg}");
        assert!(msg.contains("arrival_time"), "missing key: {msg}");

        // Drop a key entirely: same shape of message.
        let mut doc = t.to_json();
        if let Json::Arr(items) = &mut doc {
            if let Json::Obj(fields) = &mut items[2] {
                fields.remove("num_gpus");
            }
        }
        let msg = Trace::from_json(&doc).unwrap_err().to_string();
        assert!(msg.contains("job #2"), "missing job index: {msg}");
        assert!(msg.contains("num_gpus"), "missing key: {msg}");
    }

    #[test]
    fn load_errors_name_the_file() {
        let missing = "/definitely/not/a/real/tesserae-trace.json";
        let msg = format!("{:#}", Trace::load(missing).unwrap_err());
        assert!(msg.contains(missing), "missing path: {msg}");

        let path = std::env::temp_dir().join(format!(
            "tesserae_trace_malformed_{}.json",
            std::process::id()
        ));
        std::fs::write(&path, "{\"not\": \"an array\"}").unwrap();
        let msg = format!("{:#}", Trace::load(path.to_str().unwrap()).unwrap_err());
        assert!(msg.contains(path.to_str().unwrap()), "missing path: {msg}");
        assert!(msg.contains("array"), "missing cause: {msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn arrivals_window() {
        let t = Trace::shockwave(&TraceParams {
            num_jobs: 100,
            jobs_per_hour: 80.0,
            seed: 17,
        });
        let all: usize = t.arrivals(0.0, f64::INFINITY).count();
        // First job arrives strictly after t=0 (exponential gap).
        assert_eq!(all, 100);
        let t0 = t.jobs[10].arrival_time;
        let later = t.arrivals(t0, f64::INFINITY).count();
        assert_eq!(later, 89);
    }

    #[test]
    fn deterministic_generation() {
        let p = TraceParams {
            num_jobs: 30,
            jobs_per_hour: 80.0,
            seed: 19,
        };
        assert_eq!(Trace::shockwave(&p), Trace::shockwave(&p));
        assert_ne!(
            Trace::shockwave(&p),
            Trace::shockwave(&TraceParams { seed: 20, ..p.clone() })
        );
    }
}

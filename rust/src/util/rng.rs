//! Deterministic PRNG substrate.
//!
//! The offline crate set has no `rand`, so we implement PCG64 (O'Neill,
//! "PCG: A Family of Simple Fast Space-Efficient Statistically Good
//! Algorithms for Random Number Generation") plus the handful of
//! distributions the scheduler stack needs: uniforms, exponential
//! inter-arrival times (Poisson job arrivals), normals (profiling jitter),
//! log-uniform job durations (the Gavel trace's 10^[1.5,3] minutes), and
//! weighted choice (Shockwave's job-size mix).
//!
//! Everything in the repository that is "random" flows through this type so
//! that traces, profiles and simulations are reproducible from a single seed.

/// PCG64 (XSL-RR 128/64) pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Two generators with different seeds
    /// produce independent-looking streams.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into state + stream.
        let mut sm = seed.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Pcg64 { state, inc };
        rng.next_u64(); // advance past the seed-correlated first output
        rng
    }

    /// Derive an independent child generator (for per-job / per-run streams).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15))
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's unbiased method.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with the given rate (events per unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }

    /// 10^Uniform[lo_exp, hi_exp) — the Gavel trace duration distribution.
    pub fn log10_uniform(&mut self, lo_exp: f64, hi_exp: f64) -> f64 {
        10f64.powf(self.range_f64(lo_exp, hi_exp))
    }

    /// Weighted choice: returns an index with probability weights[i]/sum.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_choice over empty/zero weights");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from 0..n (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg64::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} not near 10k");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Pcg64::new(13);
        let rate = 80.0 / 3600.0; // 80 jobs/hour
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 45.0).abs() < 1.5, "mean inter-arrival {mean} != 45s");
    }

    #[test]
    fn weighted_choice_distribution() {
        let mut r = Pcg64::new(17);
        let w = [0.72, 0.2, 0.05, 0.03]; // Shockwave job-size mix
        let mut counts = [0usize; 4];
        for _ in 0..100_000 {
            counts[r.weighted_choice(&w)] += 1;
        }
        for i in 0..4 {
            let frac = counts[i] as f64 / 100_000.0;
            assert!((frac - w[i]).abs() < 0.01, "bucket {i}: {frac} vs {}", w[i]);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn log10_uniform_bounds() {
        let mut r = Pcg64::new(23);
        for _ in 0..10_000 {
            let d = r.log10_uniform(1.5, 3.0);
            assert!((31.62..1000.0).contains(&d), "{d}");
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Pcg64::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}

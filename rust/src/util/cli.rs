//! Tiny CLI argument parser (the offline crate set has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args, with
//! typed accessors and defaults. The `tesserae` binary, examples and benches
//! all parse through this.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse the process arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // Note: positionals come before flags by convention — `--verbose x`
        // would otherwise parse as the option verbose=x.
        let a = parse(&[
            "simulate",
            "trace.json",
            "--jobs",
            "900",
            "--gpus=80",
            "--verbose",
        ]);
        assert_eq!(a.subcommand(), Some("simulate"));
        assert_eq!(a.get_usize("jobs", 0), 900);
        assert_eq!(a.get_usize("gpus", 0), 80);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional[1], "trace.json");
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.get_usize("jobs", 120), 120);
        assert_eq!(a.get_f64("rate", 80.0), 80.0);
        assert_eq!(a.get_str("policy", "tesserae-t"), "tesserae-t");
        assert!(!a.flag("verbose"));
        assert_eq!(a.subcommand(), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--pack", "--migrate"]);
        assert!(a.flag("pack"));
        assert!(a.flag("migrate"));
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        let a = parse(&["--jobs", "many"]);
        a.get_usize("jobs", 0);
    }
}

//! Resume-safe JSON checkpoints for long experiment sweeps.
//!
//! The paper-scale Fig. 2 / Fig. 14 sweeps measure individual cells that
//! can each take minutes; a budget cap or an interrupted run used to
//! discard everything already measured. A [`Checkpoint`] is a flat
//! `key → JSON` store flushed to disk after every completed cell
//! (write-temp-then-rename, so a kill mid-write never corrupts completed
//! work); re-running the sweep with the same file skips finished cells.

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use super::json::Json;

/// A persistent map of completed experiment cells.
#[derive(Debug)]
pub struct Checkpoint {
    path: PathBuf,
    cells: BTreeMap<String, Json>,
}

impl Checkpoint {
    /// Open `path`, loading any previously completed cells. A missing
    /// file starts empty silently (fresh sweep); a file that exists but is
    /// truncated or otherwise unparsable — a kill mid-write outside the
    /// rename window, disk-full tails, manual edits — is *discarded with a
    /// warning* and the sweep re-measures, rather than aborting the run or
    /// silently trusting partial data.
    pub fn load_or_new(path: impl AsRef<Path>) -> Checkpoint {
        let path = path.as_ref().to_path_buf();
        let cells = match fs::read_to_string(&path) {
            Err(_) => BTreeMap::new(),
            Ok(text) => match Json::parse(&text) {
                Ok(doc) => match doc.get("cells").and_then(Json::as_obj) {
                    Some(cells) => cells.clone(),
                    None => {
                        crate::obs_log!(
                            warn,
                            "checkpoint {}: no 'cells' object; discarding and re-measuring",
                            path.display()
                        );
                        BTreeMap::new()
                    }
                },
                Err(e) => {
                    crate::obs_log!(
                        warn,
                        "checkpoint {}: corrupt ({e}); discarding and re-measuring",
                        path.display()
                    );
                    BTreeMap::new()
                }
            },
        };
        Checkpoint { path, cells }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.cells.get(key)
    }

    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record a completed cell and flush the file.
    pub fn put(&mut self, key: &str, value: Json) -> io::Result<()> {
        self.cells.insert(key.to_string(), value);
        self.save()
    }

    fn save(&self) -> io::Result<()> {
        let doc = Json::Obj(
            [("cells".to_string(), Json::Obj(self.cells.clone()))]
                .into_iter()
                .collect(),
        );
        durable_write(&self.path, &doc.to_string_pretty())
    }
}

/// Durable atomic file replacement: write a temp file next to `path`,
/// `sync_all` it, rename it over `path`, then best-effort fsync the
/// parent directory so the rename itself survives power loss. A plain
/// write-temp-then-rename protects against a killed *process* but not a
/// lost *machine* — an unsynced temp can legally surface as a zero-length
/// or torn file after the rename.
pub fn durable_write(path: &Path, contents: &str) -> io::Result<()> {
    use std::io::Write;

    // Append (not replace-extension): distinct target paths must never
    // collapse onto one temp file.
    let mut tmp_name = path.as_os_str().to_os_string();
    tmp_name.push(".tmp");
    let tmp = PathBuf::from(tmp_name);
    {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(contents.as_bytes())?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)?;
    // Directory fsync is what persists the rename; not all platforms
    // allow opening a directory for sync, so this part is best-effort.
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        if let Ok(dir) = fs::File::open(parent) {
            let _ = dir.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("tesserae_ckpt_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn cells_survive_reload() {
        let path = tmp_path("reload");
        let _ = fs::remove_file(&path);
        let mut c = Checkpoint::load_or_new(&path);
        assert!(c.is_empty());
        c.put("fig2/gavel/256", Json::obj(vec![("total_s", Json::num(1.5))]))
            .unwrap();
        c.put("fig2/gavel/512", Json::obj(vec![("total_s", Json::num(4.0))]))
            .unwrap();
        drop(c);
        let re = Checkpoint::load_or_new(&path);
        assert_eq!(re.len(), 2);
        assert_eq!(
            re.get("fig2/gavel/256")
                .and_then(|v| v.get("total_s"))
                .and_then(Json::as_f64),
            Some(1.5)
        );
        assert!(re.get("fig2/gavel/1024").is_none());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn missing_or_garbage_file_starts_empty() {
        let path = tmp_path("garbage");
        let _ = fs::remove_file(&path);
        assert!(Checkpoint::load_or_new(&path).is_empty());
        fs::write(&path, "{not json").unwrap();
        assert!(Checkpoint::load_or_new(&path).is_empty());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn truncated_checkpoint_discards_and_recovers() {
        // Simulate a kill mid-write (or a disk-full tail): a previously
        // valid file cut off halfway. Resume must start empty instead of
        // crashing or trusting partial data, and the next put must produce
        // a well-formed file again.
        let path = tmp_path("truncated");
        let _ = fs::remove_file(&path);
        let mut c = Checkpoint::load_or_new(&path);
        c.put("fig2/tesserae/512", Json::num(3.25)).unwrap();
        c.put("fig2/tesserae/1024", Json::num(9.5)).unwrap();
        drop(c);
        let full = fs::read_to_string(&path).unwrap();
        fs::write(&path, &full[..full.len() / 2]).unwrap();

        let mut re = Checkpoint::load_or_new(&path);
        assert!(re.is_empty(), "truncated cells must be discarded");
        re.put("fig2/tesserae/512", Json::num(3.25)).unwrap();
        drop(re);
        let healed = Checkpoint::load_or_new(&path);
        assert_eq!(healed.len(), 1);
        assert_eq!(
            healed.get("fig2/tesserae/512").and_then(Json::as_f64),
            Some(3.25)
        );
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn valid_json_without_cells_object_starts_empty() {
        let path = tmp_path("nocells");
        fs::write(&path, "{\"version\": 2}").unwrap();
        assert!(Checkpoint::load_or_new(&path).is_empty());
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn durable_write_replaces_and_leaves_no_temp() {
        let path = tmp_path("durable");
        let _ = fs::remove_file(&path);
        durable_write(&path, "first").unwrap();
        durable_write(&path, "second").unwrap();
        assert_eq!(fs::read_to_string(&path).unwrap(), "second");
        let mut tmp_name = path.as_os_str().to_os_string();
        tmp_name.push(".tmp");
        assert!(
            !PathBuf::from(tmp_name).exists(),
            "temp file must not outlive the rename"
        );
        let _ = fs::remove_file(&path);
    }

    #[test]
    fn put_overwrites_existing_key() {
        let path = tmp_path("overwrite");
        let _ = fs::remove_file(&path);
        let mut c = Checkpoint::load_or_new(&path);
        c.put("k", Json::num(1.0)).unwrap();
        c.put("k", Json::num(2.0)).unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c.get("k").and_then(Json::as_f64), Some(2.0));
        let _ = fs::remove_file(&path);
    }
}

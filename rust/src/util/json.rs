//! Minimal JSON substrate (parser + emitter).
//!
//! The offline crate set has no `serde`, but the build pipeline needs a
//! structured interchange format in two places: the artifact manifest
//! written by `python/compile/aot.py` and trace files written/read by the
//! trace generators. This module implements the subset of JSON we need:
//! objects, arrays, strings (with escapes), f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns an error naming the missing key — manifest
    /// loading uses this for actionable failures.
    pub fn require(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Construct an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (rejects trailing garbage).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse/manifest error with byte-offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 multibyte sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("hi\nthere"));
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Json::Null));
        let re = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(re, v);
        let re2 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(re2, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
        let back = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(back, v);
    }

    #[test]
    fn integers_emit_without_decimal() {
        assert_eq!(Json::num(42.0).to_string_compact(), "42");
        assert_eq!(Json::num(0.5).to_string_compact(), "0.5");
    }

    #[test]
    fn nested_structures() {
        let v = Json::obj(vec![
            ("sizes", Json::arr(vec![Json::num(8.0), Json::num(256.0)])),
            (
                "inner",
                Json::obj(vec![("name", Json::str("assignment"))]),
            ),
        ]);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v);
        assert_eq!(
            back.get("inner").unwrap().require("name").unwrap().as_str(),
            Some("assignment")
        );
        assert!(back.get("inner").unwrap().require("nope").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).to_string_pretty(), "[]");
    }
}

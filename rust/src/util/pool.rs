//! The process-wide shared worker pool: every source of intra-round
//! parallelism — `MatchingService` batch solves, POP partition solves,
//! sharded per-job work in the simulator and the placement policies, and
//! the scenario-level experiment sweeps — leases threads from one global
//! budget instead of spinning up its own `std::thread::scope` pool per
//! call. Before this existed, `run_sim_scenarios` running one thread per
//! scenario *on top of* per-call pools inside each scenario oversubscribed
//! the machine by `scenarios × cores`; with the shared budget, whoever
//! leases first gets the threads and everything nested underneath runs
//! inline on its caller.
//!
//! Determinism contract: every entry point is a *chunked reduction* —
//! items are split into contiguous chunks, each chunk is processed in
//! input order on one worker, and per-chunk outputs are concatenated in
//! chunk order. Results are therefore positionally identical to a
//! sequential loop for **any** thread budget, including 1 (the parity
//! tests' reference side). Nothing here may reorder work or fold results
//! associatively across chunk boundaries.
//!
//! The budget comes from one knob: `tesserae --threads N` (the CLI calls
//! [`WorkerPool::install_budget`]) or the `TESSERAE_THREADS` environment
//! variable, defaulting to `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::obs::metrics;

/// Env knob read once per process when no budget was installed via CLI.
pub const THREADS_ENV: &str = "TESSERAE_THREADS";

/// The shared pool: a thread *budget* plus a lease counter. Threads are
/// not kept parked — chunks run on `std::thread::scope` workers — but the
/// lease accounting is process-wide, which is what prevents nested callers
/// from oversubscribing.
pub struct WorkerPool {
    /// Installed budget; 0 = fall back to env / available parallelism.
    installed: AtomicUsize,
    /// Extra (non-caller) worker threads currently leased, process-wide.
    leased: AtomicUsize,
}

static POOL: WorkerPool = WorkerPool {
    installed: AtomicUsize::new(0),
    leased: AtomicUsize::new(0),
};

static DEFAULT_BUDGET: OnceLock<usize> = OnceLock::new();
static OVERRIDE_LOCK: OnceLock<Mutex<()>> = OnceLock::new();

/// RAII lease of extra worker threads; returns them on drop.
struct Lease<'a> {
    pool: &'a WorkerPool,
    granted: usize,
}

impl Lease<'_> {
    /// Give back lease slots beyond `extras` immediately (chunk rounding
    /// can need fewer workers than were leased; holding the surplus for
    /// the call's duration would starve nested pool users).
    fn shrink_to(&mut self, extras: usize) {
        if self.granted > extras {
            self.pool
                .leased
                .fetch_sub(self.granted - extras, Ordering::Release);
            self.granted = extras;
        }
    }
}

impl Drop for Lease<'_> {
    fn drop(&mut self) {
        if self.granted > 0 {
            self.pool.leased.fetch_sub(self.granted, Ordering::Release);
        }
    }
}

/// Telemetry for one lease attempt (self-gated: no-ops when telemetry is
/// off). A denied lease (budget exhausted by an outer caller) is this
/// non-blocking pool's equivalent of a lease wait.
fn record_lease(granted: usize) {
    metrics::counter_add("pool.lease_attempts", 1);
    if granted > 0 {
        metrics::counter_add("pool.workers_granted", granted as u64);
    } else {
        metrics::counter_add("pool.lease_denied", 1);
    }
}

/// Guard from [`WorkerPool::budget_override`]: serializes budget
/// experiments (tests, benches) and restores the previous budget on drop.
pub struct BudgetGuard {
    prev: usize,
    _lock: MutexGuard<'static, ()>,
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        POOL.installed.store(self.prev, Ordering::Release);
    }
}

impl WorkerPool {
    /// The process-wide pool.
    pub fn global() -> &'static WorkerPool {
        &POOL
    }

    /// Install the thread budget (the `--threads` CLI knob). 0 restores
    /// the default (env var, then available parallelism).
    pub fn install_budget(&self, threads: usize) {
        self.installed.store(threads, Ordering::Release);
    }

    /// The resolved thread budget: installed > `TESSERAE_THREADS` >
    /// `available_parallelism`, never 0.
    pub fn budget(&self) -> usize {
        let installed = self.installed.load(Ordering::Acquire);
        if installed != 0 {
            return installed;
        }
        *DEFAULT_BUDGET.get_or_init(|| {
            std::env::var(THREADS_ENV)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(|| {
                    std::thread::available_parallelism()
                        .map(|p| p.get())
                        .unwrap_or(1)
                })
        })
    }

    /// Exclusive scoped budget override for tests and benches: takes a
    /// process-global lock (so concurrent overrides cannot interleave),
    /// installs `threads`, and restores the previous value when the guard
    /// drops. Work on other threads keeps running — it just sees the
    /// overridden budget, which never affects results (only wall-clock).
    pub fn budget_override(&self, threads: usize) -> BudgetGuard {
        let lock = OVERRIDE_LOCK
            .get_or_init(|| Mutex::new(()))
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let prev = self.installed.swap(threads, Ordering::AcqRel);
        BudgetGuard { prev, _lock: lock }
    }

    /// Extra workers currently leased (observability / tests).
    pub fn leased(&self) -> usize {
        self.leased.load(Ordering::Acquire)
    }

    /// Try to lease up to `want` extra workers. The caller's own thread is
    /// never counted — a budget of `B` admits at most `B - 1` leased
    /// extras, so `B` threads ever run work at once.
    fn lease_extra(&self, want: usize) -> Lease<'_> {
        let cap = self.budget().saturating_sub(1);
        let mut cur = self.leased.load(Ordering::Acquire);
        loop {
            let avail = cap.saturating_sub(cur);
            let n = want.min(avail);
            if n == 0 {
                return Lease {
                    pool: self,
                    granted: 0,
                };
            }
            match self.leased.compare_exchange_weak(
                cur,
                cur + n,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    return Lease {
                        pool: self,
                        granted: n,
                    }
                }
                Err(seen) => cur = seen,
            }
        }
    }

    /// How many workers (including the caller) a job of `items` items
    /// should use under `max_workers` (0 = budget) and a minimum chunk
    /// size of `min_per_worker` items.
    fn plan_workers(&self, items: usize, max_workers: usize, min_per_worker: usize) -> usize {
        let min_per = min_per_worker.max(1);
        if items <= min_per {
            return 1;
        }
        let budget = self.budget();
        let cap = if max_workers == 0 {
            budget
        } else {
            max_workers.min(budget)
        };
        cap.min(items.div_ceil(min_per)).max(1)
    }

    /// Chunk-level map: split `items` into contiguous chunks, run
    /// `f(chunk_start_index, chunk)` per chunk (chunk 0 on the calling
    /// thread, the rest on leased scoped workers), and concatenate the
    /// per-chunk outputs in chunk order. Each invocation must return
    /// exactly `chunk.len()` results, making the concatenation positionally
    /// identical to a sequential pass for any budget.
    pub fn run_chunks<T, U, F>(
        &self,
        items: &[T],
        max_workers: usize,
        min_per_worker: usize,
        f: F,
    ) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &[T]) -> Vec<U> + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        // Watchdog chunk-boundary checkpoint: a no-op on pool workers
        // (they never arm the thread-local deadline — their panic
        // payloads would be discarded by the joins below) and on
        // unconfigured runs.
        crate::recovery::watchdog::checkpoint();
        let want = self.plan_workers(n, max_workers, min_per_worker);
        if want <= 1 {
            let out = f(0, items);
            debug_assert_eq!(out.len(), n, "chunk closure must map 1:1");
            return out;
        }
        let mut lease = self.lease_extra(want - 1);
        // The lease span covers the whole sharded (or degraded-inline)
        // section; `granted: 0` records a denied lease — the closest
        // thing to a "lease wait" this non-blocking pool has.
        crate::obs_span!("pool.lease", { items: n, want: want - 1, granted: lease.granted });
        record_lease(lease.granted);
        let workers = 1 + lease.granted;
        if workers <= 1 {
            drop(lease);
            let out = f(0, items);
            debug_assert_eq!(out.len(), n, "chunk closure must map 1:1");
            return out;
        }
        let chunk = n.div_ceil(workers);
        // Chunk rounding can use fewer workers than leased (e.g. 4 items
        // over 3 workers → 2 chunks); return the surplus before working.
        let workers = n.div_ceil(chunk);
        lease.shrink_to(workers - 1);
        let mut parts: Vec<Vec<U>> = Vec::with_capacity(workers);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = items.chunks(chunk);
            let mine = rest.next().expect("n > 0");
            let handles: Vec<_> = rest
                .enumerate()
                .map(|(i, part)| {
                    let start = (i + 1) * chunk;
                    scope.spawn(move || {
                        crate::obs_span!("pool.chunk", { start: start, len: part.len() });
                        let out = f(start, part);
                        debug_assert_eq!(out.len(), part.len(), "chunk closure must map 1:1");
                        out
                    })
                })
                .collect();
            let out = {
                crate::obs_span!("pool.chunk", { start: 0usize, len: mine.len() });
                f(0, mine)
            };
            debug_assert_eq!(out.len(), mine.len(), "chunk closure must map 1:1");
            parts.push(out);
            for h in handles {
                parts.push(h.join().expect("pool worker panicked"));
            }
        });
        drop(lease);
        crate::recovery::watchdog::checkpoint();
        parts.into_iter().flatten().collect()
    }

    /// Item-level map over shared items: `f(item_index, &item)` in input
    /// order, chunk-scheduled like [`WorkerPool::run_chunks`].
    pub fn map<T, U, F>(
        &self,
        items: &[T],
        max_workers: usize,
        min_per_worker: usize,
        f: F,
    ) -> Vec<U>
    where
        T: Sync,
        U: Send,
        F: Fn(usize, &T) -> U + Sync,
    {
        self.run_chunks(items, max_workers, min_per_worker, |start, part| {
            part.iter()
                .enumerate()
                .map(|(i, t)| f(start + i, t))
                .collect()
        })
    }

    /// Item-level map over *mutable* items (POP's retained per-partition
    /// sub-schedulers): each item is visited exactly once, results in input
    /// order. Chunks are `chunks_mut` slices, so items never alias.
    pub fn map_mut<T, U, F>(
        &self,
        items: &mut [T],
        max_workers: usize,
        min_per_worker: usize,
        f: F,
    ) -> Vec<U>
    where
        T: Send,
        U: Send,
        F: Fn(usize, &mut T) -> U + Sync,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let inline = |items: &mut [T]| -> Vec<U> {
            items
                .iter_mut()
                .enumerate()
                .map(|(i, t)| f(i, t))
                .collect()
        };
        // Watchdog chunk-boundary checkpoint; see `run_chunks`.
        crate::recovery::watchdog::checkpoint();
        let want = self.plan_workers(n, max_workers, min_per_worker);
        if want <= 1 {
            return inline(items);
        }
        let mut lease = self.lease_extra(want - 1);
        crate::obs_span!("pool.lease", { items: n, want: want - 1, granted: lease.granted });
        record_lease(lease.granted);
        let workers = 1 + lease.granted;
        if workers <= 1 {
            drop(lease);
            return inline(items);
        }
        let chunk = n.div_ceil(workers);
        // As in `run_chunks`: chunk rounding can use fewer workers than
        // leased; return the surplus before working.
        let workers = n.div_ceil(chunk);
        lease.shrink_to(workers - 1);
        let mut parts: Vec<Vec<U>> = Vec::with_capacity(workers);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = items.chunks_mut(chunk);
            let mine = rest.next().expect("n > 0");
            let handles: Vec<_> = rest
                .enumerate()
                .map(|(i, part)| {
                    let start = (i + 1) * chunk;
                    scope.spawn(move || {
                        crate::obs_span!("pool.chunk", { start: start, len: part.len() });
                        part.iter_mut()
                            .enumerate()
                            .map(|(j, t)| f(start + j, t))
                            .collect::<Vec<U>>()
                    })
                })
                .collect();
            parts.push(
                mine.iter_mut()
                    .enumerate()
                    .map(|(j, t)| f(j, t))
                    .collect(),
            );
            for h in handles {
                parts.push(h.join().expect("pool worker panicked"));
            }
        });
        drop(lease);
        crate::recovery::watchdog::checkpoint();
        parts.into_iter().flatten().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_preserves_input_order_at_any_budget() {
        let items: Vec<usize> = (0..1000).collect();
        let expect: Vec<usize> = items.iter().map(|i| i * 3).collect();
        for budget in [1usize, 2, 8] {
            let pool = WorkerPool::global();
            let _guard = pool.budget_override(budget);
            let got = pool.map(&items, 0, 1, |_, &i| i * 3);
            assert_eq!(got, expect, "budget {budget}");
        }
    }

    #[test]
    fn run_chunks_concatenates_in_chunk_order() {
        let items: Vec<u64> = (0..500).collect();
        let pool = WorkerPool::global();
        let _guard = pool.budget_override(4);
        let got = pool.run_chunks(&items, 0, 1, |start, part| {
            // Per-chunk scratch (the MatchingService pattern): the output
            // must still be positionally exact.
            let mut scratch = 0u64;
            part.iter()
                .enumerate()
                .map(|(i, &v)| {
                    scratch += 1;
                    (start + i) as u64 * 1000 + v
                })
                .collect()
        });
        let expect: Vec<u64> = (0..500u64).map(|i| i * 1000 + i).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn map_mut_visits_every_item_once() {
        let mut items: Vec<u32> = vec![0; 777];
        let pool = WorkerPool::global();
        let _guard = pool.budget_override(6);
        let idx = pool.map_mut(&mut items, 0, 1, |i, slot| {
            *slot += 1;
            i
        });
        assert!(items.iter().all(|&v| v == 1));
        assert_eq!(idx, (0..777).collect::<Vec<_>>());
    }

    #[test]
    fn nested_calls_degrade_to_inline_under_exhausted_budget() {
        let pool = WorkerPool::global();
        let _guard = pool.budget_override(2);
        // The outer call leases the single extra worker; inner calls see
        // an exhausted budget and run inline — but results are identical.
        let items: Vec<usize> = (0..64).collect();
        let got = pool.map(&items, 0, 1, |_, &i| {
            let inner: Vec<usize> = pool.map(&(0..8).collect::<Vec<_>>(), 0, 1, |_, &j| i + j);
            inner.iter().sum::<usize>()
        });
        let expect: Vec<usize> = (0..64).map(|i| (0..8).map(|j| i + j).sum()).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn small_inputs_stay_inline() {
        let pool = WorkerPool::global();
        let items: Vec<usize> = (0..10).collect();
        // min_per_worker larger than the input: plan_workers must answer 1
        // (no lease, no threads), and the map must still be exact.
        assert_eq!(pool.plan_workers(items.len(), 0, 64), 1);
        let got = pool.map(&items, 0, 64, |_, &i| i);
        assert_eq!(got, items);
    }

    #[test]
    fn lease_spans_and_counters_recorded_when_enabled() {
        let _telemetry = crate::obs::enabled_guard(true);
        crate::obs::span::drain_events();
        let pool = WorkerPool::global();
        let _budget = pool.budget_override(4);
        let items: Vec<usize> = (0..256).collect();
        let got = pool.map(&items, 0, 1, |_, &i| i * 2);
        assert_eq!(got.len(), 256);
        let events = crate::obs::span::drain_events();
        let lease = events
            .iter()
            .find(|e| e.name == "pool.lease")
            .expect("lease span recorded");
        assert!(lease.args.iter().any(|(k, _)| *k == "granted"));
        assert!(
            events.iter().any(|e| e.name == "pool.chunk"),
            "chunk spans recorded"
        );
        let snap = crate::obs::metrics::snapshot();
        assert!(snap.counters.get("pool.lease_attempts").copied().unwrap_or(0) >= 1);
    }

    #[test]
    fn budget_override_restores_previous_value() {
        let pool = WorkerPool::global();
        let outer = pool.budget_override(3);
        assert_eq!(pool.budget(), 3);
        drop(outer);
        // Back to the default (env or available parallelism), never 0.
        assert!(pool.budget() >= 1);
    }
}

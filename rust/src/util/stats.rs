//! Summary statistics and CDF helpers used by the metrics / experiments
//! layers (JCT distributions, FTF-ratio CDFs, fidelity deviations).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) with linear interpolation; NaN-free input
/// assumed. Empty input returns 0.0.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Empirical CDF sampled at `points` evenly spaced quantiles, returned as
/// (value, cumulative_fraction) pairs — the shape the paper's Figures 9(b),
/// 10 and 13 plot.
pub fn ecdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return vec![];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..points)
        .map(|i| {
            let frac = (i + 1) as f64 / points as f64;
            let idx = ((frac * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
            (v[idx], frac)
        })
        .collect()
}

/// Relative deviation |a-b| / b (guarding b == 0), as a fraction.
pub fn rel_dev(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        if a == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (a - b).abs() / b.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert!(ecdf(&[], 5).is_empty());
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone_and_bounded() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        let cdf = ecdf(&xs, 10);
        assert_eq!(cdf.len(), 10);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert_eq!(cdf.last().unwrap().0, 9.0);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_dev_cases() {
        assert!((rel_dev(105.0, 100.0) - 0.05).abs() < 1e-12);
        assert_eq!(rel_dev(0.0, 0.0), 0.0);
        assert!(rel_dev(1.0, 0.0).is_infinite());
    }
}

//! Summary statistics and CDF helpers used by the metrics / experiments
//! layers (JCT distributions, FTF-ratio CDFs, fidelity deviations).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for fewer than two samples.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile with linear interpolation; NaN-free input assumed.
/// `p` is clamped into `0..=100` (so p<0 reads the minimum and p>100 the
/// maximum instead of indexing out of bounds). Empty input returns 0.0.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 100.0) };
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Empirical CDF sampled at `points` evenly spaced quantiles, returned as
/// (value, cumulative_fraction) pairs — the shape the paper's Figures 9(b),
/// 10 and 13 plot.
pub fn ecdf(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return vec![];
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..points)
        .map(|i| {
            let frac = (i + 1) as f64 / points as f64;
            let idx = ((frac * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
            (v[idx], frac)
        })
        .collect()
}

/// Fixed-bucket log-scale histogram for streaming latency/size
/// distributions (the telemetry registry's p50/p95/p99 source).
///
/// Buckets are logarithmic with [`Histogram::SUBDIV`] buckets per octave
/// (factor-of-two range), spanning `LO = 1e-9` (1 ns when recording
/// seconds) up to ~2^60·LO ≈ 1.15e9; values at or below `LO` land in
/// bucket 0 and values beyond the top land in a final overflow bucket.
/// Exact `min`/`max`/`sum` are tracked alongside, so percentiles are
/// clamped into the true observed range (single-sample histograms report
/// that sample exactly). Memory is a fixed ~2 KiB; recording is O(1) and
/// allocation-free after construction.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Smallest resolvable value; everything ≤ this shares bucket 0.
    pub const LO: f64 = 1e-9;
    /// Buckets per octave (resolution ≈ 2^(1/4) ≈ 19% per bucket).
    pub const SUBDIV: usize = 4;
    /// Octaves covered above `LO` before the overflow bucket.
    pub const OCTAVES: usize = 60;
    /// Total bucket count: underflow + OCTAVES·SUBDIV + overflow.
    pub const NBUCKETS: usize = 1 + Self::OCTAVES * Self::SUBDIV + 1;

    pub fn new() -> Histogram {
        Histogram {
            buckets: vec![0; Self::NBUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for `v`. NaN and values ≤ LO map to bucket 0.
    fn bucket_of(v: f64) -> usize {
        if v.is_nan() || v <= Self::LO {
            return 0;
        }
        let octs = (v / Self::LO).log2() * Self::SUBDIV as f64;
        // `v > LO` ⇒ octs > 0; floor+1 keeps bucket 0 exclusive to ≤ LO.
        (octs.floor() as usize + 1).min(Self::NBUCKETS - 1)
    }

    /// Upper edge of bucket `i` (the value reported when a percentile
    /// falls in that bucket, before clamping into [min, max]).
    fn bucket_upper(i: usize) -> f64 {
        if i == 0 {
            Self::LO
        } else {
            Self::LO * 2f64.powf(i as f64 / Self::SUBDIV as f64)
        }
    }

    pub fn record(&mut self, v: f64) {
        let v = if v.is_nan() { 0.0 } else { v };
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Arithmetic mean of recorded values; 0.0 when empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact smallest recorded value; 0.0 when empty.
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Exact largest recorded value; 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Approximate p-th percentile (`p` clamped into 0..=100): the upper
    /// edge of the bucket holding the p-th ranked sample, clamped into the
    /// exact observed [min, max]. Error is bounded by the ~19% bucket
    /// width. Empty histograms return 0.0.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = if p.is_nan() { 0.0 } else { p.clamp(0.0, 100.0) };
        // Rank of the target sample, 1-based; p=0 reads the first.
        let target = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return Histogram::bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold `other`'s samples into `self` (bucket-wise add).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples recorded in `self` but not in the earlier snapshot
    /// `earlier` (bucket-wise saturating subtraction) — the per-round
    /// delta the flight recorder stores. `min`/`max` keep the later
    /// snapshot's values (exact extremes of a delta are not recoverable).
    pub fn diff(&self, earlier: &Histogram) -> Histogram {
        let mut out = self.clone();
        for (a, b) in out.buckets.iter_mut().zip(&earlier.buckets) {
            *a = a.saturating_sub(*b);
        }
        out.count = self.count.saturating_sub(earlier.count);
        out.sum = (self.sum - earlier.sum).max(0.0);
        if out.count == 0 {
            out.min = f64::INFINITY;
            out.max = f64::NEG_INFINITY;
            out.sum = 0.0;
        }
        out
    }
}

/// Relative deviation |a-b| / b (guarding b == 0), as a fraction.
pub fn rel_dev(a: f64, b: f64) -> f64 {
    if b == 0.0 {
        if a == 0.0 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (a - b).abs() / b.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert!(ecdf(&[], 5).is_empty());
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_monotone_and_bounded() {
        let xs = [5.0, 1.0, 9.0, 3.0, 7.0];
        let cdf = ecdf(&xs, 10);
        assert_eq!(cdf.len(), 10);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert_eq!(cdf.last().unwrap().0, 9.0);
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_dev_cases() {
        assert!((rel_dev(105.0, 100.0) - 0.05).abs() < 1e-12);
        assert_eq!(rel_dev(0.0, 0.0), 0.0);
        assert!(rel_dev(1.0, 0.0).is_infinite());
    }

    #[test]
    fn percentile_single_element() {
        let xs = [7.5];
        for p in [0.0, 25.0, 50.0, 99.9, 100.0] {
            assert_eq!(percentile(&xs, p), 7.5);
        }
        assert_eq!(median(&xs), 7.5);
    }

    #[test]
    fn percentile_clamps_out_of_range_p() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        // p < 0 reads the minimum, p > 100 the maximum — no OOB panic.
        assert_eq!(percentile(&xs, -10.0), 1.0);
        assert_eq!(percentile(&xs, 150.0), 4.0);
        assert_eq!(percentile(&xs, f64::NAN), 1.0);
        assert_eq!(percentile(&[], 150.0), 0.0);
    }

    #[test]
    fn median_and_ecdf_degenerate_inputs() {
        assert_eq!(median(&[]), 0.0);
        let one = ecdf(&[3.0], 4);
        assert_eq!(one.len(), 4);
        assert!(one.iter().all(|&(v, _)| v == 3.0));
        assert!((one.last().unwrap().1 - 1.0).abs() < 1e-12);
        assert!(ecdf(&[1.0, 2.0], 0).is_empty());
    }

    #[test]
    fn histogram_empty_and_single() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);

        let mut h = Histogram::new();
        h.record(0.125);
        // A single sample is reported exactly at every percentile: the
        // bucket edge is clamped into [min, max] = [v, v].
        for p in [0.0, 50.0, 99.0, 100.0, 250.0] {
            assert_eq!(h.percentile(p), 0.125);
        }
        assert_eq!(h.mean(), 0.125);
        assert_eq!(h.min(), 0.125);
        assert_eq!(h.max(), 0.125);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        // Values at/below LO land in bucket 0 and report as min.
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-3.0);
        h.record(Histogram::LO);
        h.record(f64::NAN); // treated as 0.0
        assert_eq!(h.count(), 4);
        assert_eq!(h.percentile(100.0), Histogram::LO);
        assert_eq!(h.min(), -3.0);

        // Distinct octaves land in distinct buckets: p50 of {1ms, 1s}
        // must not collapse to one value.
        let mut h = Histogram::new();
        h.record(1e-3);
        h.record(1.0);
        let p25 = h.percentile(25.0);
        let p100 = h.percentile(100.0);
        assert!(p25 < 2e-3, "p25 {p25} should sit near the 1ms sample");
        assert_eq!(p100, 1.0);
        // Percentile approximation stays within one bucket width (~19%).
        assert!(p25 >= 1e-3, "bucket upper edge can't undercut the sample");

        // Far beyond the top edge: clamped into the overflow bucket but
        // max stays exact.
        let mut h = Histogram::new();
        h.record(1e30);
        assert_eq!(h.percentile(50.0), 1e30);
    }

    #[test]
    fn histogram_merge_matches_combined_stream() {
        // Dyadic values: float sums are exact in any accumulation order,
        // so merged and whole-stream histograms compare bit-equal.
        let xs: Vec<f64> = (1..=50).map(|i| i as f64 * 0.25).collect();
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
            whole.record(x);
        }
        a.merge(&b);
        assert_eq!(a, whole);
        assert_eq!(a.count(), 50);
        assert!((a.sum() - whole.sum()).abs() < 1e-12);
    }

    #[test]
    fn histogram_percentiles_monotone() {
        let mut h = Histogram::new();
        let mut rng_state = 0x9e3779b97f4a7c15u64;
        for _ in 0..1000 {
            // Cheap xorshift spread over several orders of magnitude.
            rng_state ^= rng_state << 13;
            rng_state ^= rng_state >> 7;
            rng_state ^= rng_state << 17;
            let v = (rng_state % 1_000_000) as f64 * 1e-6;
            h.record(v);
        }
        let mut last = f64::NEG_INFINITY;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 100.0] {
            let v = h.percentile(p);
            assert!(
                v >= last,
                "percentile must be monotone in p: p{p} gave {v} < {last}"
            );
            last = v;
        }
        assert!(h.percentile(99.0) <= h.max());
        assert!(h.percentile(50.0) >= h.min());
    }

    #[test]
    fn histogram_diff_is_the_delta() {
        let mut earlier = Histogram::new();
        earlier.record(0.5);
        earlier.record(2.0);
        let mut later = earlier.clone();
        later.record(8.0);
        later.record(8.0);
        let d = later.diff(&earlier);
        assert_eq!(d.count(), 2);
        assert!((d.sum() - 16.0).abs() < 1e-12);
        assert_eq!(d.percentile(50.0), 8.0);
        // Identical snapshots diff to an empty histogram.
        let z = earlier.diff(&earlier);
        assert!(z.is_empty());
        assert_eq!(z.percentile(99.0), 0.0);
        assert_eq!(z.sum(), 0.0);
    }
}

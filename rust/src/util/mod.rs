//! Shared substrates: PRNG, statistics, JSON, CLI parsing, benchmarking and
//! property-testing helpers. These exist because the offline crate set
//! contains none of `rand`, `serde`, `clap`, `criterion`, `proptest`.

pub mod alloc;
pub mod benchutil;
pub mod checkpoint;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;

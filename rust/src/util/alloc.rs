//! Counting global allocator for the allocation-free-round audit.
//!
//! Built with `--features alloc_audit`, every heap allocation in the
//! process is counted — globally (whole-round reporting) and per thread
//! (so a worker can measure exactly the allocations its own solve kernel
//! made, unpolluted by concurrent threads). Without the feature the
//! system allocator is untouched and every reader returns zero, so audit
//! plumbing can stay compiled into the hot path at no cost.
//!
//! The audit exists to *prove* the bench claim in ISSUE 6: steady-state
//! matching solves allocate nothing. `bench_round_pipeline` asserts
//! `kernel_allocs == 0` whenever [`audit_enabled`] is true.

#![allow(dead_code)]

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide allocation call count (all threads).
static ALLOC_CALLS: AtomicUsize = AtomicUsize::new(0);
/// Process-wide allocated byte count (all threads; frees not subtracted).
static ALLOC_BYTES: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    // `const` init: reading/writing the Cell never allocates, which keeps
    // the accounting safe to run inside `GlobalAlloc::alloc` itself.
    static THREAD_ALLOC_CALLS: Cell<usize> = const { Cell::new(0) };
}

#[inline]
fn record(bytes: usize) {
    ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
    ALLOC_BYTES.fetch_add(bytes, Ordering::Relaxed);
    // `try_with`: the TLS slot may already be torn down during thread
    // exit; missing those late frees' allocations is fine.
    let _ = THREAD_ALLOC_CALLS.try_with(|c| c.set(c.get() + 1));
}

/// Whether the counting allocator is installed in this build.
pub fn audit_enabled() -> bool {
    cfg!(feature = "alloc_audit")
}

/// Total allocation calls across all threads since process start
/// (0 when the audit feature is off).
pub fn allocs() -> usize {
    ALLOC_CALLS.load(Ordering::Relaxed)
}

/// Total bytes requested across all threads since process start
/// (0 when the audit feature is off).
pub fn bytes() -> usize {
    ALLOC_BYTES.load(Ordering::Relaxed)
}

/// Allocation calls made by the *current thread* (0 when the audit
/// feature is off). Take a delta around a kernel call to count exactly
/// its allocations, immune to concurrent threads.
pub fn thread_allocs() -> usize {
    THREAD_ALLOC_CALLS.try_with(Cell::get).unwrap_or(0)
}

#[cfg(feature = "alloc_audit")]
mod install {
    use std::alloc::{GlobalAlloc, Layout, System};

    /// [`System`] wrapper that bumps the counters on every allocation.
    struct CountingAllocator;

    unsafe impl GlobalAlloc for CountingAllocator {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            super::record(layout.size());
            System.alloc(layout)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            super::record(layout.size());
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            super::record(new_size);
            System.realloc(ptr, layout, new_size)
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAllocator = CountingAllocator;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn readers_are_consistent_with_feature_flag() {
        if !audit_enabled() {
            assert_eq!(allocs(), 0);
            assert_eq!(bytes(), 0);
            assert_eq!(thread_allocs(), 0);
        }
    }

    #[test]
    #[cfg_attr(not(feature = "alloc_audit"), ignore = "needs --features alloc_audit")]
    fn counters_advance_on_allocation() {
        let before_global = allocs();
        let before_thread = thread_allocs();
        let v: Vec<u64> = Vec::with_capacity(1 << 10);
        std::hint::black_box(&v);
        assert!(allocs() > before_global, "global counter did not advance");
        assert!(
            thread_allocs() > before_thread,
            "thread counter did not advance"
        );
        assert!(bytes() >= (1 << 10) * std::mem::size_of::<u64>());
    }

    #[test]
    #[cfg_attr(not(feature = "alloc_audit"), ignore = "needs --features alloc_audit")]
    fn thread_counter_is_per_thread() {
        let before = thread_allocs();
        std::thread::spawn(|| {
            let v: Vec<u8> = Vec::with_capacity(4096);
            std::hint::black_box(&v);
        })
        .join()
        .unwrap();
        // The spawned thread's Vec must not land on this thread's counter.
        // (Thread spawn itself allocates on *this* thread before handoff,
        // so only assert the other thread's kernel allocation is not
        // double-counted: measure a no-alloc window.)
        let mid = thread_allocs();
        let x = std::hint::black_box(41u64) + 1;
        assert_eq!(x, 42);
        assert_eq!(thread_allocs(), mid);
        assert!(mid >= before);
    }
}

//! Criterion-style micro/meso benchmark harness (the offline crate set has
//! no `criterion`). Provides warmup, adaptive iteration counts, and
//! median/mean/stddev reporting, plus the table formatting every bench
//! binary uses to print paper-style rows.

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats;

/// Result of timing one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Timing {
    pub fn summary(&self) -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>10}",
            self.name,
            self.iters,
            fmt_duration(self.median_s),
            fmt_duration(self.mean_s),
            fmt_duration(self.std_s),
        )
    }
}

/// Human-friendly duration.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.3}s", secs)
    }
}

/// Whether the bench binary was invoked in smoke mode (`--smoke` on the
/// command line, or `TESSERAE_BENCH_SMOKE=1`): CI builds every bench and
/// runs each one briefly at tiny sizes to prove the harness end-to-end.
/// Smoke runs skip size-gated acceptance asserts and never overwrite the
/// committed BENCH_*.json artifacts.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
        || std::env::var("TESSERAE_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Best-effort git revision: follow `.git/HEAD` (walking up from the
/// working directory) to the current commit hash. `None` outside a
/// checkout or on an unreadable repository — benchmark artifacts must
/// never fail over provenance.
pub fn git_rev() -> Option<String> {
    git_rev_in(&std::env::current_dir().ok()?)
}

/// [`git_rev`] from an explicit start directory (the testable core).
/// When HEAD points at a ref with no loose file (`git pack-refs`, fresh
/// clones), fall back to scanning `.git/packed-refs` instead of silently
/// dropping provenance to `None`.
fn git_rev_in(start: &std::path::Path) -> Option<String> {
    let mut dir = start.to_path_buf();
    loop {
        let git = dir.join(".git");
        if let Ok(text) = std::fs::read_to_string(git.join("HEAD")) {
            let text = text.trim();
            return match text.strip_prefix("ref: ") {
                Some(r) => {
                    let refname = r.trim();
                    std::fs::read_to_string(git.join(refname))
                        .ok()
                        .map(|h| h.trim().to_string())
                        .filter(|h| !h.is_empty())
                        .or_else(|| packed_ref(&git.join("packed-refs"), refname))
                }
                None => Some(text.to_string()).filter(|h| !h.is_empty()), // detached
            };
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Look `refname` up in a `packed-refs` file: `<hash> <refname>` lines,
/// with `#` header lines and `^` peeled-tag lines skipped.
fn packed_ref(packed: &std::path::Path, refname: &str) -> Option<String> {
    let text = std::fs::read_to_string(packed).ok()?;
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') || line.starts_with('^') {
            continue;
        }
        if let Some((hash, name)) = line.split_once(' ') {
            if name.trim() == refname && !hash.is_empty() {
                return Some(hash.to_string());
            }
        }
    }
    None
}

/// Provenance block embedded in every `BENCH_*.json` artifact: which code
/// (crate version + git revision), which machine shape (thread budget,
/// available cores), which build (feature flags), and which mode (smoke,
/// telemetry) produced the numbers.
pub fn bench_meta() -> Json {
    let pool = crate::util::pool::WorkerPool::global();
    Json::obj(vec![
        ("crate_version", Json::str(env!("CARGO_PKG_VERSION"))),
        (
            "git_rev",
            match git_rev() {
                Some(rev) => Json::str(&rev),
                None => Json::Null,
            },
        ),
        ("thread_budget", Json::num(pool.budget() as f64)),
        (
            "available_parallelism",
            Json::num(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1) as f64,
            ),
        ),
        (
            "features",
            Json::obj(vec![
                ("pjrt", Json::Bool(cfg!(feature = "pjrt"))),
                ("alloc_audit", Json::Bool(cfg!(feature = "alloc_audit"))),
            ]),
        ),
        ("smoke", Json::Bool(smoke_mode())),
        ("telemetry", Json::Bool(crate::obs::enabled())),
    ])
}

/// Benchmark runner with a wall-clock budget per case.
pub struct Bench {
    /// Target measurement time per case.
    pub budget: Duration,
    /// Warmup time per case.
    pub warmup: Duration,
    /// Minimum measured samples.
    pub min_samples: usize,
    results: Vec<Timing>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            budget: Duration::from_millis(800),
            warmup: Duration::from_millis(150),
            min_samples: 5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::default()
    }

    /// Quick-mode harness used by `cargo test` paths (tiny budget).
    pub fn quick() -> Self {
        Bench {
            budget: Duration::from_millis(60),
            warmup: Duration::from_millis(10),
            min_samples: 3,
            results: Vec::new(),
        }
    }

    /// Time `f`, preventing the result from being optimized away by
    /// feeding it through `std::hint::black_box`.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> Timing {
        // Warmup + calibration.
        let warm_start = Instant::now();
        let mut warm_iters = 0usize;
        while warm_start.elapsed() < self.warmup || warm_iters == 0 {
            std::hint::black_box(f());
            warm_iters += 1;
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
        let target = (self.budget.as_secs_f64() / per_iter.max(1e-9)) as usize;
        let samples = target.clamp(self.min_samples, 10_000);

        let mut times = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        let timing = Timing {
            name: name.to_string(),
            iters: samples,
            mean_s: stats::mean(&times),
            median_s: stats::median(&times),
            std_s: stats::std_dev(&times),
            min_s: stats::min(&times),
            max_s: stats::max(&times),
        };
        self.results.push(timing.clone());
        timing
    }

    /// Time a single invocation (for long-running end-to-end cases where
    /// repeated sampling is too expensive).
    pub fn run_once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> (Timing, T) {
        let t0 = Instant::now();
        let out = std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        let timing = Timing {
            name: name.to_string(),
            iters: 1,
            mean_s: dt,
            median_s: dt,
            std_s: 0.0,
            min_s: dt,
            max_s: dt,
        };
        self.results.push(timing.clone());
        (timing, out)
    }

    pub fn header() -> String {
        format!(
            "{:<44} {:>10} {:>12} {:>12} {:>10}",
            "benchmark", "iters", "median", "mean", "std"
        )
    }

    pub fn report(&self) -> String {
        let mut out = String::new();
        out.push_str(&Self::header());
        out.push('\n');
        out.push_str(&"-".repeat(92));
        out.push('\n');
        for t in &self.results {
            out.push_str(&t.summary());
            out.push('\n');
        }
        out
    }

    pub fn results(&self) -> &[Timing] {
        &self.results
    }
}

/// Simple fixed-width ASCII table used by experiment reports to print the
/// paper's rows ("Avg JCT", "Makespan", speedups, ...).
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for c in 0..ncols {
                if c > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<w$}", cells[c], w = widths[c]));
            }
            s.trim_end().to_string()
        };
        let mut out = line(&self.headers);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bench::quick();
        let t = b.run("busy-loop", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(t.mean_s > 0.0);
        assert!(t.iters >= 3);
        assert!(b.report().contains("busy-loop"));
    }

    #[test]
    fn run_once_returns_value() {
        let mut b = Bench::quick();
        let (t, v) = b.run_once("once", || 41 + 1);
        assert_eq!(v, 42);
        assert_eq!(t.iters, 1);
    }

    #[test]
    fn bench_meta_is_serializable_and_complete() {
        let meta = bench_meta();
        let parsed = Json::parse(&meta.to_string_compact()).unwrap();
        for key in [
            "crate_version",
            "git_rev",
            "thread_budget",
            "available_parallelism",
            "features",
            "smoke",
            "telemetry",
        ] {
            assert!(parsed.get(key).is_some(), "meta missing {key}");
        }
        assert!(parsed.get("thread_budget").and_then(Json::as_f64).unwrap() >= 1.0);
        assert!(parsed
            .get("features")
            .and_then(|f| f.get("alloc_audit"))
            .is_some());
    }

    /// Build a synthetic `.git` under a unique temp dir; returns the repo
    /// root. `loose`/`packed` control where `refs/heads/main` lives.
    fn fake_repo(tag: &str, head: &str, loose: Option<&str>, packed: Option<&str>) -> std::path::PathBuf {
        let root = std::env::temp_dir().join(format!(
            "tesserae_gitrev_{}_{tag}",
            std::process::id()
        ));
        let git = root.join(".git");
        let _ = std::fs::remove_dir_all(&root);
        std::fs::create_dir_all(git.join("refs/heads")).unwrap();
        std::fs::write(git.join("HEAD"), head).unwrap();
        if let Some(hash) = loose {
            std::fs::write(git.join("refs/heads/main"), hash).unwrap();
        }
        if let Some(contents) = packed {
            std::fs::write(git.join("packed-refs"), contents).unwrap();
        }
        root
    }

    #[test]
    fn git_rev_follows_loose_ref() {
        let root = fake_repo("loose", "ref: refs/heads/main\n", Some("abc123\n"), None);
        assert_eq!(git_rev_in(&root).as_deref(), Some("abc123"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn git_rev_falls_back_to_packed_refs() {
        let packed = "# pack-refs with: peeled fully-peeled sorted\n\
                      deadbeef01 refs/heads/other\n\
                      cafebabe02 refs/heads/main\n\
                      ^feedface03\n";
        let root = fake_repo("packed", "ref: refs/heads/main\n", None, Some(packed));
        assert_eq!(git_rev_in(&root).as_deref(), Some("cafebabe02"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn git_rev_prefers_loose_over_packed() {
        // git itself treats the loose file as authoritative when both exist.
        let packed = "stale00 refs/heads/main\n";
        let root = fake_repo(
            "both",
            "ref: refs/heads/main\n",
            Some("fresh11\n"),
            Some(packed),
        );
        assert_eq!(git_rev_in(&root).as_deref(), Some("fresh11"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn git_rev_detached_head_and_missing_ref() {
        let root = fake_repo("detached", "1234abcd\n", None, None);
        assert_eq!(git_rev_in(&root).as_deref(), Some("1234abcd"));
        let _ = std::fs::remove_dir_all(&root);

        // Ref named nowhere — loose missing, packed-refs lacks the branch.
        let root = fake_repo(
            "missing",
            "ref: refs/heads/main\n",
            None,
            Some("aa11 refs/heads/other\n"),
        );
        assert_eq!(git_rev_in(&root), None);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn fmt_duration_ranges() {
        assert!(fmt_duration(5e-9).ends_with("ns"));
        assert!(fmt_duration(5e-6).ends_with("µs"));
        assert!(fmt_duration(5e-3).ends_with("ms"));
        assert!(fmt_duration(5.0).ends_with('s'));
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["scheduler", "avg JCT (s)", "makespan (s)"]);
        t.row_strs(&["Tesserae-T", "1200.5", "86400"]);
        t.row_strs(&["Tiresias", "1944.8", "99360"]);
        let s = t.render();
        assert!(s.contains("Tesserae-T"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }
}

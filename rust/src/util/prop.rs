//! Minimal property-based testing driver (the offline crate set has no
//! `proptest`). Generates `cases` random inputs from a seeded [`Pcg64`] and
//! runs the property; on failure it reports the case index and seed so the
//! failure is reproducible.

use super::rng::Pcg64;

/// Run `property` against `cases` generated inputs. `gen` receives a fresh
/// forked RNG per case. Panics (with seed/case info) on the first violation.
pub fn forall<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut property: P)
where
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    let mut root = Pcg64::new(seed);
    for case in 0..cases {
        let mut rng = root.fork(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property '{name}' failed (seed={seed}, case={case}): {msg}\ninput: {input:?}"
            );
        }
    }
}

/// Convenience assertion for approximate float equality inside properties.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{a} != {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(
            "u64-roundtrip",
            1,
            50,
            |r| r.next_u64(),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        forall(
            "always-fails",
            2,
            10,
            |r| r.below(10),
            |_| Err("nope".to_string()),
        );
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(approx_eq(1.0, 1.1, 1e-9).is_err());
    }
}

//! Ablations (§7): parallelization strategy (Fig. 8, Fig. 15), profiling
//! noise (Fig. 16), estimator comparison (Fig. 18), plus the worked
//! examples of Fig. 1 and Fig. 7.

use std::sync::Arc;

use crate::cluster::GpuType;
use crate::estimator::{
    CachedSource, LinearBoEstimator, MatrixCompletionEstimator, OracleEstimator,
    ThroughputSource,
};
use crate::jobs::{ModelKind, ParallelismStrategy};
use crate::profiler::Profiler;
use crate::util::benchutil::Table;

use super::{run_sim_scenarios, run_sim_with_source, run_sims_parallel, Scale, SchedKind};

/// Fig. 8: normalized packed throughput of GPT3-3B on 8 GPUs under
/// different parallelism strategies and partners (incl. the OOM cell).
pub fn fig8_parallelism_packing() -> String {
    let p = Profiler::new(GpuType::A100, 42);
    let partners = [
        ModelKind::ResNet50,
        ModelKind::Vgg19,
        ModelKind::Dcgan,
        ModelKind::PointNet,
    ];
    let llm = ModelKind::Gpt3_3B;
    let n = 8;
    let dp = ParallelismStrategy::DataParallel;
    let strategies: Vec<(String, ParallelismStrategy)> = vec![
        ("DP".into(), ParallelismStrategy::DataParallel),
        (
            "Default PP".into(),
            ParallelismStrategy::default_pp(llm, n),
        ),
        (
            "Best PP".into(),
            ParallelismStrategy::Pipeline(vec![3, 3, 3, 4, 4, 5, 5, 5]),
        ),
    ];
    let mut t = Table::new(&["partner", "strategy", "norm(GPT3-3B)", "norm(partner)", "sum"]);
    for partner in partners {
        for (name, s) in &strategies {
            match p.true_normalized_pair((llm, s), (partner, &dp), n) {
                Some((a, b)) => t.row(&[
                    partner.name().into(),
                    name.clone(),
                    format!("{:.2}", a),
                    format!("{:.2}", b),
                    format!("{:.2}", a + b),
                ]),
                None => t.row(&[
                    partner.name().into(),
                    name.clone(),
                    "OOM".into(),
                    "OOM".into(),
                    "-".into(),
                ]),
            }
        }
    }
    format!(
        "Fig. 8 — packing throughput vs parallelism strategy, GPT3-3B on 8xA100\n\
         (paper: best PP beats default PP under packing; VGG-19 + default PP OOMs)\n{}",
        t.render()
    )
}

/// Fig. 15: impact of the packed-LLM strategy choice on LLM Avg. JCT
/// (paper: best-strategy selection improves LLM JCT by ~1.12x).
pub fn fig15_strategy_impact(scale: &Scale) -> String {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let kinds = [
        SchedKind::TesseraeTDp,
        SchedKind::TesseraeTDefaultPp,
        SchedKind::TesseraeT,
    ];
    let mut t = Table::new(&["strategy arm", "LLM avg JCT (s)", "all-jobs avg JCT (s)"]);
    let llm_ids: std::collections::BTreeSet<u64> = trace
        .jobs
        .iter()
        .filter(|j| j.model.is_llm())
        .map(|j| j.id)
        .collect();
    let mut llm_jcts = Vec::new();
    for (kind, r) in kinds
        .iter()
        .copied()
        .zip(run_sims_parallel(&kinds, &trace, spec, scale.seed))
    {
        let llm: Vec<f64> = r
            .outcomes
            .iter()
            .filter(|(id, _)| llm_ids.contains(*id))
            .map(|(_, o)| o.jct)
            .collect();
        let avg_llm = crate::util::stats::mean(&llm);
        llm_jcts.push(avg_llm);
        t.row(&[
            kind.label(),
            format!("{:.0}", avg_llm),
            format!("{:.0}", r.avg_jct),
        ]);
    }
    let speedup = if llm_jcts[2] > 0.0 {
        llm_jcts[1] / llm_jcts[2]
    } else {
        0.0
    };
    format!(
        "Fig. 15 — parallelization strategy impact on LLM JCT (paper: 1.12x)\n{}\nbest-vs-default-PP LLM JCT speedup: {:.2}x\n",
        t.render(),
        speedup
    )
}

/// Fig. 16: sensitivity to profiling noise n_p (paper: JCT degrades at
/// most 1.12x even at 100% noise; makespan robust).
pub fn fig16_noise_sensitivity(scale: &Scale, noise_levels: &[f64]) -> String {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    // One scenario per noise level (the clean run is always scenario 0),
    // swept across threads.
    let mut scenarios: Vec<(SchedKind, f64)> = vec![(SchedKind::TesseraeT, 0.0)];
    let mut level_idx: Vec<usize> = Vec::new();
    for &np in noise_levels {
        if np == 0.0 {
            level_idx.push(0);
        } else {
            level_idx.push(scenarios.len());
            scenarios.push((SchedKind::TesseraeT, np));
        }
    }
    let results = run_sim_scenarios(&scenarios, &trace, spec, scale.seed);
    let clean = &results[0];
    let mut t = Table::new(&["noise n_p", "avg JCT (s)", "makespan (s)", "JCT vs clean"]);
    for (&np, &idx) in noise_levels.iter().zip(&level_idx) {
        let r = &results[idx];
        t.row(&[
            format!("{:.0}%", np * 100.0),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            format!("{:.2}x", r.avg_jct / clean.avg_jct),
        ]);
    }
    format!(
        "Fig. 16 — profiling-noise sensitivity (paper: <=1.12x JCT at 100% noise)\n{}",
        t.render()
    )
}

/// Fig. 18: estimator comparison — Oracle vs Linear+BO vs matrix
/// completion (paper: Linear+BO ~ Oracle, beats matrix completion).
pub fn fig18_estimators(scale: &Scale) -> String {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let truth = Profiler::new(GpuType::A100, scale.seed);

    let sources: Vec<(String, Arc<dyn ThroughputSource>)> = vec![
        (
            "Oracle".into(),
            Arc::new(CachedSource::new(OracleEstimator::new(truth.clone()))),
        ),
        (
            "Linear+BO (ours)".into(),
            Arc::new(CachedSource::new(LinearBoEstimator::new(
                truth.clone(),
                6,
                scale.seed,
            ))),
        ),
        (
            "Matrix completion".into(),
            Arc::new(CachedSource::new(MatrixCompletionEstimator::new(
                truth.clone(),
                0.4,
                scale.seed,
            ))),
        ),
    ];

    // One pool worker per estimator: each scenario owns its source (Arc)
    // and runs against the shared immutable trace.
    let seed = scale.seed;
    let trace_ref = &trace;
    let results: Vec<(String, usize, crate::simulator::SimResult)> =
        crate::util::pool::WorkerPool::global().map(&sources, 0, 1, |_, (name, source)| {
            let samples = source.profiling_samples();
            let r = run_sim_with_source(
                SchedKind::TesseraeT,
                trace_ref,
                spec,
                seed,
                Arc::clone(source),
            );
            (name.clone(), samples, r)
        });

    let mut t = Table::new(&[
        "estimator",
        "profiling samples",
        "avg JCT (s)",
        "makespan (s)",
    ]);
    for (name, samples, r) in results {
        t.row(&[
            name,
            format!("{samples}"),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
        ]);
    }
    format!(
        "Fig. 18 — profiling-cost reduction (paper: Linear+BO ~ Oracle > matrix completion)\n{}",
        t.render()
    )
}

/// Design-choice ablation (not a paper figure): the packing-edge weight
/// threshold. Edges are created only when the combined normalized
/// throughput exceeds `min_weight`; the default 1.0 means "packing must
/// beat running the placed job alone".
pub fn ablation_pack_threshold(scale: &Scale, thresholds: &[f64]) -> String {
    use crate::estimator::{CachedSource, OracleEstimator};
    use crate::matching::HungarianEngine;
    use crate::policies::placement::PackingConfig;
    use crate::schedulers::TesseraeScheduler;
    use crate::simulator::{simulate, SimConfig};

    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let truth = Profiler::new(GpuType::A100, scale.seed);
    let mut t = Table::new(&["min edge weight", "avg JCT (s)", "makespan (s)", "migrations"]);
    for &mw in thresholds {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(CachedSource::new(OracleEstimator::new(truth.clone())));
        let mut sched = TesseraeScheduler::tesserae_t(source, Arc::new(HungarianEngine));
        sched.packing = Some(PackingConfig {
            min_weight: mw,
            ..Default::default()
        });
        let r = simulate(&trace, &mut sched, &truth, &SimConfig::new(spec));
        t.row(&[
            format!("{mw:.2}"),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            format!("{}", r.total_migrations),
        ]);
    }
    format!(
        "Ablation — packing-edge weight threshold (design choice: edges need \
         combined normalized throughput > threshold)\n{}",
        t.render()
    )
}

/// Fig. 1: the worked migration example — Gavel's policy migrates 3 jobs
/// between two nearby plans where GPU-id remapping needs 0.
pub fn fig1_migration_example() -> String {
    use crate::cluster::{ClusterSpec, PlacementPlan};
    use crate::matching::HungarianEngine;
    use crate::policies::placement::{migrate, MigrationMode};

    let spec = ClusterSpec::new(1, 4, GpuType::A100);
    let mut prev = PlacementPlan::new(4);
    prev.place(1, &[0]);
    prev.place(2, &[1, 2]);
    prev.place(4, &[3]);
    let mut next = PlacementPlan::new(4);
    next.place(4, &[0]);
    next.place(1, &[1]);
    next.place(2, &[2, 3]);

    let gavel = migrate(&spec, &prev, &next, MigrationMode::GavelBaseline, &HungarianEngine);
    let ours = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
    format!(
        "Fig. 1 — migration policy example\n\
         plans: P_i = {{(0,1),(1,2),(2,2),(3,4)}}, P_i+1 = {{(0,4),(1,1),(2,2),(3,2)}}\n\
         Gavel's policy migrates {} jobs; Tesserae's remapping migrates {}.\n",
        gavel.migrations, ours.migrations
    )
}

/// Fig. 7: the worked packing-matching example.
pub fn fig7_packing_example() -> String {
    use crate::matching::{max_weight_matching, HungarianEngine};
    let edges = vec![
        (0usize, 0usize, 0.8f64),
        (0, 1, 1.2),
        (1, 1, 0.9),
        (1, 2, 1.1),
        (2, 2, 1.3),
    ];
    let m = max_weight_matching(3, 3, &edges, &HungarianEngine);
    let total: f64 = m.iter().map(|p| p.weight).sum();
    let mut s = String::from("Fig. 7 — packing as max-weight bipartite matching\n");
    for p in &m {
        s.push_str(&format!(
            "  placed job {} <-> pending job {} (weight {:.2})\n",
            p.left + 1,
            p.right + 4,
            p.weight
        ));
    }
    s.push_str(&format!("  total combined normalized throughput: {total:.2}\n"));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig8_contains_oom_and_best_pp_win() {
        let s = fig8_parallelism_packing();
        assert!(s.contains("OOM"), "{s}");
        // Extract resnet-50 rows: Best PP sum must beat Default PP sum.
        let rows: Vec<&str> = s.lines().filter(|l| l.starts_with("resnet-50")).collect();
        let sum_of = |needle: &str| -> f64 {
            rows.iter()
                .find(|l| l.contains(needle))
                .and_then(|l| l.split_whitespace().last())
                .and_then(|v| v.parse().ok())
                .unwrap_or(0.0)
        };
        assert!(
            sum_of("Best PP") > sum_of("Default PP"),
            "{s}"
        );
    }

    #[test]
    fn fig16_zero_noise_is_identity() {
        let s = fig16_noise_sensitivity(&Scale::quick(), &[0.0, 1.0]);
        assert!(s.contains("1.00x"));
    }

    #[test]
    fn fig18_linear_bo_cheaper_than_oracle() {
        let s = fig18_estimators(&Scale::quick());
        assert!(s.contains("Oracle"));
        assert!(s.contains("Linear+BO"));
    }

    #[test]
    fn fig1_example_counts() {
        let s = fig1_migration_example();
        assert!(s.contains("migrates 3 jobs"), "{s}");
        assert!(s.contains("remapping migrates 0"), "{s}");
    }

    #[test]
    fn fig7_example_matches() {
        let s = fig7_packing_example();
        assert!(s.contains("total combined normalized throughput: 3.00"), "{s}");
    }
}

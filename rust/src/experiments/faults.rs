//! Fault-matrix experiment: how each scheduler family degrades as
//! injected failures ramp from none to harsh (§7 robustness study).
//!
//! Each cell runs the same trace under a generated [`FaultPlan`] — GPU and
//! node renewal failures, random preemptions and stragglers — and reports
//! the fault counters next to the usual JCT/FTF/migration columns. The
//! fault-free row doubles as the rate-0 bit-parity anchor: its numbers
//! must match a plain [`super::run_sim`] run exactly (asserted in tests
//! and again in `bench_faults`).

use std::sync::Arc;

use crate::cluster::{ClusterSpec, GpuType};
use crate::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use crate::faults::{FaultConfig, FaultPlan};
use crate::matching::{HungarianEngine, MatchingEngine};
use crate::profiler::Profiler;
use crate::simulator::{simulate_recoverable, RecoveryOptions, SimConfig, SimResult};
use crate::trace::Trace;
use crate::util::benchutil::Table;

use super::{build_scheduler, Scale, SchedKind};

/// [`super::run_sim`] with a fault script wired into the simulator.
pub fn run_sim_faulted(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    faults: &FaultPlan,
) -> SimResult {
    run_sim_faulted_recoverable(kind, trace, spec, seed, faults, &RecoveryOptions::default())
}

/// [`run_sim_faulted`] with crash-recovery options: the arm used by the
/// kill-and-restore CI step and `bench_recovery`, where faults, snapshots
/// and the restore path all have to compose.
pub fn run_sim_faulted_recoverable(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    faults: &FaultPlan,
    recovery: &RecoveryOptions,
) -> SimResult {
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth.clone())));
    let engine: Arc<dyn MatchingEngine> = Arc::new(HungarianEngine);
    let mut sched = build_scheduler(kind, source, engine);
    let mut cfg = SimConfig::new(spec);
    cfg.faults = faults.clone();
    simulate_recoverable(trace, sched.as_mut(), &truth, &cfg, recovery)
}

/// The MTBF sweep rows. MTBFs are per-unit rounds: on an `n`-GPU cluster
/// the expected cluster-wide GPU failure rate is `n / gpu_mtbf_rounds`
/// per round. The horizon just needs to outlast the run; events past the
/// drain round never fire.
pub fn fault_scenarios(spec: &ClusterSpec, horizon_rounds: u64) -> Vec<(String, FaultPlan)> {
    let gen = |label: &str, cfg: FaultConfig| {
        (label.to_string(), FaultPlan::generate(&cfg, spec, horizon_rounds))
    };
    vec![
        ("fault-free".to_string(), FaultPlan::none()),
        gen(
            "mild",
            FaultConfig {
                gpu_mtbf_rounds: 4_000.0,
                node_mtbf_rounds: 20_000.0,
                preempts_per_round: 0.01,
                stragglers_per_round: 0.01,
                seed: 11,
                ..Default::default()
            },
        ),
        gen(
            "paper",
            FaultConfig {
                gpu_mtbf_rounds: 1_000.0,
                node_mtbf_rounds: 6_000.0,
                preempts_per_round: 0.03,
                stragglers_per_round: 0.03,
                seed: 12,
                ..Default::default()
            },
        ),
        gen(
            "harsh",
            FaultConfig {
                gpu_mtbf_rounds: 250.0,
                node_mtbf_rounds: 1_500.0,
                repair_rounds: 15,
                preempts_per_round: 0.08,
                stragglers_per_round: 0.08,
                seed: 13,
                ..Default::default()
            },
        ),
    ]
}

/// Run the full matrix — scenario × scheduler — on the shared worker
/// pool. Each cell builds its own scheduler stack, so the results are
/// bit-identical to sequential [`run_sim_faulted`] calls, in input order.
pub fn run_fault_matrix(
    kinds: &[SchedKind],
    scenarios: &[(String, FaultPlan)],
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
) -> Vec<SimResult> {
    let cells: Vec<(SchedKind, &FaultPlan)> = scenarios
        .iter()
        .flat_map(|(_, plan)| kinds.iter().map(move |&k| (k, plan)))
        .collect();
    crate::util::pool::WorkerPool::global().map(&cells, 0, 1, |_, &(kind, plan)| {
        run_sim_faulted(kind, trace, spec, seed, plan)
    })
}

/// The printable fault matrix (the `figure faults` CLI entry).
pub fn fault_matrix(scale: &Scale) -> String {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let kinds = [SchedKind::TesseraeT, SchedKind::Gavel, SchedKind::Pop(4)];
    let scenarios = fault_scenarios(&spec, 100_000);
    let results = run_fault_matrix(&kinds, &scenarios, &trace, spec, scale.seed);

    let mut t = Table::new(&[
        "scenario",
        "scheduler",
        "avg JCT (s)",
        "worst FTF",
        "migr",
        "evict",
        "preempt",
        "replace",
        "straggle",
        "degraded",
        "unfinished",
    ]);
    for (si, (label, plan)) in scenarios.iter().enumerate() {
        for (ki, kind) in kinds.iter().enumerate() {
            let r = &results[si * kinds.len() + ki];
            t.row(&[
                format!("{label} ({} ev)", plan.len()),
                kind.label(),
                format!("{:.0}", r.avg_jct),
                format!("{:.2}", r.worst_ftf()),
                format!("{}", r.total_migrations),
                format!("{}", r.evictions),
                format!("{}", r.preemptions),
                format!("{}", r.replacements),
                format!("{}", r.stragglers),
                format!("{}", r.degraded_rounds),
                format!("{}", r.unfinished),
            ]);
        }
    }
    format!(
        "Fault matrix — MTBF sweep × schedulers (rate 0 row is the bit-parity anchor)\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Scale {
        Scale {
            jobs: 12,
            nodes: 2,
            gpus_per_node: 2,
            jobs_per_hour: 240.0,
            seed: 3,
        }
    }

    #[test]
    fn fault_free_row_matches_plain_run_bitwise() {
        let scale = tiny();
        let trace = scale.shockwave_trace();
        let spec = scale.spec(GpuType::A100);
        let faulted = run_sim_faulted(
            SchedKind::TesseraeT,
            &trace,
            spec,
            scale.seed,
            &FaultPlan::none(),
        );
        let plain = super::super::run_sim(SchedKind::TesseraeT, &trace, spec, scale.seed, 0.0);
        assert_eq!(faulted.avg_jct.to_bits(), plain.avg_jct.to_bits());
        assert_eq!(faulted.makespan.to_bits(), plain.makespan.to_bits());
        assert_eq!(faulted.total_migrations, plain.total_migrations);
        assert_eq!(faulted.rounds, plain.rounds);
        assert_eq!(faulted.evictions, 0);
        assert_eq!(faulted.degraded_rounds, 0);
    }

    #[test]
    fn matrix_cells_match_sequential_and_are_deterministic() {
        let scale = tiny();
        let trace = scale.shockwave_trace();
        let spec = scale.spec(GpuType::A100);
        let kinds = [SchedKind::TesseraeT, SchedKind::Gavel];
        // A hand-rolled harsh scenario small enough for a unit test.
        let scenarios = vec![
            ("none".to_string(), FaultPlan::none()),
            (
                "faulty".to_string(),
                FaultPlan::generate(
                    &FaultConfig {
                        gpu_mtbf_rounds: 60.0,
                        preempts_per_round: 0.05,
                        seed: 5,
                        ..Default::default()
                    },
                    &spec,
                    2_000,
                ),
            ),
        ];
        let par = run_fault_matrix(&kinds, &scenarios, &trace, spec, scale.seed);
        assert_eq!(par.len(), 4);
        let mut i = 0;
        for (_, plan) in &scenarios {
            for &kind in &kinds {
                let seq = run_sim_faulted(kind, &trace, spec, scale.seed, plan);
                assert_eq!(par[i].scheduler, seq.scheduler);
                assert_eq!(par[i].avg_jct.to_bits(), seq.avg_jct.to_bits());
                assert_eq!(par[i].total_migrations, seq.total_migrations);
                assert_eq!(par[i].evictions, seq.evictions);
                assert_eq!(par[i].preemptions, seq.preemptions);
                assert_eq!(par[i].replacements, seq.replacements);
                assert_eq!(par[i].unfinished, seq.unfinished);
                i += 1;
            }
        }
    }
}

//! Scalability experiments: Fig. 2 (decision time vs active jobs for
//! Tesserae / Gavel / POP) and Fig. 14 (scalability + Tesserae overhead
//! breakdown), plus the matching-engine comparison that exercises the AOT
//! auction artifact.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cluster::{ClusterSpec, GpuType, PlacementPlan};
use crate::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use crate::jobs::ModelKind;
use crate::matching::{HungarianEngine, MatchingEngine};
use crate::policies::JobInfo;
use crate::profiler::Profiler;
use crate::schedulers::{DecisionTimings, RoundInput, Scheduler};
use crate::sharding::ShardedCoordinator;
use crate::util::benchutil::Table;
use crate::util::checkpoint::Checkpoint;
use crate::util::json::Json;
use crate::util::rng::Pcg64;

use super::{build_scheduler, SchedKind};

/// The Fig. 2 / Fig. 14(a) job-count axis at paper scale. The LP columns
/// are feasible up to and past 2048 jobs on the revised-simplex core; the
/// sweep checkpoints per cell so a budget cap or interruption never
/// discards completed measurements.
pub const FIG2_PAPER_JOB_COUNTS: [usize; 5] = [256, 512, 1024, 2048, 3072];

/// Synthesize `n` active jobs on a cluster (the Fig. 2 workload: ResNet-50,
/// VGG-19, DCGAN, PointNet with mixed GPU demands).
pub fn synthetic_active_jobs(n: usize, seed: u64) -> Vec<JobInfo> {
    let mut rng = Pcg64::new(seed);
    let models = [
        ModelKind::ResNet50,
        ModelKind::Vgg19,
        ModelKind::Dcgan,
        ModelKind::PointNet,
    ];
    (0..n)
        .map(|i| {
            let gpus = [1u32, 1, 1, 2, 2, 4, 8][rng.below(7) as usize];
            JobInfo {
                id: i as u64,
                model: models[rng.below(4) as usize],
                num_gpus: gpus,
                arrival_time: i as f64,
                attained_service: rng.range_f64(0.0, 100_000.0),
                total_iters: rng.range_f64(1e4, 1e6),
                completed_iters: 0.0,
                rounds_received: rng.below(50),
                now: 1e6,
                iso_tput: 10.0,
            }
        })
        .collect()
}

/// Replace ~15% of `active` with fresh arrivals (new ids, drawn from the
/// same synthetic distribution): one simulator round's worth of churn.
pub fn churn_active_jobs(active: &[JobInfo], seed: u64) -> Vec<JobInfo> {
    let mut rng = Pcg64::new(seed);
    let donors = synthetic_active_jobs(active.len(), seed ^ 0xd0);
    active
        .iter()
        .zip(donors)
        .map(|(j, mut d)| {
            if rng.f64() < 0.15 {
                d.id += 1_000_000;
                d
            } else {
                j.clone()
            }
        })
        .collect()
}

/// One decision-time measurement: scheduler `kind` deciding one round with
/// `n` active jobs on `spec`. The first decision only warms caches; the
/// *measured* second decision sees a realistic consecutive round — the
/// warm round's realized plan as `prev_plan` plus ~15% job churn — so
/// cross-round state (e.g. the matching service's cost-matrix cache) is
/// exercised the way simulator steady state exercises it, rather than
/// flattered by an identical-input replay.
pub fn measure_decision(
    kind: SchedKind,
    n: usize,
    spec: &ClusterSpec,
    seed: u64,
) -> DecisionTimings {
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth)));
    let engine: Arc<dyn MatchingEngine> = Arc::new(HungarianEngine);
    let mut sched = build_scheduler(kind, source, engine);
    let active = synthetic_active_jobs(n, seed);
    let prev = PlacementPlan::new(spec.total_gpus());
    let warm = sched.decide(&RoundInput {
        now: 1e6,
        round: 10,
        active: &active,
        prev_plan: &prev,
        spec,
        health: None,
    });
    let churned = churn_active_jobs(&active, seed ^ 0x5eed);
    sched
        .decide(&RoundInput {
            now: 1e6 + 360.0,
            round: 11,
            active: &churned,
            prev_plan: &warm.plan,
            spec,
            health: None,
        })
        .timings
}

/// Fig. 2 / Fig. 14(a): decision time vs number of active jobs on a
/// 256-GPU cluster. See [`fig2_decision_time_checkpointed`]; this wrapper
/// measures without a checkpoint file.
pub fn fig2_decision_time(job_counts: &[usize], budget: Duration) -> String {
    fig2_decision_time_checkpointed(job_counts, budget, None)
}

/// Fig. 2 / Fig. 14(a) with per-cell checkpointing. `budget` caps each
/// scheduler's largest measurement — points that would exceed it are
/// skipped with a note (this *is* the result: the LP baselines blow
/// through the budget first, though the revised-simplex core pushes their
/// wall past the paper's 2048-job column). Every completed cell is
/// flushed to `ckpt` immediately, and a re-run with the same file reuses
/// stored cells instead of re-measuring (a stored cell whose measurement
/// wall exceeded the budget re-blows its column on resume).
///
/// Measurement stays sequential across cells, unlike the metric-producing
/// trace sweeps (`run_sim_scenarios`): the wall-clock decision time *is*
/// this figure's output, and running the columns concurrently would fold
/// cross-column CPU contention into the numbers. The parallelism that
/// does count — POP solving its k partition LPs on a worker pool — lives
/// *inside* the measured decision, exactly as it would in production.
pub fn fig2_decision_time_checkpointed(
    job_counts: &[usize],
    budget: Duration,
    mut ckpt: Option<&mut Checkpoint>,
) -> String {
    let spec = ClusterSpec::scale_256();
    let kinds = [
        (SchedKind::TesseraeT, "tesserae-t"),
        (SchedKind::Gavel, "gavel"),
        (SchedKind::Pop(8), "pop-8"),
    ];
    let mut t = Table::new(&["active jobs", "Tesserae-T", "Gavel", "POP-8"]);
    let mut blown = [false; 3];
    for &n in job_counts {
        let mut row = vec![format!("{n}")];
        for (i, &(kind, name)) in kinds.iter().enumerate() {
            if blown[i] {
                row.push("> budget".into());
                continue;
            }
            let key = format!("fig2/{name}/{n}");
            // A cell only counts as stored if both numeric fields parse —
            // a foreign/hand-edited file re-measures instead of rendering
            // zeros (and silently un-blowing a budget-capped column).
            let stored = ckpt.as_ref().and_then(|c| {
                let cell = c.get(&key)?;
                let total = cell.get("total_s").and_then(Json::as_f64)?;
                let wall = cell.get("wall_s").and_then(Json::as_f64)?;
                Some((total, wall))
            });
            let (total_s, wall_s) = match stored {
                Some(cell) => cell,
                None => {
                    let t0 = Instant::now();
                    let d = measure_decision(kind, n, &spec, 11);
                    let wall = t0.elapsed().as_secs_f64();
                    if let Some(c) = ckpt.as_mut() {
                        if let Err(e) = c.put(
                            &key,
                            Json::obj(vec![
                                ("scheduler", Json::str(name)),
                                ("jobs", Json::num(n as f64)),
                                ("total_s", Json::num(d.total_s)),
                                ("scheduling_s", Json::num(d.scheduling_s)),
                                ("packing_s", Json::num(d.packing_s)),
                                ("migration_s", Json::num(d.migration_s)),
                                ("wall_s", Json::num(wall)),
                            ]),
                        ) {
                            crate::obs_log!(warn, "checkpoint write failed for {key}: {e}");
                        }
                    }
                    (d.total_s, wall)
                }
            };
            row.push(format!("{total_s:.3}s"));
            if wall_s > budget.as_secs_f64() {
                blown[i] = true;
            }
        }
        t.row(&row);
    }
    format!(
        "Fig. 2 / Fig. 14(a) — decision time vs active jobs, 256 GPUs\n\
         (paper: Gavel/POP superlinear; Tesserae < 1.6s at 2048 jobs)\n{}",
        t.render()
    )
}

/// Fig. 14(b): Tesserae-T decision-time breakdown, extended with the
/// matching-service columns. See [`fig14b_breakdown_checkpointed`].
pub fn fig14b_breakdown(job_counts: &[usize]) -> String {
    fig14b_breakdown_checkpointed(job_counts, None)
}

/// Fig. 14(b) with per-cell checkpointing: Tesserae-T decision-time
/// breakdown — the legacy scheduling/packing/migration buckets plus one
/// column per pipeline stage (estimate/schedule/pack/migrate/commit) —
/// and the matching-service columns (instances generated vs pruned /
/// deduped / cache-hit / actually solved, and wall time inside engine
/// solves). Cells are keyed `fig14b/{jobs}` and reused on resume.
pub fn fig14b_breakdown_checkpointed(
    job_counts: &[usize],
    mut ckpt: Option<&mut Checkpoint>,
) -> String {
    use crate::schedulers::Stage;
    let spec = ClusterSpec::scale_256();
    let mut t = Table::new(&[
        "active jobs",
        "scheduling",
        "packing",
        "migration",
        "total",
        "estimate",
        "schedule",
        "pack",
        "migrate",
        "commit",
        "inst",
        "pruned",
        "dedup",
        "cached",
        "solved",
        "solve time",
    ]);
    // Per-stage checkpoint keys, aligned with `Stage::ALL`.
    const STAGE_FIELDS: [&str; Stage::COUNT] = [
        "estimate_s",
        "schedule_s",
        "pack_s",
        "migrate_s",
        "commit_s",
    ];
    let field = |cell: &Json, key: &str| cell.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    for &n in job_counts {
        let key = format!("fig14b/{n}");
        // Only a cell where every rendered field parses counts as stored;
        // anything else re-measures rather than rendering zeros. (Stage
        // fields are required too, so pre-pipeline checkpoints re-measure
        // instead of rendering zero stages.)
        const FIG14B_FIELDS: [&str; 10] = [
            "scheduling_s",
            "packing_s",
            "migration_s",
            "total_s",
            "instances",
            "pruned",
            "deduped",
            "cache_hits",
            "solved",
            "solve_wall_s",
        ];
        let stored = ckpt.as_ref().and_then(|c| {
            let cell = c.get(&key)?;
            for f in FIG14B_FIELDS.iter().chain(STAGE_FIELDS.iter()) {
                cell.get(f).and_then(Json::as_f64)?;
            }
            Some(cell.clone())
        });
        let cell = match stored {
            Some(cell) => cell,
            None => {
                // When telemetry is on (e.g. under --trace-out), the cell
                // also stores the metric delta this measurement produced —
                // extra keys don't invalidate stored-cell validation.
                let metrics_base = crate::obs::enabled().then(crate::obs::metrics::snapshot);
                let d = measure_decision(SchedKind::TesseraeT, n, &spec, 13);
                let m = d.matching;
                let mut fields = vec![
                    ("jobs", Json::num(n as f64)),
                    ("scheduling_s", Json::num(d.scheduling_s)),
                    ("packing_s", Json::num(d.packing_s)),
                    ("migration_s", Json::num(d.migration_s)),
                    ("total_s", Json::num(d.total_s)),
                ];
                for (name, stage) in STAGE_FIELDS.into_iter().zip(Stage::ALL) {
                    fields.push((name, Json::num(d.stage(stage))));
                }
                fields.extend([
                    ("instances", Json::num(m.instances as f64)),
                    ("pruned", Json::num(m.pruned as f64)),
                    ("deduped", Json::num(m.deduped as f64)),
                    ("cache_hits", Json::num(m.cache_hits as f64)),
                    ("solved", Json::num(m.solved as f64)),
                    ("solve_wall_s", Json::num(m.solve_wall_s)),
                ]);
                if let Some(base) = metrics_base {
                    fields.push((
                        "metrics",
                        crate::obs::metrics::snapshot().delta_since(&base).to_json(),
                    ));
                }
                let cell = Json::obj(fields);
                if let Some(c) = ckpt.as_mut() {
                    if let Err(e) = c.put(&key, cell.clone()) {
                        crate::obs_log!(warn, "checkpoint write failed for {key}: {e}");
                    }
                }
                cell
            }
        };
        let mut row = vec![
            format!("{n}"),
            format!("{:.4}s", field(&cell, "scheduling_s")),
            format!("{:.4}s", field(&cell, "packing_s")),
            format!("{:.4}s", field(&cell, "migration_s")),
            format!("{:.4}s", field(&cell, "total_s")),
        ];
        for name in STAGE_FIELDS {
            row.push(format!("{:.4}s", field(&cell, name)));
        }
        row.extend([
            format!("{}", field(&cell, "instances") as u64),
            format!("{}", field(&cell, "pruned") as u64),
            format!("{}", field(&cell, "deduped") as u64),
            format!("{}", field(&cell, "cache_hits") as u64),
            format!("{}", field(&cell, "solved") as u64),
            format!("{:.4}s", field(&cell, "solve_wall_s")),
        ]);
        t.row(&row);
    }
    format!(
        "Fig. 14(b) — Tesserae-T overhead breakdown (paper: scheduling+packing \
         grow with jobs; migration flat in jobs, set by GPU count; \
         estimate..commit are the staged-pipeline columns)\n{}",
        t.render()
    )
}

/// Options for the `figure scale` sweep: sharded-coordinator round time
/// across cluster/job scale and shard counts.
#[derive(Debug, Clone)]
pub struct ScaleSweepOpts {
    /// `(nodes, active_jobs)` grid points, smallest first (the budget cap
    /// blows per shard-count column, so ordering matters).
    pub points: Vec<(usize, usize)>,
    /// Shard counts to compare at every point; `1` is the unsharded
    /// baseline the speedup column divides by.
    pub shard_counts: Vec<usize>,
    pub gpus_per_node: usize,
    /// Per-cell wall budget: once a shard count's measurement wall exceeds
    /// it, the remaining (larger) points in that column render `> budget`.
    pub budget: Duration,
    /// Also run the small-cluster quality comparison (JCT/makespan deltas
    /// vs the unsharded full-cluster scheduler).
    pub quality: bool,
    pub seed: u64,
}

impl ScaleSweepOpts {
    /// The issue's target grid: 1k/4k/10k nodes × 10k/40k/100k jobs,
    /// shards ∈ {1, 4, 16, 64}. Unsharded at the top cells blows the
    /// budget long before 10k nodes — that column going `> budget` while
    /// sharded columns complete *is* the figure's claim.
    pub fn paper() -> ScaleSweepOpts {
        ScaleSweepOpts {
            points: vec![(1000, 10_000), (4000, 40_000), (10_000, 100_000)],
            shard_counts: vec![1, 4, 16, 64],
            gpus_per_node: 4,
            budget: Duration::from_secs(900),
            quality: true,
            seed: 17,
        }
    }

    /// CI scale: seconds, exercises the same checkpoint/budget paths.
    pub fn quick() -> ScaleSweepOpts {
        ScaleSweepOpts {
            points: vec![(16, 96), (32, 192)],
            shard_counts: vec![1, 4],
            gpus_per_node: 2,
            budget: Duration::from_secs(600),
            quality: false,
            seed: 17,
        }
    }
}

/// One sharded decision-time measurement, mirroring [`measure_decision`]
/// (warm round on an empty plan, measured churned consecutive round) but
/// returning the per-shard round walls alongside the merged timings.
pub fn measure_sharded_decision(
    shards: usize,
    n: usize,
    spec: &ClusterSpec,
    seed: u64,
) -> (DecisionTimings, Vec<f64>) {
    let truth = Profiler::new(spec.gpu_type, seed);
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(truth)));
    let engine: Arc<dyn MatchingEngine> = Arc::new(HungarianEngine);
    let mut sched = ShardedCoordinator::tesserae_t(shards, source, engine);
    let active = synthetic_active_jobs(n, seed);
    let prev = PlacementPlan::new(spec.total_gpus());
    let warm = sched.decide(&RoundInput {
        now: 1e6,
        round: 10,
        active: &active,
        prev_plan: &prev,
        spec,
        health: None,
    });
    let churned = churn_active_jobs(&active, seed ^ 0x5eed);
    let d = sched.decide(&RoundInput {
        now: 1e6 + 360.0,
        round: 11,
        active: &churned,
        prev_plan: &warm.plan,
        spec,
        health: None,
    });
    (d.timings, sched.shard_round_times().to_vec())
}

/// The sharded-coordinator scale figure: end-to-end round time and
/// max/mean per-shard round time across the `(nodes, jobs)` grid, one
/// column per shard count, plus a speedup column (unsharded total over the
/// best sharded total at that point). Cells are keyed
/// `scale/{nodes}x{jobs}/s{shards}` and follow the Fig. 2 checkpoint
/// contract: completed cells flush immediately, resume reuses any cell
/// whose stored fields all parse, and a stored cell whose wall exceeded
/// the budget re-blows its column.
pub fn scale_sweep(opts: &ScaleSweepOpts, mut ckpt: Option<&mut Checkpoint>) -> String {
    let mut headers = vec!["nodes".to_string(), "jobs".to_string()];
    for &s in &opts.shard_counts {
        headers.push(if s == 1 {
            "unsharded".to_string()
        } else {
            format!("{s} shards")
        });
    }
    headers.push("speedup".to_string());
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(&header_refs);
    let mut blown = vec![false; opts.shard_counts.len()];
    for &(nodes, jobs) in &opts.points {
        let spec = ClusterSpec::new(nodes, opts.gpus_per_node, GpuType::A100);
        let mut row = vec![format!("{nodes}"), format!("{jobs}")];
        let mut totals: Vec<Option<f64>> = Vec::with_capacity(opts.shard_counts.len());
        for (i, &s) in opts.shard_counts.iter().enumerate() {
            if blown[i] {
                row.push("> budget".into());
                totals.push(None);
                continue;
            }
            let key = format!("scale/{nodes}x{jobs}/s{s}");
            // Same stored-cell validation as Fig. 2: every rendered field
            // must parse or the cell re-measures.
            let stored = ckpt.as_ref().and_then(|c| {
                let cell = c.get(&key)?;
                let total = cell.get("total_s").and_then(Json::as_f64)?;
                let shard_max = cell.get("shard_max_s").and_then(Json::as_f64)?;
                cell.get("shard_mean_s").and_then(Json::as_f64)?;
                let wall = cell.get("wall_s").and_then(Json::as_f64)?;
                Some((total, shard_max, wall))
            });
            let (total_s, shard_max_s, wall_s) = match stored {
                Some(cell) => cell,
                None => {
                    let t0 = Instant::now();
                    let (d, shard_s) = measure_sharded_decision(s, jobs, &spec, opts.seed);
                    let wall = t0.elapsed().as_secs_f64();
                    let shard_max = shard_s.iter().cloned().fold(0.0, f64::max);
                    let shard_mean = if shard_s.is_empty() {
                        0.0
                    } else {
                        shard_s.iter().sum::<f64>() / shard_s.len() as f64
                    };
                    if let Some(c) = ckpt.as_mut() {
                        if let Err(e) = c.put(
                            &key,
                            Json::obj(vec![
                                ("nodes", Json::num(nodes as f64)),
                                ("jobs", Json::num(jobs as f64)),
                                ("shards", Json::num(s as f64)),
                                ("total_s", Json::num(d.total_s)),
                                ("shard_max_s", Json::num(shard_max)),
                                ("shard_mean_s", Json::num(shard_mean)),
                                ("wall_s", Json::num(wall)),
                            ]),
                        ) {
                            crate::obs_log!(warn, "checkpoint write failed for {key}: {e}");
                        }
                    }
                    (d.total_s, shard_max, wall)
                }
            };
            row.push(format!("{total_s:.3}s ({shard_max_s:.3}s/shard)"));
            totals.push(Some(total_s));
            if wall_s > opts.budget.as_secs_f64() {
                blown[i] = true;
            }
        }
        let base = opts
            .shard_counts
            .iter()
            .position(|&s| s == 1)
            .and_then(|i| totals[i]);
        let best = opts
            .shard_counts
            .iter()
            .zip(&totals)
            .filter(|&(&s, _)| s > 1)
            .filter_map(|(_, t)| *t)
            .fold(f64::INFINITY, f64::min);
        row.push(match base {
            Some(b) if best.is_finite() && best > 0.0 => format!("{:.1}x", b / best),
            _ => "n/a".into(),
        });
        t.row(&row);
    }
    let mut out = format!(
        "Scale — sharded coordinator round time vs cluster/job scale\n\
         (cells: end-to-end round (max shard round); speedup = unsharded / best sharded)\n{}",
        t.render()
    );
    if opts.quality {
        out.push('\n');
        out.push_str(&scale_quality_table(opts, ckpt));
    }
    out
}

/// Quality check riding the scale figure: simulated avg JCT / makespan for
/// the sharded coordinator vs the unsharded full-cluster scheduler on a
/// small cluster where both finish quickly. Sharding trades global
/// optimality for round time; the issue's acceptance bound is ±5% avg JCT.
/// Cells are keyed `scale/quality/{base|s<k>}`.
fn scale_quality_table(opts: &ScaleSweepOpts, mut ckpt: Option<&mut Checkpoint>) -> String {
    let scale = super::Scale {
        jobs: 300,
        nodes: 32,
        gpus_per_node: 4,
        jobs_per_hour: 160.0,
        seed: opts.seed,
    };
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let mut t = Table::new(&["scheduler", "avg JCT (s)", "makespan (s)", "JCT delta"]);
    let (base_jct, base_mk) = quality_cell(
        SchedKind::TesseraeT,
        "scale/quality/base",
        &trace,
        spec,
        scale.seed,
        &mut ckpt,
    );
    t.row(&[
        "tesserae-t (full cluster)".into(),
        format!("{base_jct:.0}"),
        format!("{base_mk:.0}"),
        "—".into(),
    ]);
    for &s in &opts.shard_counts {
        if s <= 1 {
            continue;
        }
        let (jct, mk) = quality_cell(
            SchedKind::Sharded(s),
            &format!("scale/quality/s{s}"),
            &trace,
            spec,
            scale.seed,
            &mut ckpt,
        );
        let delta = if base_jct > 0.0 {
            100.0 * (jct - base_jct) / base_jct
        } else {
            0.0
        };
        t.row(&[
            format!("sharded-{s}"),
            format!("{jct:.0}"),
            format!("{mk:.0}"),
            format!("{delta:+.1}%"),
        ]);
    }
    format!(
        "Quality — sharded vs full-cluster on a {} GPU cluster, {} jobs\n\
         (acceptance: |avg JCT delta| <= 5%)\n{}",
        spec.total_gpus(),
        scale.jobs,
        t.render()
    )
}

/// One checkpointed quality cell: simulate `kind` over `trace` unless the
/// cell is already stored with both metrics parseable.
fn quality_cell(
    kind: SchedKind,
    key: &str,
    trace: &crate::trace::Trace,
    spec: ClusterSpec,
    seed: u64,
    ckpt: &mut Option<&mut Checkpoint>,
) -> (f64, f64) {
    let stored = ckpt.as_ref().and_then(|c| {
        let cell = c.get(key)?;
        let jct = cell.get("avg_jct").and_then(Json::as_f64)?;
        let mk = cell.get("makespan").and_then(Json::as_f64)?;
        Some((jct, mk))
    });
    match stored {
        Some(cell) => cell,
        None => {
            let r = super::run_sim(kind, trace, spec, seed, 0.0);
            if let Some(c) = ckpt.as_mut() {
                if let Err(e) = c.put(
                    key,
                    Json::obj(vec![
                        ("scheduler", Json::str(&kind.label())),
                        ("avg_jct", Json::num(r.avg_jct)),
                        ("makespan", Json::num(r.makespan)),
                    ]),
                ) {
                    crate::obs_log!(warn, "checkpoint write failed for {key}: {e}");
                }
            }
            (r.avg_jct, r.makespan)
        }
    }
}

/// Matching-engine comparison across problem sizes: native Hungarian vs
/// native auction vs the AOT JAX/Pallas auction through PJRT.
pub fn matching_engine_comparison(sizes: &[usize], include_aot: bool) -> String {
    use crate::linalg::Matrix;
    use crate::matching::{auction, hungarian};

    let mut engines: Vec<(&str, Box<dyn Fn(&Matrix) -> f64>)> = vec![
        (
            "hungarian",
            Box::new(|c: &Matrix| hungarian::solve_min_cost(c).cost),
        ),
        (
            "auction(native)",
            Box::new(|c: &Matrix| auction::solve_min_cost(c, Some(1.0 / 16.0)).cost),
        ),
    ];
    let aot = if include_aot {
        crate::runtime::AotAssignmentEngine::discover().ok()
    } else {
        None
    };
    if let Some(engine) = aot {
        let engine = std::sync::Arc::new(engine);
        engines.push((
            "auction(AOT/PJRT)",
            Box::new(move |c: &Matrix| engine.solve_min_cost(c).cost),
        ));
    }

    let mut t = Table::new(&["n", "engine", "time", "cost"]);
    let mut rng = Pcg64::new(21);
    for &n in sizes {
        let mut cost = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                cost.set(i, j, rng.below(64) as f64 / 16.0);
            }
        }
        for (name, solve) in &engines {
            let t0 = Instant::now();
            let c = solve(&cost);
            t.row(&[
                format!("{n}"),
                name.to_string(),
                crate::util::benchutil::fmt_duration(t0.elapsed().as_secs_f64()),
                format!("{:.2}", c),
            ]);
        }
    }
    format!("Matching engines (exact cost must agree across engines)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;

    #[test]
    fn tesserae_decision_subsecond_at_scale() {
        // The headline scalability claim, scaled down for test time:
        // 256 GPUs, 512 active jobs, must decide well under the paper's
        // 1.6 s envelope.
        let spec = ClusterSpec::scale_256();
        let total = measure_decision(SchedKind::TesseraeT, 512, &spec, 3).total_s;
        assert!(total < 1.6, "decision took {total}s");
    }

    #[test]
    fn gavel_lp_superlinear_at_scale() {
        // The Fig. 2 shape: Gavel's LP-solve time grows superlinearly in
        // active jobs. (The revised simplex shrank the constant enormously
        // — the seed's absolute gavel-vs-tesserae gap at 1000 jobs was an
        // artifact of the dense tableau — but iterations × per-iteration
        // work still compound, which is the paper's actual claim.)
        let spec = ClusterSpec::scale_256();
        let small = measure_decision(SchedKind::Gavel, 250, &spec, 5).scheduling_s;
        let large = measure_decision(SchedKind::Gavel, 2000, &spec, 5).scheduling_s;
        assert!(
            large > 3.0 * small,
            "LP blow-up vanished: {small}s at 250 jobs vs {large}s at 2000"
        );
    }

    #[test]
    fn fig2_checkpoint_resumes_without_remeasuring() {
        use crate::util::checkpoint::Checkpoint;
        let path = std::env::temp_dir().join(format!(
            "tesserae_fig2_ckpt_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let counts = [40, 80];
        let budget = Duration::from_secs(600);
        let mut ckpt = Checkpoint::load_or_new(&path);
        let first = fig2_decision_time_checkpointed(&counts, budget, Some(&mut ckpt));
        assert_eq!(ckpt.len(), 6, "3 schedulers x 2 job counts");
        // Resume from disk: every cell is stored, so the re-render is
        // instant and identical.
        let mut reloaded = Checkpoint::load_or_new(&path);
        let t0 = Instant::now();
        let second = fig2_decision_time_checkpointed(&counts, budget, Some(&mut reloaded));
        assert_eq!(first, second);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "resume re-measured instead of reusing cells"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scale_sweep_checkpoint_resumes_without_remeasuring() {
        use crate::util::checkpoint::Checkpoint;
        let path = std::env::temp_dir().join(format!(
            "tesserae_scale_ckpt_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let opts = ScaleSweepOpts {
            points: vec![(4, 12), (8, 24)],
            shard_counts: vec![1, 2],
            gpus_per_node: 2,
            budget: Duration::from_secs(600),
            quality: false,
            seed: 17,
        };
        let mut ckpt = Checkpoint::load_or_new(&path);
        let first = scale_sweep(&opts, Some(&mut ckpt));
        assert_eq!(ckpt.len(), 4, "2 points x 2 shard counts");
        let mut reloaded = Checkpoint::load_or_new(&path);
        let t0 = Instant::now();
        let second = scale_sweep(&opts, Some(&mut reloaded));
        assert_eq!(first, second);
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "resume re-measured instead of reusing cells"
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn sharded_speedup_column_reads_nx() {
        // Tiny grid, no checkpoint: the sweep must render a numeric
        // speedup (unsharded over best sharded) for every point.
        let opts = ScaleSweepOpts {
            points: vec![(4, 16)],
            shard_counts: vec![1, 2],
            gpus_per_node: 2,
            budget: Duration::from_secs(600),
            quality: false,
            seed: 17,
        };
        let out = scale_sweep(&opts, None);
        assert!(out.contains('x'), "no speedup column rendered:\n{out}");
        assert!(!out.contains("n/a"), "speedup fell back to n/a:\n{out}");
    }

    #[test]
    fn breakdown_components_sum_below_total() {
        let spec = ClusterSpec::new(8, 4, GpuType::A100);
        let d = measure_decision(SchedKind::TesseraeT, 100, &spec, 7);
        let (total, s, p, m) = (d.total_s, d.scheduling_s, d.packing_s, d.migration_s);
        assert!(s + p + m <= total * 1.05, "{s}+{p}+{m} vs {total}");
    }

    #[test]
    fn matching_service_counters_ride_the_breakdown() {
        // The measured decision is a churned consecutive round on a
        // saturated cluster — the service's counters must still account
        // for every instance (prune/cache activity depends on occupancy,
        // so only the accounting invariants are asserted here; hit/prune
        // behavior is covered by the service's own tests).
        let spec = ClusterSpec::new(8, 4, GpuType::A100);
        let m = measure_decision(SchedKind::TesseraeT, 100, &spec, 7).matching;
        assert!(m.instances > 0);
        assert_eq!(m.built, m.solved, "every built matrix is solved: {m:?}");
        assert!(
            m.pruned + m.deduped + m.cache_hits + m.built >= m.instances,
            "instance accounting leaked: {m:?}"
        );
    }

    #[test]
    fn churn_preserves_count_and_replaces_some_jobs() {
        let active = synthetic_active_jobs(200, 3);
        let churned = churn_active_jobs(&active, 11);
        assert_eq!(churned.len(), active.len());
        let replaced = churned
            .iter()
            .zip(&active)
            .filter(|(c, a)| c.id != a.id)
            .count();
        assert!(replaced > 0, "churn replaced nothing");
        assert!(replaced < active.len(), "churn replaced everything");
        for c in &churned {
            assert!(c.id < 200 || c.id >= 1_000_000);
        }
    }

    #[test]
    fn synthetic_jobs_cover_all_sizes() {
        let jobs = synthetic_active_jobs(500, 9);
        for g in [1u32, 2, 4, 8] {
            assert!(jobs.iter().any(|j| j.num_gpus == g), "no {g}-GPU jobs");
        }
    }
}

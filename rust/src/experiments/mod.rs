//! Experiment registry: one entry per figure/table of the paper's
//! evaluation (§6, §7). Each function regenerates the corresponding rows;
//! the bench binaries and the `tesserae figure <id>` CLI call into here,
//! and EXPERIMENTS.md records paper-vs-measured.

pub mod ablations;
pub mod end_to_end;
pub mod faults;
pub mod scalability;

use std::sync::Arc;

use crate::cluster::{ClusterSpec, GpuType};
use crate::estimator::{CachedSource, OracleEstimator, ThroughputSource};
use crate::matching::{HungarianEngine, MatchingEngine};
use crate::policies::placement::{MigrationMode, PackingConfig, StrategyMode};
use crate::profiler::Profiler;
use crate::recovery::{BreakerConfig, BreakerScheduler};
use crate::schedulers::{
    GavelObjective, GavelScheduler, PopScheduler, Scheduler, TesseraeScheduler,
};
use crate::simulator::{simulate, simulate_recoverable, RecoveryOptions, SimConfig, SimResult};
use crate::trace::{Trace, TraceParams};

/// Scheduler configurations evaluated across the figures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedKind {
    TesseraeT,
    /// Tesserae-T with Gavel's baseline migration (Fig. 11 "w/o").
    TesseraeTBasicMigration,
    /// Tesserae-T without any packing (migration-only ablation).
    TesseraeTNoPack,
    TesseraeFtf,
    Tiresias,
    TiresiasSingle,
    Gavel,
    GavelFtf,
    Pop(usize),
    /// Sharded coordinator: k shards each running Tesserae-T, cross-shard
    /// rebalancing at the default interval.
    Sharded(usize),
    /// Fig. 15 arms: packed-LLM strategy restricted to DP / default PP.
    TesseraeTDp,
    TesseraeTDefaultPp,
    /// Compatibility arms (§2.4): Tesserae placement under other
    /// scheduling policies.
    TesseraeFifo,
    TesseraeSrtf,
}

impl SchedKind {
    pub fn label(&self) -> String {
        match self {
            SchedKind::TesseraeT => "Tesserae-T".into(),
            SchedKind::TesseraeTBasicMigration => "Tesserae-T (basic migr.)".into(),
            SchedKind::TesseraeTNoPack => "Tesserae-T (no pack)".into(),
            SchedKind::TesseraeFtf => "Tesserae-FTF".into(),
            SchedKind::Tiresias => "Tiresias".into(),
            SchedKind::TiresiasSingle => "Tiresias (Single)".into(),
            SchedKind::Gavel => "Gavel".into(),
            SchedKind::GavelFtf => "Gavel-FTF".into(),
            SchedKind::Pop(k) => format!("POP-{k}"),
            SchedKind::Sharded(k) => format!("Sharded-{k}"),
            SchedKind::TesseraeTDp => "Tesserae-T (DP)".into(),
            SchedKind::TesseraeTDefaultPp => "Tesserae-T (Def PP)".into(),
            SchedKind::TesseraeFifo => "Tesserae-FIFO".into(),
            SchedKind::TesseraeSrtf => "Tesserae-SRTF".into(),
        }
    }
}

/// Build a scheduler over a shared throughput source + matching engine.
///
/// Every arm is wrapped in a degraded-round [`BreakerScheduler`] — a
/// transparent pass-through while closed (bit-identical to the bare
/// scheduler, which is what every parity test exercises) that switches to
/// the greedy fallback after `trip_after` consecutive degraded rounds.
/// The sharded coordinator is the exception: it embeds one breaker *per
/// shard*, and an outer breaker would trip in lockstep and override that
/// finer-grained isolation.
pub fn build_scheduler(
    kind: SchedKind,
    source: Arc<dyn ThroughputSource>,
    engine: Arc<dyn MatchingEngine>,
) -> Box<dyn Scheduler> {
    let sharded = matches!(kind, SchedKind::Sharded(_));
    let inner: Box<dyn Scheduler> = match kind {
        SchedKind::TesseraeT => Box::new(TesseraeScheduler::tesserae_t(source, engine)),
        SchedKind::TesseraeTBasicMigration => {
            let mut s = TesseraeScheduler::tesserae_t(source, engine);
            s.migration = MigrationMode::GavelBaseline;
            Box::new(s)
        }
        SchedKind::TesseraeTNoPack => {
            let mut s = TesseraeScheduler::tesserae_t(source, engine);
            s.packing = None;
            Box::new(s)
        }
        SchedKind::TesseraeFtf => Box::new(TesseraeScheduler::tesserae_ftf(source, engine)),
        SchedKind::Tiresias => Box::new(TesseraeScheduler::tiresias(source, engine)),
        SchedKind::TiresiasSingle => {
            Box::new(TesseraeScheduler::tiresias_single(source, engine))
        }
        SchedKind::Gavel => Box::new(GavelScheduler::new(
            GavelObjective::Las,
            true,
            source,
            engine,
        )),
        SchedKind::GavelFtf => Box::new(GavelScheduler::new(
            GavelObjective::Ftf,
            true,
            source,
            engine,
        )),
        SchedKind::Pop(k) => Box::new(PopScheduler::new(
            k,
            GavelObjective::Las,
            true,
            source,
            engine,
        )),
        SchedKind::Sharded(k) => Box::new(crate::sharding::ShardedCoordinator::tesserae_t(
            k, source, engine,
        )),
        SchedKind::TesseraeTDp => {
            let mut s = TesseraeScheduler::tesserae_t(source, engine);
            s.packing = Some(PackingConfig {
                strategy_mode: StrategyMode::DpOnly,
                ..Default::default()
            });
            Box::new(s)
        }
        SchedKind::TesseraeTDefaultPp => {
            let mut s = TesseraeScheduler::tesserae_t(source, engine);
            s.packing = Some(PackingConfig {
                strategy_mode: StrategyMode::DefaultPp,
                ..Default::default()
            });
            Box::new(s)
        }
        SchedKind::TesseraeFifo => Box::new(TesseraeScheduler::new(
            "tesserae-fifo",
            Box::new(crate::policies::scheduling::Fifo),
            source,
            engine,
            Some(PackingConfig::default()),
            MigrationMode::Tesserae,
        )),
        SchedKind::TesseraeSrtf => Box::new(TesseraeScheduler::new(
            "tesserae-srtf",
            Box::new(crate::policies::scheduling::Srtf),
            source,
            engine,
            Some(PackingConfig::default()),
            MigrationMode::Tesserae,
        )),
    };
    if sharded {
        inner
    } else {
        Box::new(BreakerScheduler::new(inner, BreakerConfig::default()))
    }
}

/// §2.4 "Compatibility": the same placement policies under four different
/// scheduling policies — each arm must complete the trace, and packing +
/// migration benefits must not depend on the scheduling policy choice.
pub fn compatibility_study(scale: &Scale) -> String {
    use crate::util::benchutil::Table;
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let mut t = Table::new(&[
        "scheduling policy",
        "avg JCT (s)",
        "makespan (s)",
        "migrations",
    ]);
    let kinds = [
        SchedKind::TesseraeT,
        SchedKind::TesseraeFtf,
        SchedKind::TesseraeFifo,
        SchedKind::TesseraeSrtf,
    ];
    for (kind, r) in kinds
        .iter()
        .zip(run_sims_parallel(&kinds, &trace, spec, scale.seed))
    {
        t.row(&[
            kind.label(),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            format!("{}", r.total_migrations),
        ]);
    }
    format!(
        "Compatibility (§2.4): Tesserae placement under four scheduling policies\n{}",
        t.render()
    )
}

/// Experiment scale (quick mode keeps `cargo test` fast; the benches run
/// closer to paper scale).
#[derive(Debug, Clone, Copy)]
pub struct Scale {
    pub jobs: usize,
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub jobs_per_hour: f64,
    pub seed: u64,
}

impl Scale {
    /// Test scale: minutes of simulated time, sub-second runs.
    pub fn quick() -> Scale {
        Scale {
            jobs: 60,
            nodes: 4,
            gpus_per_node: 4,
            jobs_per_hour: 160.0,
            seed: 7,
        }
    }

    /// Bench scale: the paper's 80-GPU simulation cluster, reduced trace.
    pub fn standard() -> Scale {
        Scale {
            jobs: 300,
            nodes: 20,
            gpus_per_node: 4,
            jobs_per_hour: 80.0,
            seed: 7,
        }
    }

    /// Paper scale: 900 jobs on 80 GPUs (§6.3).
    pub fn paper() -> Scale {
        Scale {
            jobs: 900,
            nodes: 20,
            gpus_per_node: 4,
            jobs_per_hour: 80.0,
            seed: 7,
        }
    }

    pub fn spec(&self, gpu: GpuType) -> ClusterSpec {
        ClusterSpec::new(self.nodes, self.gpus_per_node, gpu)
    }

    pub fn shockwave_trace(&self) -> Trace {
        Trace::shockwave(&TraceParams {
            num_jobs: self.jobs,
            jobs_per_hour: self.jobs_per_hour,
            seed: self.seed,
        })
    }

    pub fn gavel_trace(&self) -> Trace {
        Trace::gavel(&TraceParams {
            num_jobs: self.jobs,
            jobs_per_hour: self.jobs_per_hour,
            seed: self.seed,
        })
    }
}

/// Run one scheduler over a trace with the oracle (cached) source.
pub fn run_sim(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    decision_noise: f64,
) -> SimResult {
    run_sim_engine(
        kind,
        trace,
        spec,
        seed,
        decision_noise,
        Arc::new(HungarianEngine),
    )
}

/// Run several (scheduler, decision-noise) scenarios over the same trace
/// on the process-wide shared worker pool. Every scenario builds its own
/// profiler/estimator/scheduler stack from `(spec, seed)` inside its
/// worker, so nothing mutable is shared and the results are bit-identical
/// to sequential [`run_sim`] calls, in input order (asserted by
/// `parallel_sweep_matches_sequential`). Because scenario workers lease
/// from the same budget as the intra-round parallelism (matching batches,
/// POP partitions, sharded per-job work), a sweep that saturates the
/// budget at scenario level automatically runs each simulation's interior
/// sequentially instead of oversubscribing the machine — see
/// EXPERIMENTS.md "Thread budgets" for choosing between the two regimes.
pub fn run_sim_scenarios(
    scenarios: &[(SchedKind, f64)],
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
) -> Vec<SimResult> {
    crate::util::pool::WorkerPool::global()
        .map(scenarios, 0, 1, |_, &(kind, noise)| {
            run_sim(kind, trace, spec, seed, noise)
        })
}

/// [`run_sim_scenarios`] for the common noise-free SchedKind sweep.
pub fn run_sims_parallel(
    kinds: &[SchedKind],
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
) -> Vec<SimResult> {
    let scenarios: Vec<(SchedKind, f64)> = kinds.iter().map(|&k| (k, 0.0)).collect();
    run_sim_scenarios(&scenarios, trace, spec, seed)
}

/// Like [`run_sim`] but with an explicit matching engine (e.g. the AOT
/// JAX/Pallas auction) — the engine-ablation path.
pub fn run_sim_engine(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    decision_noise: f64,
    engine: Arc<dyn MatchingEngine>,
) -> SimResult {
    let truth = Profiler::new(spec.gpu_type, seed);
    let visible = if decision_noise > 0.0 {
        truth.with_decision_noise(decision_noise, seed ^ 0xbeef)
    } else {
        truth.clone()
    };
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(visible)));
    let mut sched = build_scheduler(kind, source, engine);
    let cfg = SimConfig::new(spec);
    simulate(trace, sched.as_mut(), &truth, &cfg)
}

/// [`run_sim`] with crash-recovery options threaded into the simulator
/// loop: `state_dir` writes generation-numbered snapshots, `restore`
/// resumes from the newest readable one, `stop_after_round` emulates a
/// mid-flight kill. A restored run is bit-identical to the uninterrupted
/// one (asserted by the restore-parity tests and `bench_recovery`).
pub fn run_sim_recoverable(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    decision_noise: f64,
    recovery: &RecoveryOptions,
) -> SimResult {
    let truth = Profiler::new(spec.gpu_type, seed);
    let visible = if decision_noise > 0.0 {
        truth.with_decision_noise(decision_noise, seed ^ 0xbeef)
    } else {
        truth.clone()
    };
    let source: Arc<dyn ThroughputSource> =
        Arc::new(CachedSource::new(OracleEstimator::new(visible)));
    let mut sched = build_scheduler(kind, source, Arc::new(HungarianEngine));
    let cfg = SimConfig::new(spec);
    simulate_recoverable(trace, sched.as_mut(), &truth, &cfg, recovery)
}

/// Run with a caller-supplied throughput source (Fig. 18's estimators).
pub fn run_sim_with_source(
    kind: SchedKind,
    trace: &Trace,
    spec: ClusterSpec,
    seed: u64,
    source: Arc<dyn ThroughputSource>,
) -> SimResult {
    let truth = Profiler::new(spec.gpu_type, seed);
    let engine: Arc<dyn MatchingEngine> = Arc::new(HungarianEngine);
    let mut sched = build_scheduler(kind, source, engine);
    let cfg = SimConfig::new(spec);
    simulate(trace, sched.as_mut(), &truth, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_scheduler_kind_builds_and_runs() {
        let scale = Scale {
            jobs: 12,
            nodes: 2,
            gpus_per_node: 2,
            jobs_per_hour: 240.0,
            seed: 3,
        };
        let trace = scale.shockwave_trace();
        for kind in [
            SchedKind::TesseraeT,
            SchedKind::TesseraeTBasicMigration,
            SchedKind::TesseraeTNoPack,
            SchedKind::TesseraeFtf,
            SchedKind::Tiresias,
            SchedKind::TiresiasSingle,
            SchedKind::Gavel,
            SchedKind::GavelFtf,
            SchedKind::Pop(2),
            SchedKind::Sharded(2),
            SchedKind::TesseraeTDp,
            SchedKind::TesseraeTDefaultPp,
            SchedKind::TesseraeFifo,
            SchedKind::TesseraeSrtf,
        ] {
            let r = run_sim(kind, &trace, scale.spec(GpuType::A100), 3, 0.0);
            assert_eq!(r.unfinished, 0, "{} left jobs unfinished", kind.label());
            assert!(r.avg_jct > 0.0);
        }
    }

    #[test]
    fn parallel_sweep_matches_sequential() {
        // Per-scenario seeding: the threaded sweep must reproduce the
        // sequential results bit-for-bit, in input order.
        let scale = Scale {
            jobs: 15,
            nodes: 2,
            gpus_per_node: 2,
            jobs_per_hour: 240.0,
            seed: 5,
        };
        let trace = scale.shockwave_trace();
        let spec = scale.spec(GpuType::A100);
        let scenarios = [
            (SchedKind::TesseraeT, 0.0),
            (SchedKind::Tiresias, 0.0),
            (SchedKind::Gavel, 0.0),
            (SchedKind::TesseraeT, 0.5),
        ];
        let par = run_sim_scenarios(&scenarios, &trace, spec, scale.seed);
        assert_eq!(par.len(), scenarios.len());
        for ((kind, noise), r) in scenarios.iter().zip(&par) {
            let s = run_sim(*kind, &trace, spec, scale.seed, *noise);
            assert_eq!(r.scheduler, s.scheduler);
            assert_eq!(r.avg_jct.to_bits(), s.avg_jct.to_bits());
            assert_eq!(r.makespan.to_bits(), s.makespan.to_bits());
            assert_eq!(r.total_migrations, s.total_migrations);
            assert_eq!(r.rounds, s.rounds);
        }
    }

    #[test]
    fn sweep_under_tiny_thread_budget_matches_unbounded_sweep() {
        // With a budget of 2 the scenario layer exhausts the pool and
        // every simulation's interior runs inline; results must still be
        // bit-identical to the unbounded sweep (chunking never reorders).
        let scale = Scale {
            jobs: 12,
            nodes: 2,
            gpus_per_node: 2,
            jobs_per_hour: 240.0,
            seed: 9,
        };
        let trace = scale.shockwave_trace();
        let spec = scale.spec(GpuType::A100);
        let scenarios = [
            (SchedKind::TesseraeT, 0.0),
            (SchedKind::Gavel, 0.0),
            (SchedKind::Pop(2), 0.0),
            (SchedKind::Tiresias, 0.0),
        ];
        let bounded = {
            let _budget = crate::util::pool::WorkerPool::global().budget_override(2);
            run_sim_scenarios(&scenarios, &trace, spec, scale.seed)
        };
        let unbounded = run_sim_scenarios(&scenarios, &trace, spec, scale.seed);
        for (a, b) in bounded.iter().zip(&unbounded) {
            assert_eq!(a.scheduler, b.scheduler);
            assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits());
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(a.total_migrations, b.total_migrations);
            assert_eq!(a.rounds, b.rounds);
        }
    }
}

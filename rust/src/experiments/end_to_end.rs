//! End-to-end scheduling comparisons: Figures 9, 11, 12, 13, 17 and the
//! simulator-fidelity study (Fig. 10 / Table 2).

use crate::cluster::GpuType;
use crate::coordinator::{run_cluster, ExecConfig, ExecJob};
use crate::simulator::SimResult;
use crate::util::benchutil::Table;
use crate::util::stats;

use super::{run_sims_parallel, Scale, SchedKind};

fn ratio(base: f64, ours: f64) -> String {
    if ours > 0.0 {
        format!("{:.2}x", base / ours)
    } else {
        "-".into()
    }
}

/// Fig. 9: Tesserae-T vs Tiresias (the physical-cluster comparison; here on
/// the simulator at the paper's 32-GPU shape). Returns the rendered table
/// and the two results (for CDF reporting).
pub fn fig9_tesserae_vs_tiresias(scale: &Scale) -> (String, SimResult, SimResult) {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let mut results = run_sims_parallel(
        &[SchedKind::TesseraeT, SchedKind::Tiresias],
        &trace,
        spec,
        scale.seed,
    );
    let base = results.pop().unwrap();
    let ours = results.pop().unwrap();

    let mut t = Table::new(&[
        "scheduler",
        "avg JCT (s)",
        "makespan (s)",
        "migrations",
        "JCT speedup",
        "makespan speedup",
    ]);
    for r in [&ours, &base] {
        t.row(&[
            r.scheduler.clone(),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            format!("{}", r.total_migrations),
            ratio(base.avg_jct, r.avg_jct),
            ratio(base.makespan, r.makespan),
        ]);
    }
    let mut out =
        String::from("Fig. 9 — Tesserae-T vs Tiresias (paper: JCT 1.62x, makespan 1.15x)\n");
    out.push_str(&t.render());
    out.push_str("\nJCT CDF (value at percentile):\n");
    out.push_str(&cdf_rows(&[("tesserae-t", &ours), ("tiresias", &base)]));
    (out, ours, base)
}

/// Render JCT percentiles for Fig. 9(b)/Fig. 10-style CDF comparison.
pub fn cdf_rows(results: &[(&str, &SimResult)]) -> String {
    let mut t = Table::new(&["scheduler", "p25", "p50", "p75", "p90", "p99"]);
    for (name, r) in results {
        let jcts = r.jcts();
        t.row(&[
            name.to_string(),
            format!("{:.0}", stats::percentile(&jcts, 25.0)),
            format!("{:.0}", stats::percentile(&jcts, 50.0)),
            format!("{:.0}", stats::percentile(&jcts, 75.0)),
            format!("{:.0}", stats::percentile(&jcts, 90.0)),
            format!("{:.0}", stats::percentile(&jcts, 99.0)),
        ]);
    }
    t.render()
}

/// Fig. 11: Tesserae-T vs the optimization-based baselines (Gavel and
/// partition-parallel POP-8), plus the migration-algorithm ablation
/// (paper: packing JCT 1.15–1.41x; migration −36%, JCT 1.22x).
pub fn fig11_vs_gavel(scale: &Scale) -> String {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let mut results = run_sims_parallel(
        &[
            SchedKind::TesseraeT,
            SchedKind::TesseraeTBasicMigration,
            SchedKind::Gavel,
            SchedKind::Pop(8),
        ],
        &trace,
        spec,
        scale.seed,
    );
    let pop = results.pop().unwrap();
    let gavel = results.pop().unwrap();
    let basic = results.pop().unwrap();
    let ours = results.pop().unwrap();

    let mut t = Table::new(&[
        "scheduler",
        "avg JCT (s)",
        "makespan (s)",
        "migrations",
        "JCT vs Gavel",
    ]);
    for r in [&ours, &basic, &gavel, &pop] {
        t.row(&[
            r.scheduler.clone(),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            format!("{}", r.total_migrations),
            ratio(gavel.avg_jct, r.avg_jct),
        ]);
    }
    let migr_reduction = if basic.total_migrations > 0 {
        100.0 * (1.0 - ours.total_migrations as f64 / basic.total_migrations as f64)
    } else {
        0.0
    };
    // `basic` runs the identical policy stack with only the migration
    // algorithm swapped, so the migration delta is the paper's ablation.
    format!(
        "Fig. 11 — vs optimization-based (paper: JCT 1.41x vs Gavel; migrations -36%)\n{}\nmigration reduction vs basic algorithm: {:.0}%\n",
        t.render(),
        migr_reduction
    )
}

/// Fig. 12: Tesserae-T vs Tiresias (Single); (a) A100, (b) V100
/// (paper: 1.54x/1.20x on A100; 1.08x/1.03x on V100).
pub fn fig12_vs_tiresias_single(scale: &Scale) -> String {
    let trace = scale.shockwave_trace();
    let mut out = String::from(
        "Fig. 12 — vs heuristic (paper: A100 1.54x JCT / 1.20x makespan; V100 1.08x / 1.03x)\n",
    );
    for gpu in [GpuType::A100, GpuType::V100] {
        let spec = scale.spec(gpu);
        let mut results = run_sims_parallel(
            &[SchedKind::TesseraeT, SchedKind::TiresiasSingle],
            &trace,
            spec,
            scale.seed,
        );
        let single = results.pop().unwrap();
        let ours = results.pop().unwrap();
        let mut t = Table::new(&["scheduler", "avg JCT (s)", "makespan (s)", "JCT speedup"]);
        for r in [&ours, &single] {
            t.row(&[
                r.scheduler.clone(),
                format!("{:.0}", r.avg_jct),
                format!("{:.0}", r.makespan),
                ratio(single.avg_jct, r.avg_jct),
            ]);
        }
        out.push_str(&format!("\n[{}]\n{}", gpu.name(), t.render()));
    }
    out
}

/// Fig. 13: finish-time-fairness CDF, Tesserae-FTF vs Gavel-FTF
/// (paper: worst-case FTF ratio 3.77x better).
pub fn fig13_ftf(scale: &Scale) -> String {
    let trace = scale.shockwave_trace();
    let spec = scale.spec(GpuType::A100);
    let mut results = run_sims_parallel(
        &[SchedKind::TesseraeFtf, SchedKind::GavelFtf],
        &trace,
        spec,
        scale.seed,
    );
    let gavel = results.pop().unwrap();
    let ours = results.pop().unwrap();

    let mut t = Table::new(&["scheduler", "p50 FTF", "p90 FTF", "p99 FTF", "worst FTF"]);
    for r in [&ours, &gavel] {
        let f = r.ftfs();
        t.row(&[
            r.scheduler.clone(),
            format!("{:.2}", stats::percentile(&f, 50.0)),
            format!("{:.2}", stats::percentile(&f, 90.0)),
            format!("{:.2}", stats::percentile(&f, 99.0)),
            format!("{:.2}", r.worst_ftf()),
        ]);
    }
    format!(
        "Fig. 13 — FTF CDF (paper: worst ratio 3.77x better than Gavel-FTF)\n{}\nworst-FTF improvement: {}\n",
        t.render(),
        ratio(gavel.worst_ftf(), ours.worst_ftf())
    )
}

/// Fig. 17: the Gavel-generator workload (paper: JCT up to 1.87x,
/// makespan 1.32x across baselines).
pub fn fig17_gavel_trace(scale: &Scale) -> String {
    let trace = scale.gavel_trace();
    let spec = scale.spec(GpuType::A100);
    let kinds = [
        SchedKind::TesseraeT,
        SchedKind::Tiresias,
        SchedKind::TiresiasSingle,
        SchedKind::Gavel,
        SchedKind::Pop(8),
    ];
    let results: Vec<SimResult> = run_sims_parallel(&kinds, &trace, spec, scale.seed);
    let ours = &results[0];
    let mut t = Table::new(&["scheduler", "avg JCT (s)", "makespan (s)", "Tesserae speedup"]);
    for r in &results {
        t.row(&[
            r.scheduler.clone(),
            format!("{:.0}", r.avg_jct),
            format!("{:.0}", r.makespan),
            ratio(r.avg_jct, ours.avg_jct),
        ]);
    }
    format!(
        "Fig. 17 — Gavel-trace workload (paper: Tesserae-T up to 1.87x JCT, 1.32x makespan)\n{}",
        t.render()
    )
}

/// Fig. 3 + Fig. 9-analog on the *real-execution* cluster: measured
/// checkpoint traffic/time and migration counts with and without the
/// graph-matching migration policy, over actual PJRT training jobs.
pub fn fig3_real_migration_overhead(round_wall_s: f64) -> anyhow::Result<String> {
    let jobs: Vec<ExecJob> = (0..6)
        .map(|i| ExecJob {
            id: i + 1,
            model: if i % 3 == 0 { "gpt-micro" } else { "gpt-nano" }.into(),
            num_gpus: if i == 2 { 2 } else { 1 },
            arrival_round: i / 2,
            total_steps: 40 + 10 * i,
        })
        .collect();
    let mut out = String::from(
        "Fig. 3 — measured migration overhead on the real-execution cluster\n",
    );
    let mut t = Table::new(&[
        "migration policy",
        "migrations",
        "ckpt bytes",
        "ckpt time (s)",
        "avg JCT (rounds)",
        "wall (s)",
    ]);
    for (label, mode) in [
        ("tesserae (Alg. 2+3)", crate::policies::placement::MigrationMode::Tesserae),
        ("gavel baseline", crate::policies::placement::MigrationMode::GavelBaseline),
    ] {
        let cfg = ExecConfig {
            round_wall_s,
            migration: mode,
            ..Default::default()
        };
        let r = run_cluster(&jobs, &cfg)?;
        t.row(&[
            label.to_string(),
            format!("{}", r.total_migrations),
            format!("{}", r.checkpoint_bytes),
            format!("{:.3}", r.checkpoint_time_s),
            format!("{:.1}", r.avg_jct_rounds),
            format!("{:.1}", r.wall_s),
        ]);
    }
    out.push_str(&t.render());
    Ok(out)
}

/// Table 2 / Fig. 10: simulator fidelity — run the same workload on the
/// real-execution cluster and on the simulator (calibrated to measured
/// isolated throughput) and report the JCT/makespan deviation over
/// `reps` seeds.
pub fn table2_fidelity(reps: usize, round_wall_s: f64) -> anyhow::Result<String> {
    use crate::cluster::ClusterSpec;
    use crate::simulator::{simulate, SimConfig};
    use crate::trace::Trace;

    let jobs: Vec<ExecJob> = (0..5)
        .map(|i| ExecJob {
            id: i + 1,
            model: if i % 2 == 0 { "gpt-nano" } else { "gpt-micro" }.into(),
            num_gpus: 1,
            arrival_round: i / 2,
            total_steps: 30 + 10 * i,
        })
        .collect();

    let mut jct_devs = Vec::new();
    let mut makespan_devs = Vec::new();
    for rep in 0..reps {
        let cfg = ExecConfig {
            round_wall_s,
            seed: 1 + rep as u64,
            ..Default::default()
        };
        let real = run_cluster(&jobs, &cfg)?;

        // Calibrate the simulator: isolated steps/round measured from the
        // real run's per-job steps, mapped onto the synthetic models.
        let truth = crate::profiler::Profiler::new(GpuType::A100, 1 + rep as u64);
        let sim_jobs: Vec<crate::jobs::Job> = jobs
            .iter()
            .map(|j| {
                let model = crate::coordinator::scheduling_model(&j.model);
                let (_, tput) = truth.best_isolated(model, j.num_gpus);
                // Real rounds-to-completion at isolated speed becomes the
                // simulator's total work at synthetic speed.
                let real_rounds = real.jobs[&j.id].jct_rounds.max(1) as f64;
                let _ = real_rounds;
                let steps_per_round = real.jobs[&j.id].steps as f64
                    / real.jobs[&j.id].jct_rounds.max(1) as f64;
                let rounds_needed = j.total_steps as f64 / steps_per_round.max(1e-9);
                crate::jobs::Job {
                    id: j.id,
                    model,
                    num_gpus: j.num_gpus,
                    arrival_time: j.arrival_round as f64 * 360.0,
                    total_iters: rounds_needed * 360.0 * tput,
                    batch_size: 32,
                }
            })
            .collect();
        let trace = Trace { jobs: sim_jobs };
        let spec = ClusterSpec::new(cfg.num_nodes, cfg.gpus_per_node, GpuType::A100);
        let source: std::sync::Arc<dyn crate::estimator::ThroughputSource> = std::sync::Arc::new(
            crate::estimator::CachedSource::new(crate::estimator::OracleEstimator::new(
                truth.clone(),
            )),
        );
        let engine: std::sync::Arc<dyn crate::matching::MatchingEngine> =
            std::sync::Arc::new(crate::matching::HungarianEngine);
        let mut sched = crate::schedulers::TesseraeScheduler::tesserae_t(source, engine);
        let mut sim_cfg = SimConfig::new(spec);
        sim_cfg.migration_overhead_s = 40.0;
        let sim = simulate(&trace, &mut sched, &truth, &sim_cfg);

        let real_jct = real.avg_jct_rounds * 360.0;
        let sim_jct = sim.avg_jct;
        jct_devs.push(stats::rel_dev(sim_jct, real_jct) * 100.0);
        let real_makespan = real.makespan_rounds as f64 * 360.0;
        makespan_devs.push(stats::rel_dev(sim.makespan, real_makespan) * 100.0);
    }

    let mut t = Table::new(&["metric", "mean deviation (%)", "std (%)"]);
    t.row(&[
        "avg JCT".into(),
        format!("{:.2}", stats::mean(&jct_devs)),
        format!("{:.2}", stats::std_dev(&jct_devs)),
    ]);
    t.row(&[
        "makespan".into(),
        format!("{:.2}", stats::mean(&makespan_devs)),
        format!("{:.2}", stats::std_dev(&makespan_devs)),
    ]);
    Ok(format!(
        "Table 2 — simulator fidelity vs real execution (paper: max 5.42% deviation)\n{}",
        t.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::run_sim;

    #[test]
    fn fig9_shape_holds_at_quick_scale() {
        let (report, ours, base) = fig9_tesserae_vs_tiresias(&Scale::quick());
        assert!(report.contains("Tesserae"));
        assert!(
            ours.avg_jct < base.avg_jct,
            "tesserae {} vs tiresias {}",
            ours.avg_jct,
            base.avg_jct
        );
    }

    #[test]
    fn fig11_migration_ablation_reduces_migrations() {
        let scale = Scale::quick();
        let trace = scale.shockwave_trace();
        let spec = scale.spec(GpuType::A100);
        let ours = run_sim(SchedKind::TesseraeT, &trace, spec, scale.seed, 0.0);
        let basic = run_sim(
            SchedKind::TesseraeTBasicMigration,
            &trace,
            spec,
            scale.seed,
            0.0,
        );
        assert!(
            ours.total_migrations <= basic.total_migrations,
            "{} > {}",
            ours.total_migrations,
            basic.total_migrations
        );
    }

    #[test]
    fn fig12_v100_reduces_gains() {
        let scale = Scale::quick();
        let trace = scale.shockwave_trace();
        let a100 = scale.spec(GpuType::A100);
        let v100 = scale.spec(GpuType::V100);
        let gain = |spec| {
            let ours = run_sim(SchedKind::TesseraeT, &trace, spec, scale.seed, 0.0);
            let single = run_sim(SchedKind::TiresiasSingle, &trace, spec, scale.seed, 0.0);
            single.avg_jct / ours.avg_jct
        };
        let g_a = gain(a100);
        let g_v = gain(v100);
        // Adaptability shape: speedup exists on A100 and shrinks on V100.
        assert!(g_a >= 0.95, "a100 gain {g_a}");
        assert!(g_v <= g_a + 0.25, "v100 gain {g_v} should not exceed a100 {g_a}");
    }

    #[test]
    fn fig13_report_renders() {
        let s = fig13_ftf(&Scale::quick());
        assert!(s.contains("worst-FTF improvement"));
    }
}

//! Gaussian-process regression (RBF kernel) and expected improvement —
//! the surrogate behind the Bayesian-optimization throughput estimator
//! (§4.3 "Minimizing Profiling Cost", Fig. 18).
//!
//! A native implementation (Cholesky via `linalg`) that doubles as the
//! correctness oracle for the AOT-compiled L2 `gp` artifact.

use crate::linalg::{cholesky, solve_lower, solve_lower_t, Matrix};

/// RBF-kernel GP posterior over f64 feature vectors.
#[derive(Debug, Clone)]
pub struct Gp {
    x: Vec<Vec<f64>>,
    lengthscale: f64,
    signal_var: f64,
    chol: Matrix,
    alpha: Vec<f64>,
    y_mean: f64,
}

fn rbf(a: &[f64], b: &[f64], lengthscale: f64, signal_var: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    signal_var * (-0.5 * d2 / (lengthscale * lengthscale)).exp()
}

impl Gp {
    /// Fit a GP to observations `(x, y)`. `noise_var` regularizes the
    /// kernel matrix (and models profiling noise).
    pub fn fit(
        x: Vec<Vec<f64>>,
        y: &[f64],
        lengthscale: f64,
        signal_var: f64,
        noise_var: f64,
    ) -> Result<Gp, String> {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "GP needs at least one observation");
        let n = x.len();
        let y_mean = y.iter().sum::<f64>() / n as f64;
        let mut k = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut v = rbf(&x[i], &x[j], lengthscale, signal_var);
                if i == j {
                    v += noise_var;
                }
                k.set(i, j, v);
            }
        }
        let chol = cholesky(&k)?;
        let centered: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
        let alpha = solve_lower_t(&chol, &solve_lower(&chol, &centered));
        Ok(Gp {
            x,
            lengthscale,
            signal_var,
            chol,
            alpha,
            y_mean,
        })
    }

    /// Posterior mean and variance at a query point.
    pub fn predict(&self, q: &[f64]) -> (f64, f64) {
        let n = self.x.len();
        let kstar: Vec<f64> = (0..n)
            .map(|i| rbf(&self.x[i], q, self.lengthscale, self.signal_var))
            .collect();
        let mean = self.y_mean
            + kstar
                .iter()
                .zip(&self.alpha)
                .map(|(k, a)| k * a)
                .sum::<f64>();
        let v = solve_lower(&self.chol, &kstar);
        let var = (self.signal_var - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (mean, var)
    }

    /// Expected improvement over `best_y` (maximization).
    pub fn expected_improvement(&self, q: &[f64], best_y: f64) -> f64 {
        let (mu, var) = self.predict(q);
        let sigma = var.sqrt();
        if sigma < 1e-12 {
            return (mu - best_y).max(0.0);
        }
        let z = (mu - best_y) / sigma;
        (mu - best_y) * std_normal_cdf(z) + sigma * std_normal_pdf(z)
    }

    pub fn num_observations(&self) -> usize {
        self.x.len()
    }
}

fn std_normal_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Φ(z) via the erf-free Abramowitz–Stegun 7.1.26 approximation (|err|<1.5e-7).
fn std_normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erf_abs = 1.0 - poly * (-x * x).exp();
    let erf = if x >= 0.0 { erf_abs } else { -erf_abs };
    0.5 * (1.0 + erf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn interpolates_observations() {
        let x = vec![vec![0.0], vec![1.0], vec![2.0]];
        let y = [1.0, 3.0, 2.0];
        let gp = Gp::fit(x.clone(), &y, 0.7, 1.0, 1e-6).unwrap();
        for (xi, yi) in x.iter().zip(&y) {
            let (mu, var) = gp.predict(xi);
            assert!((mu - yi).abs() < 1e-2, "mu {mu} vs {yi}");
            assert!(var < 1e-3);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let gp = Gp::fit(vec![vec![0.0]], &[1.0], 0.5, 1.0, 1e-6).unwrap();
        let (_, v_near) = gp.predict(&[0.1]);
        let (_, v_far) = gp.predict(&[5.0]);
        assert!(v_far > v_near);
        assert!((v_far - 1.0).abs() < 1e-3, "far variance ~ prior");
    }

    #[test]
    fn mean_reverts_to_prior_far_away() {
        let gp = Gp::fit(vec![vec![0.0], vec![1.0]], &[2.0, 4.0], 0.5, 1.0, 1e-6).unwrap();
        let (mu, _) = gp.predict(&[100.0]);
        assert!((mu - 3.0).abs() < 1e-6, "prior mean is the data mean, got {mu}");
    }

    #[test]
    fn normal_cdf_sane() {
        assert!((std_normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((std_normal_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((std_normal_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    #[test]
    fn ei_prefers_unexplored_high_mean() {
        let x = vec![vec![0.0], vec![2.0]];
        let y = [1.0, 2.0];
        let gp = Gp::fit(x, &y, 0.8, 1.0, 1e-4).unwrap();
        let ei_known = gp.expected_improvement(&[0.0], 2.0);
        let ei_unknown = gp.expected_improvement(&[4.0], 2.0);
        assert!(ei_unknown > ei_known);
    }

    #[test]
    fn bo_loop_finds_quadratic_max() {
        // Optimize f(x) = -(x-1.3)^2 over a grid via EI; BO should locate
        // the max within a handful of profiles.
        let f = |x: f64| -(x - 1.3) * (x - 1.3);
        let grid: Vec<f64> = (0..41).map(|i| i as f64 * 0.1 - 1.0).collect();
        let mut rng = Pcg64::new(2);
        let mut obs_x = vec![
            vec![grid[rng.below(41) as usize]],
            vec![grid[rng.below(41) as usize]],
        ];
        let mut obs_y: Vec<f64> = obs_x.iter().map(|x| f(x[0])).collect();
        for _ in 0..8 {
            let gp = Gp::fit(obs_x.clone(), &obs_y, 0.5, 1.0, 1e-6).unwrap();
            let best = obs_y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let next = grid
                .iter()
                .max_by(|a, b| {
                    gp.expected_improvement(&[**a], best)
                        .partial_cmp(&gp.expected_improvement(&[**b], best))
                        .unwrap()
                })
                .unwrap();
            obs_x.push(vec![*next]);
            obs_y.push(f(*next));
        }
        let best = obs_y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert!(best > -0.02, "BO best {best}");
    }
}

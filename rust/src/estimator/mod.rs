//! Throughput estimators — §4.3 "Minimizing Profiling Cost" and Fig. 18.
//!
//! Profiling every model, model pair and parallelism strategy offline is
//! expensive; the paper compares ways to fill the packing-weight tables
//! from a *limited* profiling budget:
//!
//! * [`OracleEstimator`] — exhaustive offline profiling (upper bound),
//! * [`LinearBoEstimator`] — the paper's approach: a linear scaling model
//!   for data-parallel jobs (`tput(N) = N × tput(1)`) plus Bayesian
//!   optimization (GP surrogate, expected improvement) over parallelism
//!   strategies for LLM jobs,
//! * [`MatrixCompletionEstimator`] — the Gavel/Quasar baseline: observe a
//!   random fraction of the pairwise packing matrix and ALS-complete it.
//!
//! Memory feasibility is *not* estimated: it is analytically predictable
//! from model/strategy shapes (and schedulers must never launch a
//! known-OOM configuration), so all estimators delegate `fits_packed` to
//! the profiler's memory model.

pub mod gp;
pub mod matrix_completion;

use std::collections::BTreeMap;

use crate::jobs::{ModelKind, ParallelismStrategy};
use crate::profiler::{JobCfg, Profiler};
use crate::util::rng::Pcg64;

use gp::Gp;
use matrix_completion::{CompletedMatrix, Observation};

/// GPU-count buckets the paper's traces use.
pub const GPU_BUCKETS: [u32; 4] = [1, 2, 4, 8];

/// Key identifying a profiled configuration: (model, strategy tag, #GPUs).
pub type CfgKey = (ModelKind, u64, u32);

fn key(cfg: JobCfg, n: u32) -> CfgKey {
    (cfg.0, cfg.1.tag(), n)
}

/// A source of scheduler-visible throughput numbers. Implemented by the
/// (noisy) profiler itself and by every estimator.
pub trait ThroughputSource: Send + Sync {
    fn name(&self) -> &'static str;
    /// Estimated isolated throughput (iters/s); 0.0 when infeasible.
    fn isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64;
    /// Estimated normalized packed pair; `None` when the pair OOMs.
    fn normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)>;
    /// Profiling samples the estimator consumed while building its tables.
    fn profiling_samples(&self) -> usize;
}

impl ThroughputSource for Profiler {
    fn name(&self) -> &'static str {
        "profiler"
    }

    fn isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        self.profiled_isolated_tput(model, strategy, n)
    }

    fn normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        self.profiled_normalized_pair(a, b, n)
    }

    fn profiling_samples(&self) -> usize {
        0
    }
}

/// Enumerate all (model, strategy) configurations at a GPU count.
fn all_cfgs(n: u32) -> Vec<(ModelKind, ParallelismStrategy)> {
    let mut out = Vec::new();
    for m in ModelKind::ALL {
        for s in ParallelismStrategy::candidates(m, n) {
            out.push((m, s));
        }
    }
    out
}

// ====================================================================== cache

/// Memoizing wrapper: placement policies query pair weights once per
/// (model, strategy, model, strategy, n) — job-identity independent — so a
/// small cache removes the dominant profiler cost from the round hot path
/// (see EXPERIMENTS.md §Perf).
pub struct CachedSource<S: ThroughputSource> {
    inner: S,
    pairs: std::sync::Mutex<BTreeMap<(CfgKey, CfgKey), Option<(f64, f64)>>>,
    iso: std::sync::Mutex<BTreeMap<CfgKey, f64>>,
}

impl<S: ThroughputSource> CachedSource<S> {
    pub fn new(inner: S) -> CachedSource<S> {
        CachedSource {
            inner,
            pairs: std::sync::Mutex::new(BTreeMap::new()),
            iso: std::sync::Mutex::new(BTreeMap::new()),
        }
    }
}

impl<S: ThroughputSource> ThroughputSource for CachedSource<S> {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        let k = key((model, strategy), n);
        if let Some(&v) = self.iso.lock().unwrap().get(&k) {
            return v;
        }
        let v = self.inner.isolated_tput(model, strategy, n);
        self.iso.lock().unwrap().insert(k, v);
        v
    }

    fn normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        let k = (key(a, n), key(b, n));
        if let Some(v) = self.pairs.lock().unwrap().get(&k) {
            return *v;
        }
        let v = self.inner.normalized_pair(a, b, n);
        self.pairs.lock().unwrap().insert(k, v);
        v
    }

    fn profiling_samples(&self) -> usize {
        self.inner.profiling_samples()
    }
}

// ===================================================================== oracle

/// Exhaustive offline profiling: every configuration and pair at every GPU
/// bucket (the paper's default §5 profiling mode).
pub struct OracleEstimator {
    profiler: Profiler,
    samples: usize,
}

impl OracleEstimator {
    pub fn new(profiler: Profiler) -> OracleEstimator {
        // Count the profiling runs an exhaustive sweep would execute.
        let mut samples = 0;
        for &n in &GPU_BUCKETS {
            let cfgs = all_cfgs(n);
            samples += cfgs.len(); // isolated runs
            samples += cfgs.len() * cfgs.len(); // pairwise runs
        }
        OracleEstimator { profiler, samples }
    }
}

impl ThroughputSource for OracleEstimator {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        // Profiled (not true) accessors: when the underlying profiler
        // carries decision noise n_p (Fig. 16), even exhaustive offline
        // profiling observes noisy measurements.
        self.profiler.profiled_isolated_tput(model, strategy, n)
    }

    fn normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        self.profiler.profiled_normalized_pair(a, b, n)
    }

    fn profiling_samples(&self) -> usize {
        self.samples
    }
}

// =============================================================== linear + BO

/// The paper's estimator: linear scaling for DP jobs + Bayesian
/// optimization over LLM parallelism strategies.
pub struct LinearBoEstimator {
    profiler: Profiler,
    /// Measured 1-GPU isolated throughput per model.
    iso1: BTreeMap<ModelKind, f64>,
    /// Measured 1-GPU normalized retention per (model, partner) pair.
    pair1: BTreeMap<(ModelKind, ModelKind), (f64, f64)>,
    /// Exactly profiled LLM entries (BO's chosen probe points).
    exact_iso: BTreeMap<CfgKey, f64>,
    exact_pair: BTreeMap<(CfgKey, CfgKey), (f64, f64)>,
    /// One GP per (LLM model, n): predicts the LLM's normalized packed
    /// throughput from (strategy, partner) features.
    gps: BTreeMap<(ModelKind, u32), Gp>,
    samples: usize,
}

/// Feature vector for the LLM packing GP: strategy shape + partner profile.
fn llm_features(strategy: &ParallelismStrategy, partner: Option<ModelKind>, n: u32) -> Vec<f64> {
    let (is_dp, is_tp, balance, frontness) = match strategy {
        ParallelismStrategy::DataParallel => (1.0, 0.0, 1.0, 0.5),
        ParallelismStrategy::TensorParallel => (0.0, 1.0, 1.0, 0.5),
        ParallelismStrategy::Pipeline(split) => {
            let total: f64 = split.iter().sum::<u32>() as f64;
            let maxs = split.iter().copied().max().unwrap_or(1) as f64;
            let balance = (total / split.len() as f64) / maxs;
            // Center of mass of layers along the pipeline in [0,1].
            let com: f64 = split
                .iter()
                .enumerate()
                .map(|(g, &s)| g as f64 * s as f64)
                .sum::<f64>()
                / (total * (split.len().saturating_sub(1)).max(1) as f64);
            (0.0, 0.0, balance, com)
        }
    };
    let (p_int, p_mem) = partner
        .map(|p| (p.compute_intensity(), p.model_mem_gb() / 40.0))
        .unwrap_or((0.0, 0.0));
    vec![
        is_dp,
        is_tp,
        balance,
        frontness,
        p_int,
        p_mem,
        (n as f64).log2() / 3.0,
    ]
}

impl LinearBoEstimator {
    /// Build the estimator. `bo_budget` is the number of profiling runs BO
    /// may spend per (LLM, n) group beyond its 2 random seeds.
    pub fn new(profiler: Profiler, bo_budget: usize, seed: u64) -> LinearBoEstimator {
        let mut e = LinearBoEstimator {
            profiler,
            iso1: BTreeMap::new(),
            pair1: BTreeMap::new(),
            exact_iso: BTreeMap::new(),
            exact_pair: BTreeMap::new(),
            gps: BTreeMap::new(),
            samples: 0,
        };
        let dp = ParallelismStrategy::DataParallel;

        // 1-GPU profiles for every model (the linear model's anchor).
        for m in ModelKind::ALL {
            e.iso1.insert(m, e.profiler.true_isolated_tput(m, &dp, 1));
            e.samples += 1;
        }
        // 1-GPU pairwise packing profiles.
        for a in ModelKind::ALL {
            for b in ModelKind::ALL {
                if let Some(pair) = e.profiler.true_normalized_pair((a, &dp), (b, &dp), 1) {
                    e.pair1.insert((a, b), pair);
                }
                e.samples += 1;
            }
        }

        // Bayesian optimization over LLM strategies at multi-GPU scales.
        let mut rng = Pcg64::new(seed);
        for llm in ModelKind::ALL.into_iter().filter(|m| m.is_llm()) {
            for &n in &[4u32, 8] {
                e.bo_sweep(llm, n, bo_budget, &mut rng);
            }
        }
        e
    }

    /// Probe points: (strategy, partner or isolated).
    fn bo_domain(llm: ModelKind, n: u32) -> Vec<(ParallelismStrategy, Option<ModelKind>)> {
        let mut pts = Vec::new();
        for s in ParallelismStrategy::candidates(llm, n) {
            pts.push((s.clone(), None));
            for p in ModelKind::ALL {
                pts.push((s.clone(), Some(p)));
            }
        }
        pts
    }

    /// Profile one probe point; records exact entries and returns the
    /// objective value (the LLM's normalized throughput).
    fn probe(
        &mut self,
        llm: ModelKind,
        n: u32,
        s: &ParallelismStrategy,
        partner: Option<ModelKind>,
    ) -> f64 {
        self.samples += 1;
        let (_, best_iso) = self.profiler.best_isolated(llm, n);
        match partner {
            None => {
                let t = self.profiler.true_isolated_tput(llm, s, n);
                self.exact_iso.insert(key((llm, s), n), t);
                if best_iso > 0.0 {
                    t / best_iso
                } else {
                    0.0
                }
            }
            Some(p) => {
                // Partner runs its own best strategy.
                let (ps, _) = self.profiler.best_isolated(p, n);
                match self.profiler.true_normalized_pair((llm, s), (p, &ps), n) {
                    Some(pair) => {
                        self.exact_pair
                            .insert((key((llm, s), n), key((p, &ps), n)), pair);
                        pair.0
                    }
                    None => 0.0, // OOM point
                }
            }
        }
    }

    fn bo_sweep(&mut self, llm: ModelKind, n: u32, budget: usize, rng: &mut Pcg64) {
        let domain = Self::bo_domain(llm, n);
        if domain.is_empty() {
            return;
        }
        let mut obs_x: Vec<Vec<f64>> = Vec::new();
        let mut obs_y: Vec<f64> = Vec::new();
        let mut probed: Vec<bool> = vec![false; domain.len()];
        // Two random seed points.
        for _ in 0..2.min(domain.len()) {
            let i = rng.below(domain.len() as u64) as usize;
            if probed[i] {
                continue;
            }
            probed[i] = true;
            let (s, p) = domain[i].clone();
            let y = self.probe(llm, n, &s, p);
            obs_x.push(llm_features(&s, p, n));
            obs_y.push(y);
        }
        // EI-driven probes.
        for _ in 0..budget {
            let Ok(gp) = Gp::fit(obs_x.clone(), &obs_y, 0.6, 0.25, 1e-4) else {
                break;
            };
            let best = obs_y.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let next = (0..domain.len())
                .filter(|&i| !probed[i])
                .max_by(|&a, &b| {
                    let (sa, pa) = &domain[a];
                    let (sb, pb) = &domain[b];
                    gp.expected_improvement(&llm_features(sa, *pa, n), best)
                        .partial_cmp(&gp.expected_improvement(&llm_features(sb, *pb, n), best))
                        .unwrap()
                });
            let Some(i) = next else { break };
            probed[i] = true;
            let (s, p) = domain[i].clone();
            let y = self.probe(llm, n, &s, p);
            obs_x.push(llm_features(&s, p, n));
            obs_y.push(y);
        }
        if let Ok(gp) = Gp::fit(obs_x, &obs_y, 0.6, 0.25, 1e-4) {
            self.gps.insert((llm, n), gp);
        }
    }

    /// Linear-model retention estimate for a non-LLM job.
    fn retention1(&self, a: ModelKind, b: ModelKind) -> Option<f64> {
        self.pair1.get(&(a, b)).map(|p| p.0)
    }
}

impl ThroughputSource for LinearBoEstimator {
    fn name(&self) -> &'static str {
        "linear+bo"
    }

    fn isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        if !self.profiler.fits_isolated(model, strategy, n) {
            return 0.0;
        }
        if let Some(&t) = self.exact_iso.get(&key((model, strategy), n)) {
            return t;
        }
        if !model.is_llm() || n == 1 {
            // Linear model: tput(N) = N × tput(1).
            return self.iso1.get(&model).copied().unwrap_or(0.0) * n as f64;
        }
        // LLM at scale with an unprofiled strategy: GP prediction of the
        // normalized value, denormalized with the linear upper bound.
        let linear = self.iso1.get(&model).copied().unwrap_or(0.0) * n as f64;
        match self.gps.get(&(model, n)) {
            Some(gp) => {
                let (mu, _) = gp.predict(&llm_features(strategy, None, n));
                mu.clamp(0.05, 1.0) * linear
            }
            None => linear,
        }
    }

    fn normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        if !self.profiler.fits_packed(a, b, n) {
            return None;
        }
        if let Some(&pair) = self.exact_pair.get(&(key(a, n), key(b, n))) {
            return Some(pair);
        }
        if let Some(&(pb, pa)) = self.exact_pair.get(&(key(b, n), key(a, n))) {
            return Some((pa, pb));
        }
        let side = |x: JobCfg, other: JobCfg| -> f64 {
            if x.0.is_llm() && n > 1 {
                match self.gps.get(&(x.0, n)) {
                    Some(gp) => gp
                        .predict(&llm_features(x.1, Some(other.0), n))
                        .0
                        .clamp(0.0, 1.0),
                    None => 0.5,
                }
            } else {
                // Retention measured at 1 GPU transfers across scales.
                self.retention1(x.0, other.0).unwrap_or(0.5)
            }
        };
        Some((side(a, b), side(b, a)))
    }

    fn profiling_samples(&self) -> usize {
        self.samples
    }
}

// ========================================================= matrix completion

/// Gavel/Quasar-style estimator: observe a random fraction of the pairwise
/// packing matrix and ALS-complete the rest. Isolated throughputs are
/// profiled exhaustively (they are cheap single-job runs).
pub struct MatrixCompletionEstimator {
    profiler: Profiler,
    /// Per GPU bucket: completed #models × #models retention matrices
    /// (row = job whose retention we read, col = partner).
    completed: BTreeMap<u32, CompletedMatrix>,
    /// Exactly observed cells.
    observed: BTreeMap<(ModelKind, ModelKind, u32), (f64, f64)>,
    samples: usize,
}

impl MatrixCompletionEstimator {
    pub fn new(profiler: Profiler, observe_frac: f64, seed: u64) -> MatrixCompletionEstimator {
        let mut e = MatrixCompletionEstimator {
            profiler,
            completed: BTreeMap::new(),
            observed: BTreeMap::new(),
            samples: 0,
        };
        let models = ModelKind::ALL;
        let mut rng = Pcg64::new(seed ^ 0x6d63);
        for &n in &GPU_BUCKETS {
            let mut obs = Vec::new();
            for (i, &a) in models.iter().enumerate() {
                for (j, &b) in models.iter().enumerate() {
                    if rng.f64() >= observe_frac {
                        continue;
                    }
                    e.samples += 1;
                    let (sa, _) = e.profiler.best_isolated(a, n);
                    let (sb, _) = e.profiler.best_isolated(b, n);
                    if let Some(pair) = e.profiler.true_normalized_pair((a, &sa), (b, &sb), n) {
                        obs.push(Observation {
                            row: i,
                            col: j,
                            value: pair.0,
                        });
                        e.observed.insert((a, b, n), pair);
                    }
                }
            }
            e.completed.insert(
                n,
                CompletedMatrix::fit(
                    models.len(),
                    models.len(),
                    &obs,
                    2,
                    1e-3,
                    30,
                    seed ^ n as u64,
                ),
            );
        }
        e
    }

    fn model_index(m: ModelKind) -> usize {
        ModelKind::ALL.iter().position(|&x| x == m).unwrap()
    }
}

impl ThroughputSource for MatrixCompletionEstimator {
    fn name(&self) -> &'static str {
        "matrix-completion"
    }

    fn isolated_tput(&self, model: ModelKind, strategy: &ParallelismStrategy, n: u32) -> f64 {
        self.profiler.true_isolated_tput(model, strategy, n)
    }

    fn normalized_pair(&self, a: JobCfg, b: JobCfg, n: u32) -> Option<(f64, f64)> {
        if !self.profiler.fits_packed(a, b, n) {
            return None;
        }
        if let Some(&pair) = self.observed.get(&(a.0, b.0, n)) {
            return Some(pair);
        }
        let m = self.completed.get(&n)?;
        let ra = m
            .predict(Self::model_index(a.0), Self::model_index(b.0))
            .clamp(0.0, 1.0);
        let rb = m
            .predict(Self::model_index(b.0), Self::model_index(a.0))
            .clamp(0.0, 1.0);
        Some((ra, rb))
    }

    fn profiling_samples(&self) -> usize {
        self.samples
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::jobs::ModelKind::*;

    fn profiler() -> Profiler {
        Profiler::new(GpuType::A100, 21)
    }

    fn dp() -> ParallelismStrategy {
        ParallelismStrategy::DataParallel
    }

    #[test]
    fn oracle_matches_profiler_truth() {
        let p = profiler();
        let o = OracleEstimator::new(p.clone());
        assert_eq!(
            o.isolated_tput(ResNet50, &dp(), 4),
            p.true_isolated_tput(ResNet50, &dp(), 4)
        );
        assert_eq!(
            o.normalized_pair((PointNet, &dp()), (Dcgan, &dp()), 2),
            p.true_normalized_pair((PointNet, &dp()), (Dcgan, &dp()), 2)
        );
        assert!(o.profiling_samples() > 100);
    }

    #[test]
    fn linear_model_scales_one_gpu_profile() {
        let p = profiler();
        let e = LinearBoEstimator::new(p.clone(), 6, 3);
        let est4 = e.isolated_tput(ResNet50, &dp(), 4);
        let est1 = e.isolated_tput(ResNet50, &dp(), 1);
        assert!((est4 - 4.0 * est1).abs() < 1e-9, "{est4} vs 4×{est1}");
        // The linear estimate is close to truth (within DP efficiency loss).
        let truth = p.true_isolated_tput(ResNet50, &dp(), 4);
        assert!((est4 - truth).abs() / truth < 0.25);
    }

    #[test]
    fn linear_bo_estimates_pairs_reasonably() {
        let p = profiler();
        let e = LinearBoEstimator::new(p.clone(), 6, 3);
        let est = e
            .normalized_pair((PointNet, &dp()), (Dcgan, &dp()), 2)
            .unwrap();
        let truth = p
            .true_normalized_pair((PointNet, &dp()), (Dcgan, &dp()), 2)
            .unwrap();
        assert!((est.0 - truth.0).abs() < 0.25, "{est:?} vs {truth:?}");
        assert!((est.1 - truth.1).abs() < 0.25);
    }

    #[test]
    fn bo_spends_its_budget_not_more() {
        let p = profiler();
        let small = LinearBoEstimator::new(p.clone(), 2, 3);
        let large = LinearBoEstimator::new(p.clone(), 10, 3);
        assert!(large.profiling_samples() > small.profiling_samples());
        let oracle = OracleEstimator::new(p);
        assert!(large.profiling_samples() < oracle.profiling_samples());
    }

    #[test]
    fn estimators_respect_oom() {
        let p = profiler();
        let e = LinearBoEstimator::new(p.clone(), 4, 3);
        let mc = MatrixCompletionEstimator::new(p.clone(), 0.5, 5);
        let even = ParallelismStrategy::default_pp(Gpt3_3B, 8);
        // VGG + default-PP GPT3-3B OOMs (Fig. 8); every estimator must agree.
        assert!(e
            .normalized_pair((Gpt3_3B, &even), (Vgg19, &dp()), 8)
            .is_none());
        assert!(mc
            .normalized_pair((Gpt3_3B, &even), (Vgg19, &dp()), 8)
            .is_none());
    }

    #[test]
    fn matrix_completion_predicts_unobserved_cells() {
        let p = profiler();
        let mc = MatrixCompletionEstimator::new(p.clone(), 0.5, 5);
        // Every feasible non-LLM pair must produce a finite estimate.
        for a in [ResNet50, Vgg19, Dcgan, PointNet] {
            for b in [ResNet50, Vgg19, Dcgan, PointNet] {
                if let Some((ra, rb)) = mc.normalized_pair((a, &dp()), (b, &dp()), 1) {
                    assert!((0.0..=1.0).contains(&ra), "{a:?}/{b:?} {ra}");
                    assert!((0.0..=1.0).contains(&rb));
                }
            }
        }
        assert!(mc.profiling_samples() > 0);
    }

    #[test]
    fn matrix_completion_accuracy_tracks_budget() {
        let p = profiler();
        let dense = MatrixCompletionEstimator::new(p.clone(), 0.9, 5);
        let sparse = MatrixCompletionEstimator::new(p.clone(), 0.2, 5);
        let err = |e: &MatrixCompletionEstimator| {
            let mut total = 0.0;
            let mut count = 0;
            for a in [ResNet50, Vgg19, Dcgan, PointNet] {
                for b in [ResNet50, Vgg19, Dcgan, PointNet] {
                    if let (Some(est), Some(truth)) = (
                        e.normalized_pair((a, &dp()), (b, &dp()), 1),
                        p.true_normalized_pair((a, &dp()), (b, &dp()), 1),
                    ) {
                        total += (est.0 - truth.0).abs();
                        count += 1;
                    }
                }
            }
            total / count as f64
        };
        assert!(
            err(&dense) <= err(&sparse) + 0.02,
            "{} vs {}",
            err(&dense),
            err(&sparse)
        );
    }
}

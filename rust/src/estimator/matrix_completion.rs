//! Low-rank matrix completion via alternating least squares — the baseline
//! throughput estimator Gavel/Quasar use (Fig. 18's "Matrix Completion").
//!
//! Given a partially observed matrix `M` (packed-throughput entries for
//! model pairs), find rank-k factors `U Vᵀ ≈ M` on the observed cells and
//! use `U Vᵀ` to predict the missing ones.

use crate::linalg::{solve_spd, Matrix};
use crate::util::rng::Pcg64;

/// Observed cell of the matrix.
#[derive(Debug, Clone, Copy)]
pub struct Observation {
    pub row: usize,
    pub col: usize,
    pub value: f64,
}

/// ALS matrix completion.
#[derive(Debug, Clone)]
pub struct CompletedMatrix {
    u: Matrix,
    v: Matrix,
}

impl CompletedMatrix {
    /// Fit rank-`k` factors to the observations of an `rows × cols` matrix.
    /// `reg` is the ridge regularizer; `iters` the number of ALS sweeps.
    pub fn fit(
        rows: usize,
        cols: usize,
        observations: &[Observation],
        k: usize,
        reg: f64,
        iters: usize,
        seed: u64,
    ) -> CompletedMatrix {
        assert!(k >= 1);
        let mut rng = Pcg64::new(seed);
        let mut u = Matrix::random(rows, k, &mut rng);
        let mut v = Matrix::random(cols, k, &mut rng);
        // Scale initial factors toward the observation mean for stability.
        let mean = if observations.is_empty() {
            0.0
        } else {
            observations.iter().map(|o| o.value).sum::<f64>() / observations.len() as f64
        };
        let scale = (mean.abs() / k as f64).sqrt().max(0.1);
        for val in 0..rows {
            for c in 0..k {
                u.set(val, c, u.get(val, c) * scale + scale);
            }
        }
        for val in 0..cols {
            for c in 0..k {
                v.set(val, c, v.get(val, c) * scale + scale);
            }
        }

        // Group observations per row / per col.
        let mut by_row: Vec<Vec<(usize, f64)>> = vec![Vec::new(); rows];
        let mut by_col: Vec<Vec<(usize, f64)>> = vec![Vec::new(); cols];
        for o in observations {
            by_row[o.row].push((o.col, o.value));
            by_col[o.col].push((o.row, o.value));
        }

        for _ in 0..iters {
            solve_side(&mut u, &v, &by_row, k, reg);
            solve_side(&mut v, &u, &by_col, k, reg);
        }
        CompletedMatrix { u, v }
    }

    /// Predicted value at (row, col).
    pub fn predict(&self, row: usize, col: usize) -> f64 {
        let k = self.u.cols();
        (0..k).map(|c| self.u.get(row, c) * self.v.get(col, c)).sum()
    }

    /// RMSE over a set of cells.
    pub fn rmse(&self, cells: &[Observation]) -> f64 {
        if cells.is_empty() {
            return 0.0;
        }
        let se: f64 = cells
            .iter()
            .map(|o| {
                let d = self.predict(o.row, o.col) - o.value;
                d * d
            })
            .sum();
        (se / cells.len() as f64).sqrt()
    }
}

/// One ALS half-step: re-solve every row of `target` against `fixed`.
fn solve_side(
    target: &mut Matrix,
    fixed: &Matrix,
    obs: &[Vec<(usize, f64)>],
    k: usize,
    reg: f64,
) {
    for (i, cells) in obs.iter().enumerate() {
        if cells.is_empty() {
            continue;
        }
        // Solve (Fᵀ F + reg I) w = Fᵀ y over this row's observed cells.
        let mut a = Matrix::zeros(k, k);
        let mut b = vec![0.0; k];
        for &(j, y) in cells {
            for p in 0..k {
                let fp = fixed.get(j, p);
                b[p] += fp * y;
                for q in 0..k {
                    a.set(p, q, a.get(p, q) + fp * fixed.get(j, q));
                }
            }
        }
        for p in 0..k {
            a.set(p, p, a.get(p, p) + reg);
        }
        if let Ok(w) = solve_spd(&a, &b) {
            for (p, wp) in w.iter().enumerate() {
                target.set(i, p, *wp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a rank-2 ground-truth matrix and observe a fraction of cells.
    fn synthetic(rows: usize, cols: usize, frac: f64, seed: u64) -> (Matrix, Vec<Observation>, Vec<Observation>) {
        let mut rng = Pcg64::new(seed);
        let u = Matrix::random(rows, 2, &mut rng);
        let v = Matrix::random(cols, 2, &mut rng);
        let truth = u.matmul(&v.transpose());
        let mut seen = Vec::new();
        let mut held_out = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let o = Observation {
                    row: r,
                    col: c,
                    value: truth.get(r, c),
                };
                if rng.f64() < frac {
                    seen.push(o);
                } else {
                    held_out.push(o);
                }
            }
        }
        (truth, seen, held_out)
    }

    #[test]
    fn recovers_low_rank_matrix() {
        let (_, seen, held_out) = synthetic(10, 10, 0.6, 3);
        let m = CompletedMatrix::fit(10, 10, &seen, 2, 1e-3, 30, 7);
        assert!(m.rmse(&seen) < 0.05, "train rmse {}", m.rmse(&seen));
        assert!(m.rmse(&held_out) < 0.3, "test rmse {}", m.rmse(&held_out));
    }

    #[test]
    fn dense_observation_near_exact() {
        let (_, seen, _) = synthetic(8, 8, 1.0, 5);
        let m = CompletedMatrix::fit(8, 8, &seen, 2, 1e-4, 40, 9);
        assert!(m.rmse(&seen) < 1e-2);
    }

    #[test]
    fn sparse_observation_degrades_gracefully() {
        // Averaged over seeds: denser observation gives a no-worse holdout
        // RMSE than very sparse observation.
        let mut dense_err = 0.0;
        let mut sparse_err = 0.0;
        for seed in 0..6u64 {
            let (_, seen_dense, test_d) = synthetic(12, 12, 0.7, 11 + seed);
            let (_, seen_sparse, test_s) = synthetic(12, 12, 0.15, 11 + seed);
            let dense = CompletedMatrix::fit(12, 12, &seen_dense, 2, 1e-3, 30, 13 + seed);
            let sparse = CompletedMatrix::fit(12, 12, &seen_sparse, 2, 1e-3, 30, 13 + seed);
            dense_err += dense.rmse(&test_d);
            sparse_err += sparse.rmse(&test_s);
        }
        assert!(
            dense_err <= sparse_err + 0.05,
            "dense {dense_err} vs sparse {sparse_err}"
        );
    }

    #[test]
    fn empty_rows_keep_initial_values() {
        let obs = vec![Observation {
            row: 0,
            col: 0,
            value: 2.0,
        }];
        let m = CompletedMatrix::fit(3, 3, &obs, 1, 1e-3, 10, 1);
        // Prediction for the observed cell is close; unobserved rows finite.
        assert!((m.predict(0, 0) - 2.0).abs() < 0.5);
        assert!(m.predict(2, 2).is_finite());
    }
}

//! Real-execution coordinator: the "physical cluster" mode.
//!
//! A leader thread runs Tesserae's round loop over a set of worker threads,
//! each owning one simulated GPU backed by its own PJRT CPU client. Jobs
//! are *actual* training runs of the AOT-exported GPT models: every round
//! the leader invokes the placement policies (allocate → pack → migrate),
//! ships parameter checkpoints to workers that gained jobs (the measured
//! migration cost of Fig. 3), and workers execute real `train_step`s —
//! interleaving the two tenants of a packed GPU — until the round's
//! wall-clock budget expires.
//!
//! Multi-GPU jobs run as data-parallel replicas with a round-granular
//! parameter average at the leader (a poor-man's all-reduce, which also
//! keeps replica state consistent across migrations).
//!
//! Scheduling-side throughput estimates reuse the synthetic profiler (each
//! exec model is mapped onto a Table-1 [`ModelKind`]); all *reported*
//! numbers — steps, losses, throughput, JCTs, checkpoint bytes and stall
//! times — are measured from the real execution.

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::cluster::{ClusterSpec, GpuType, PlacementPlan};
use crate::estimator::{CachedSource, OracleEstimator};
use crate::jobs::{JobId, ModelKind};
use crate::matching::HungarianEngine;
use crate::policies::placement::{MigrationMode, PackingConfig};
use crate::policies::scheduling::TiresiasLas;
use crate::policies::JobInfo;
use crate::profiler::Profiler;
use crate::runtime::train::ParamState;
use crate::runtime::{Manifest, Runtime, TrainSession};
use crate::schedulers::{pipeline, RoundInput, TesseraeScheduler};
use crate::util::rng::Pcg64;

/// A job submitted to the real-execution cluster.
#[derive(Debug, Clone)]
pub struct ExecJob {
    pub id: JobId,
    /// Exported model name: "gpt-nano" or "gpt-micro".
    pub model: String,
    /// Number of data-parallel replicas (GPUs).
    pub num_gpus: u32,
    /// Round index at which the job arrives.
    pub arrival_round: u64,
    /// Total train steps (summed across replicas) to completion.
    pub total_steps: u64,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct ExecConfig {
    pub num_nodes: usize,
    pub gpus_per_node: usize,
    /// Wall-clock compute budget per round (seconds).
    pub round_wall_s: f64,
    /// Enable the packing policy.
    pub packing: bool,
    /// Migration policy.
    pub migration: MigrationMode,
    pub seed: u64,
    /// Runaway guard.
    pub max_rounds: u64,
}

impl Default for ExecConfig {
    fn default() -> Self {
        ExecConfig {
            num_nodes: 2,
            gpus_per_node: 2,
            round_wall_s: 1.0,
            packing: true,
            migration: MigrationMode::Tesserae,
            seed: 1,
            max_rounds: 10_000,
        }
    }
}

/// Per-job outcome of a real-execution run.
#[derive(Debug, Clone)]
pub struct ExecJobReport {
    pub id: JobId,
    pub model: String,
    pub steps: u64,
    pub losses: Vec<f32>,
    /// Rounds from arrival to completion.
    pub jct_rounds: u64,
    pub migrations: u64,
    pub first_loss: f32,
    pub last_loss: f32,
}

/// Aggregate real-execution report.
#[derive(Debug, Clone)]
pub struct ExecReport {
    pub jobs: BTreeMap<JobId, ExecJobReport>,
    pub rounds: u64,
    pub total_migrations: usize,
    /// Measured checkpoint traffic (bytes moved due to migration/averaging).
    pub checkpoint_bytes: u64,
    /// Measured time spent moving checkpoints (the Fig. 3 overhead).
    pub checkpoint_time_s: f64,
    /// Wall time of the whole run.
    pub wall_s: f64,
    pub avg_jct_rounds: f64,
    pub makespan_rounds: u64,
}

/// Map an exec model onto a Table-1 model for the scheduling-side
/// profiler (compute-light nano ↔ DCGAN, heavier micro ↔ ResNet-50).
pub fn scheduling_model(model: &str) -> ModelKind {
    match model {
        "gpt-nano" => ModelKind::Dcgan,
        _ => ModelKind::ResNet50,
    }
}

// ----------------------------------------------------------------- worker

struct TaskSpec {
    job: JobId,
    model: String,
    /// Parameters shipped with the task (after migration/averaging); when
    /// `None` the worker uses its cache or initializes from the job id.
    params: Option<ParamState>,
}

struct TaskReport {
    job: JobId,
    steps: u64,
    losses: Vec<f32>,
}

enum WorkerMsg {
    Round {
        tasks: Vec<TaskSpec>,
        wall_budget_s: f64,
        reply: Sender<Vec<TaskReport>>,
    },
    /// Fetch (and keep) a job's parameters.
    Fetch {
        job: JobId,
        reply: Sender<Option<ParamState>>,
    },
    /// Drop a job's cached parameters.
    Evict {
        job: JobId,
    },
    Shutdown,
}

fn worker_main(manifest: Manifest, rx: Receiver<WorkerMsg>, seed: u64) {
    let rt = Runtime::new(manifest).expect("worker runtime");
    let mut sessions: BTreeMap<String, TrainSession> = BTreeMap::new();
    let mut cache: BTreeMap<JobId, ParamState> = BTreeMap::new();
    let mut rng = Pcg64::new(seed);

    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Shutdown => break,
            WorkerMsg::Evict { job } => {
                cache.remove(&job);
            }
            WorkerMsg::Fetch { job, reply } => {
                let _ = reply.send(cache.get(&job).cloned());
            }
            WorkerMsg::Round {
                tasks,
                wall_budget_s,
                reply,
            } => {
                // Install sessions + parameters.
                for t in &tasks {
                    if !sessions.contains_key(&t.model) {
                        let s = TrainSession::load(&rt, &t.model).expect("load session");
                        sessions.insert(t.model.clone(), s);
                    }
                    if let Some(p) = &t.params {
                        cache.insert(t.job, p.clone());
                    } else if !cache.contains_key(&t.job) {
                        let s = &sessions[&t.model];
                        cache.insert(t.job, s.init_params(t.job as i32).expect("init"));
                    }
                }
                // Interleave one step per tenant until the budget expires —
                // the CUDA-MPS sharing model of §5 at step granularity.
                let mut reports: Vec<TaskReport> = tasks
                    .iter()
                    .map(|t| TaskReport {
                        job: t.job,
                        steps: 0,
                        losses: Vec::new(),
                    })
                    .collect();
                let deadline =
                    Instant::now() + std::time::Duration::from_secs_f64(wall_budget_s);
                if !tasks.is_empty() && wall_budget_s > 0.0 {
                    'round: loop {
                        for (t, rep) in tasks.iter().zip(&mut reports) {
                            let session = &sessions[&t.model];
                            let batch = session.synthetic_batch(&mut rng);
                            let params = cache.get_mut(&t.job).unwrap();
                            let loss = session.step(params, &batch).expect("train step");
                            rep.steps += 1;
                            rep.losses.push(loss);
                            if Instant::now() >= deadline {
                                break 'round;
                            }
                        }
                    }
                }
                let _ = reply.send(reports);
            }
        }
    }
}

// ----------------------------------------------------------------- leader

struct WorkerHandle {
    tx: Sender<WorkerMsg>,
    handle: std::thread::JoinHandle<()>,
}

struct JobRt {
    spec: ExecJob,
    steps: u64,
    losses: Vec<f32>,
    attained_rounds: u64,
    migrations: u64,
    finish_round: Option<u64>,
    /// Parameters held at the leader (job not resident anywhere).
    parked: Option<ParamState>,
}

/// Run a real-execution cluster over the given jobs. Returns measured
/// per-job and aggregate results.
pub fn run_cluster(jobs: &[ExecJob], cfg: &ExecConfig) -> Result<ExecReport> {
    let manifest = Manifest::discover()?;
    let spec = ClusterSpec::new(cfg.num_nodes, cfg.gpus_per_node, GpuType::A100);
    let total_gpus = spec.total_gpus();

    // Spawn one worker per GPU.
    let workers: Vec<WorkerHandle> = (0..total_gpus)
        .map(|g| {
            let (tx, rx) = channel();
            let m = manifest.clone();
            let seed = cfg.seed ^ (g as u64).wrapping_mul(0x9e37_79b9);
            let handle = std::thread::Builder::new()
                .name(format!("gpu-worker-{g}"))
                .spawn(move || worker_main(m, rx, seed))
                .expect("spawn worker");
            WorkerHandle { tx, handle }
        })
        .collect();

    let t_start = Instant::now();
    let mut states: BTreeMap<JobId, JobRt> = jobs
        .iter()
        .map(|j| {
            (
                j.id,
                JobRt {
                    spec: j.clone(),
                    steps: 0,
                    losses: Vec::new(),
                    attained_rounds: 0,
                    migrations: 0,
                    finish_round: None,
                    parked: None,
                },
            )
        })
        .collect();

    let profiler = Profiler::new(GpuType::A100, cfg.seed);
    // The coordinator consumes the same staged round pipeline as the
    // simulated schedulers: one persistent `TesseraeScheduler` provider
    // (Tiresias order, the configured packing/migration modes) driven by
    // `pipeline::run_round`, so its matching-service caches carry across
    // rounds exactly as in simulation. The source is memoized: the
    // Estimate stage prices the whole job window every round, and the
    // lookups repeat across rounds.
    let mut scheduler = TesseraeScheduler::new(
        "coordinator",
        Box::new(TiresiasLas::default()),
        Arc::new(CachedSource::new(OracleEstimator::new(profiler))),
        Arc::new(HungarianEngine),
        cfg.packing.then(PackingConfig::default),
        cfg.migration,
    );

    let mut prev_plan = PlacementPlan::new(total_gpus);
    let mut total_migrations = 0usize;
    let mut checkpoint_bytes = 0u64;
    let mut checkpoint_time_s = 0.0f64;
    let mut round: u64 = 0;
    let mut makespan_rounds: u64 = 0;

    loop {
        let active: Vec<JobInfo> = states
            .values()
            .filter(|s| s.finish_round.is_none() && s.spec.arrival_round <= round)
            .map(|s| {
                let model = scheduling_model(&s.spec.model);
                JobInfo {
                    id: s.spec.id,
                    model,
                    num_gpus: s.spec.num_gpus,
                    arrival_time: s.spec.arrival_round as f64,
                    attained_service: s.attained_rounds as f64 * s.spec.num_gpus as f64 * 360.0,
                    total_iters: s.spec.total_steps as f64,
                    completed_iters: s.steps as f64,
                    rounds_received: s.attained_rounds,
                    now: round as f64,
                    iso_tput: 1.0,
                }
            })
            .collect();

        let all_done = states.values().all(|s| s.finish_round.is_some());
        if all_done {
            break;
        }
        if active.is_empty() {
            round += 1;
            continue;
        }

        // --- placement: the staged round pipeline (Estimate → Schedule →
        // Pack → Migrate → Commit, Listing 1) ---
        let decision = pipeline::run_round(
            &mut scheduler,
            &RoundInput {
                now: round as f64,
                round,
                active: &active,
                prev_plan: &prev_plan,
                spec: &spec,
                health: None,
            },
        );
        let plan = decision.plan;
        total_migrations += decision.migrations;

        // --- checkpoint movement for migrated jobs (measured, Fig. 3) ---
        let t_ckpt = Instant::now();
        let mut shipments: BTreeMap<JobId, ParamState> = BTreeMap::new();
        for job_id in plan.jobs() {
            let old_gpus = prev_plan.gpus_of(job_id);
            let new_gpus = plan.gpus_of(job_id);
            let moved = !old_gpus.is_empty() && old_gpus != new_gpus;
            if moved {
                states.get_mut(&job_id).unwrap().migrations += 1;
                // Fetch replica states from the old workers and average.
                let mut replicas = Vec::new();
                for &g in old_gpus {
                    let (tx, rx) = channel();
                    workers[g]
                        .tx
                        .send(WorkerMsg::Fetch {
                            job: job_id,
                            reply: tx,
                        })
                        .map_err(|_| anyhow!("worker {g} gone"))?;
                    if let Some(p) = rx.recv().unwrap_or(None) {
                        checkpoint_bytes +=
                            p.tensors.iter().map(|t| t.len() * 4).sum::<usize>() as u64;
                        replicas.push(p);
                    }
                    workers[g].tx.send(WorkerMsg::Evict { job: job_id }).ok();
                }
                if !replicas.is_empty() {
                    shipments.insert(job_id, ParamState::average(&replicas));
                }
            } else if let Some(p) = states.get_mut(&job_id).and_then(|s| s.parked.take()) {
                // A job returning from the queue carries its parked state.
                shipments.insert(job_id, p);
            }
        }
        // Jobs that lost their placement entirely: park their state.
        for job_id in prev_plan.jobs() {
            if plan.gpus_of(job_id).is_empty() {
                let old_gpus = prev_plan.gpus_of(job_id);
                let mut replicas = Vec::new();
                for &g in old_gpus {
                    let (tx, rx) = channel();
                    workers[g]
                        .tx
                        .send(WorkerMsg::Fetch {
                            job: job_id,
                            reply: tx,
                        })
                        .ok();
                    if let Some(p) = rx.recv().unwrap_or(None) {
                        checkpoint_bytes +=
                            p.tensors.iter().map(|t| t.len() * 4).sum::<usize>() as u64;
                        replicas.push(p);
                    }
                    workers[g].tx.send(WorkerMsg::Evict { job: job_id }).ok();
                }
                if !replicas.is_empty() {
                    if let Some(s) = states.get_mut(&job_id) {
                        s.parked = Some(ParamState::average(&replicas));
                    }
                }
            }
        }
        checkpoint_time_s += t_ckpt.elapsed().as_secs_f64();

        // --- dispatch the round to every worker with tenants ---
        let mut replies = Vec::new();
        for g in 0..total_gpus {
            let tenants = plan.jobs_on(g);
            if tenants.is_empty() {
                continue;
            }
            let tasks: Vec<TaskSpec> = tenants
                .iter()
                .map(|&job| TaskSpec {
                    job,
                    model: states[&job].spec.model.clone(),
                    params: shipments.get(&job).cloned(),
                })
                .collect();
            let (tx, rx) = channel();
            workers[g]
                .tx
                .send(WorkerMsg::Round {
                    tasks,
                    wall_budget_s: cfg.round_wall_s,
                    reply: tx,
                })
                .map_err(|_| anyhow!("worker {g} gone"))?;
            replies.push(rx);
        }
        for rx in replies {
            for rep in rx.recv().map_err(|_| anyhow!("worker died mid-round"))? {
                let s = states.get_mut(&rep.job).unwrap();
                s.steps += rep.steps;
                s.losses.extend(rep.losses);
            }
        }

        // Round accounting: completions + attained service.
        for job_id in plan.jobs() {
            let s = states.get_mut(&job_id).unwrap();
            s.attained_rounds += 1;
            if s.finish_round.is_none() && s.steps >= s.spec.total_steps {
                s.finish_round = Some(round + 1);
                makespan_rounds = makespan_rounds.max(round + 1);
                for &g in plan.gpus_of(job_id) {
                    workers[g].tx.send(WorkerMsg::Evict { job: job_id }).ok();
                }
            }
        }

        // Synchronize multi-GPU replicas: fetch, average, re-ship
        // (round-granular all-reduce). Costs are measured as checkpoint
        // traffic too — DP sync is real data movement here.
        let t_sync = Instant::now();
        for job_id in plan.jobs() {
            let gpus = plan.gpus_of(job_id);
            let finished = states[&job_id].finish_round.is_some();
            if gpus.len() > 1 && !finished {
                let mut replicas = Vec::new();
                for &g in gpus {
                    let (tx, rx) = channel();
                    workers[g]
                        .tx
                        .send(WorkerMsg::Fetch {
                            job: job_id,
                            reply: tx,
                        })
                        .ok();
                    if let Some(p) = rx.recv().unwrap_or(None) {
                        checkpoint_bytes +=
                            p.tensors.iter().map(|t| t.len() * 4).sum::<usize>() as u64;
                        replicas.push(p);
                    }
                }
                if !replicas.is_empty() {
                    let avg = ParamState::average(&replicas);
                    for &g in gpus {
                        let (tx, rx) = channel();
                        workers[g]
                            .tx
                            .send(WorkerMsg::Round {
                                tasks: vec![TaskSpec {
                                    job: job_id,
                                    model: states[&job_id].spec.model.clone(),
                                    params: Some(avg.clone()),
                                }],
                                wall_budget_s: 0.0,
                                reply: tx,
                            })
                            .ok();
                        let _ = rx.recv();
                    }
                }
            }
        }
        checkpoint_time_s += t_sync.elapsed().as_secs_f64();

        // Next round's "previous plan" excludes finished jobs.
        let mut next_prev = plan.clone();
        let finished: std::collections::BTreeSet<JobId> = states
            .values()
            .filter(|s| s.finish_round.is_some())
            .map(|s| s.spec.id)
            .collect();
        next_prev.remove_jobs(&finished);
        prev_plan = next_prev;

        round += 1;
        if round >= cfg.max_rounds {
            break;
        }
    }

    for w in &workers {
        w.tx.send(WorkerMsg::Shutdown).ok();
    }
    for w in workers {
        w.handle.join().ok();
    }

    let mut reports = BTreeMap::new();
    let mut jcts = Vec::new();
    for (id, s) in &states {
        let jct = s
            .finish_round
            .map(|f| f.saturating_sub(s.spec.arrival_round))
            .unwrap_or(cfg.max_rounds);
        jcts.push(jct as f64);
        reports.insert(
            *id,
            ExecJobReport {
                id: *id,
                model: s.spec.model.clone(),
                steps: s.steps,
                first_loss: s.losses.first().copied().unwrap_or(f32::NAN),
                last_loss: s.losses.last().copied().unwrap_or(f32::NAN),
                losses: s.losses.clone(),
                jct_rounds: jct,
                migrations: s.migrations,
            },
        );
    }

    Ok(ExecReport {
        jobs: reports,
        rounds: round,
        total_migrations,
        checkpoint_bytes,
        checkpoint_time_s,
        wall_s: t_start.elapsed().as_secs_f64(),
        avg_jct_rounds: crate::util::stats::mean(&jcts),
        makespan_rounds,
    })
}

//! Job model: the workload zoo of Table 1, parallelism strategies for the
//! LLM jobs (§4.2 "Parallelism Strategy"), and the static job spec carried
//! by traces. Dynamic per-job state (attained service, progress, placement)
//! lives in the simulator / coordinator.

pub mod strategy;

pub use strategy::ParallelismStrategy;

/// Unique job identifier (stable across rounds).
pub type JobId = u64;

/// The model zoo of Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    ResNet50,
    Vgg19,
    Dcgan,
    PointNet,
    Gpt3Medium,
    Gpt3Xl,
    Gpt3_3B,
}

impl ModelKind {
    pub const ALL: [ModelKind; 7] = [
        ModelKind::ResNet50,
        ModelKind::Vgg19,
        ModelKind::Dcgan,
        ModelKind::PointNet,
        ModelKind::Gpt3Medium,
        ModelKind::Gpt3Xl,
        ModelKind::Gpt3_3B,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::ResNet50 => "resnet-50",
            ModelKind::Vgg19 => "vgg-19",
            ModelKind::Dcgan => "dcgan",
            ModelKind::PointNet => "pointnet",
            ModelKind::Gpt3Medium => "gpt3-medium",
            ModelKind::Gpt3Xl => "gpt3-xl",
            ModelKind::Gpt3_3B => "gpt3-3b",
        }
    }

    pub fn from_name(name: &str) -> Option<ModelKind> {
        Self::ALL.iter().copied().find(|m| m.name() == name)
    }

    /// Table 1 task column.
    pub fn task(&self) -> &'static str {
        match self {
            ModelKind::ResNet50 | ModelKind::Vgg19 => "image classification",
            ModelKind::Dcgan => "image-to-image translation",
            ModelKind::PointNet => "3d point cloud classification",
            _ => "language modeling",
        }
    }

    /// Table 1 dataset column.
    pub fn dataset(&self) -> &'static str {
        match self {
            ModelKind::ResNet50 | ModelKind::Vgg19 => "imagenet",
            ModelKind::Dcgan => "lsun",
            ModelKind::PointNet => "shapenet",
            _ => "wikipedia",
        }
    }

    /// Table 1 batch-size range (inclusive).
    pub fn batch_size_range(&self) -> (u32, u32) {
        match self {
            ModelKind::ResNet50 => (32, 256),
            ModelKind::Vgg19 => (16, 128),
            ModelKind::Dcgan => (128, 1024),
            ModelKind::PointNet => (32, 256),
            _ => (512, 512),
        }
    }

    /// Whether the model contains transformer layers — the paper's group-2
    /// (Megatron-LM 3D parallelism) vs group-1 (PyTorch DDP) split (§5).
    pub fn is_llm(&self) -> bool {
        matches!(
            self,
            ModelKind::Gpt3Medium | ModelKind::Gpt3Xl | ModelKind::Gpt3_3B
        )
    }

    /// Transformer layer count (used to enumerate pipeline splits).
    pub fn num_layers(&self) -> u32 {
        match self {
            ModelKind::Gpt3Medium => 24,
            ModelKind::Gpt3Xl => 24,
            ModelKind::Gpt3_3B => 32,
            // Non-LLMs train with DDP only; layer count is not used for
            // strategy search but is handy for reporting.
            ModelKind::ResNet50 => 50,
            ModelKind::Vgg19 => 19,
            ModelKind::Dcgan => 8,
            ModelKind::PointNet => 6,
        }
    }

    /// Approximate parameter memory per full model copy in GB (fp16 weights
    /// + optimizer states), used by the synthetic memory model.
    pub fn model_mem_gb(&self) -> f64 {
        match self {
            ModelKind::ResNet50 => 3.0,
            ModelKind::Vgg19 => 6.5,
            ModelKind::Dcgan => 2.0,
            ModelKind::PointNet => 1.0,
            ModelKind::Gpt3Medium => 8.0,
            ModelKind::Gpt3Xl => 16.0,
            ModelKind::Gpt3_3B => 30.0,
        }
    }

    /// Activation / working-set memory per GPU in GB (roughly independent of
    /// the parallelism strategy at fixed micro-batch).
    pub fn activation_mem_gb(&self) -> f64 {
        match self {
            ModelKind::ResNet50 => 3.0,
            ModelKind::Vgg19 => 4.5,
            ModelKind::Dcgan => 2.5,
            ModelKind::PointNet => 1.5,
            ModelKind::Gpt3Medium => 4.0,
            ModelKind::Gpt3Xl => 5.0,
            ModelKind::Gpt3_3B => 8.0,
        }
    }

    /// Compute intensity in [0,1]: how much of a GPU's compute the model
    /// saturates when running alone. Drives the packing-interference model.
    pub fn compute_intensity(&self) -> f64 {
        match self {
            ModelKind::ResNet50 => 0.75,
            ModelKind::Vgg19 => 0.90,
            ModelKind::Dcgan => 0.60,
            ModelKind::PointNet => 0.35,
            ModelKind::Gpt3Medium => 0.92,
            ModelKind::Gpt3Xl => 0.95,
            ModelKind::Gpt3_3B => 0.97,
        }
    }

    /// Isolated single-GPU throughput in iterations/second on the reference
    /// A100 (calibrated to the rough ratios the paper quotes, e.g. PointNet
    /// far faster per iteration than GPT3-3B in §4.2's profiling example).
    pub fn base_tput_a100(&self) -> f64 {
        match self {
            ModelKind::ResNet50 => 10.0,
            ModelKind::Vgg19 => 6.0,
            ModelKind::Dcgan => 14.0,
            ModelKind::PointNet => 50.0,
            ModelKind::Gpt3Medium => 6.0,
            ModelKind::Gpt3Xl => 3.5,
            ModelKind::Gpt3_3B => 2.0,
        }
    }
}

/// Static job specification (what a trace contains).
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: JobId,
    pub model: ModelKind,
    /// Number of GPUs requested (1, 2, 4 or 8 in the paper's traces).
    pub num_gpus: u32,
    /// Arrival time in seconds since trace start.
    pub arrival_time: f64,
    /// Total work in iterations. A job finishes once the integral of its
    /// achieved throughput reaches this.
    pub total_iters: f64,
    pub batch_size: u32,
}

impl Job {
    /// Isolated duration in seconds at `iso_tput` iterations/s — the FTF
    /// metric's ideal-share denominator uses this.
    pub fn isolated_duration(&self, iso_tput: f64) -> f64 {
        self.total_iters / iso_tput.max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_zoo_is_complete() {
        assert_eq!(ModelKind::ALL.len(), 7);
        let llms = ModelKind::ALL.iter().filter(|m| m.is_llm()).count();
        assert_eq!(llms, 3);
        for m in ModelKind::ALL {
            assert_eq!(ModelKind::from_name(m.name()), Some(m));
            let (lo, hi) = m.batch_size_range();
            assert!(lo <= hi);
            assert!(m.base_tput_a100() > 0.0);
            assert!(m.model_mem_gb() > 0.0);
            assert!((0.0..=1.0).contains(&m.compute_intensity()));
        }
    }

    #[test]
    fn llm_batch_sizes_fixed_at_512() {
        for m in [ModelKind::Gpt3Medium, ModelKind::Gpt3Xl, ModelKind::Gpt3_3B] {
            assert_eq!(m.batch_size_range(), (512, 512));
        }
    }

    #[test]
    fn gpt3_3b_has_32_layers() {
        // The paper's best-PP example for GPT3-3B, (3,3,3,4,4,5,5,5), sums
        // to 32 layers on 8 GPUs.
        assert_eq!(ModelKind::Gpt3_3B.num_layers(), 32);
    }

    #[test]
    fn from_name_rejects_unknown() {
        assert_eq!(ModelKind::from_name("bert"), None);
    }

    #[test]
    fn isolated_duration_inverts_throughput() {
        let j = Job {
            id: 1,
            model: ModelKind::ResNet50,
            num_gpus: 2,
            arrival_time: 0.0,
            total_iters: 100.0,
            batch_size: 64,
        };
        assert!((j.isolated_duration(20.0) - 5.0).abs() < 1e-12);
    }
}

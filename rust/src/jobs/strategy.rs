//! Parallelism strategies for 3D-parallel (LLM) jobs — the degree of
//! freedom §4.2 adds to packing: the scheduler may re-pick a job's
//! parallelization when packing it, boosting the bipartite edge weight
//! (Fig. 7(b), Fig. 8, Fig. 15).

use super::ModelKind;

/// A parallelization of one training job over its GPUs.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ParallelismStrategy {
    /// Pure data parallelism: one full model replica per GPU.
    DataParallel,
    /// Tensor (intra-layer) model parallelism across all GPUs.
    TensorParallel,
    /// Pipeline parallelism: `layers[g]` transformer layers on GPU `g`.
    Pipeline(Vec<u32>),
}

impl ParallelismStrategy {
    pub fn name(&self) -> String {
        match self {
            ParallelismStrategy::DataParallel => "DP".to_string(),
            ParallelismStrategy::TensorParallel => "TP".to_string(),
            ParallelismStrategy::Pipeline(split) => {
                let parts: Vec<String> = split.iter().map(|x| x.to_string()).collect();
                format!("PP({})", parts.join(","))
            }
        }
    }

    /// Megatron-LM's default: layers split as evenly as possible, with the
    /// remainder pushed onto the *front* stages (Megatron assigns
    /// ceil(L/N) to the first L mod N stages).
    pub fn default_pp(model: ModelKind, num_gpus: u32) -> ParallelismStrategy {
        let layers = model.num_layers();
        let n = num_gpus.max(1);
        let base = layers / n;
        let extra = layers % n;
        let split: Vec<u32> = (0..n)
            .map(|g| if g < extra { base + 1 } else { base })
            .collect();
        ParallelismStrategy::Pipeline(split)
    }

    /// Non-LLM jobs always use DDP (the paper's group-1 applications).
    pub fn for_non_llm() -> ParallelismStrategy {
        ParallelismStrategy::DataParallel
    }

    /// The candidate set the scheduler searches when optimizing a packed
    /// LLM's strategy (§4.2): DP, TP, the default PP split, and a family of
    /// *front-light* PP splits that put fewer layers on the leading stages
    /// (the paper's winning GPT3-3B split (3,3,3,4,4,5,5,5) is front-light).
    pub fn candidates(model: ModelKind, num_gpus: u32) -> Vec<ParallelismStrategy> {
        if !model.is_llm() || num_gpus <= 1 {
            return vec![ParallelismStrategy::DataParallel];
        }
        let mut out = vec![
            ParallelismStrategy::DataParallel,
            ParallelismStrategy::TensorParallel,
            Self::default_pp(model, num_gpus),
        ];
        for skew in [1u32, 2] {
            if let Some(s) = front_light_split(model.num_layers(), num_gpus, skew) {
                let s = ParallelismStrategy::Pipeline(s);
                if !out.contains(&s) {
                    out.push(s);
                }
            }
        }
        out
    }

    /// Stable numeric tag for hashing / table keys.
    pub fn tag(&self) -> u64 {
        match self {
            ParallelismStrategy::DataParallel => 1,
            ParallelismStrategy::TensorParallel => 2,
            ParallelismStrategy::Pipeline(split) => {
                let mut h = 3u64;
                for &x in split {
                    h = h.wrapping_mul(131).wrapping_add(x as u64 + 7);
                }
                h
            }
        }
    }

    /// Total layers covered by a pipeline split (for validation).
    pub fn pipeline_layers(&self) -> Option<u32> {
        match self {
            ParallelismStrategy::Pipeline(s) => Some(s.iter().sum()),
            _ => None,
        }
    }
}

/// Build a front-light pipeline split: stage g gets roughly
/// `avg - skew + 2*skew*g/(n-1)` layers (linearly increasing back-to-front),
/// adjusted to sum exactly to `layers`. Returns None if infeasible
/// (some stage would get < 1 layer).
fn front_light_split(layers: u32, num_gpus: u32, skew: u32) -> Option<Vec<u32>> {
    let n = num_gpus as i64;
    let l = layers as i64;
    if n <= 1 || l < n {
        return None;
    }
    let avg = l as f64 / n as f64;
    let mut split: Vec<i64> = (0..n)
        .map(|g| {
            let frac = if n > 1 { g as f64 / (n - 1) as f64 } else { 0.0 };
            (avg - skew as f64 + 2.0 * skew as f64 * frac).round() as i64
        })
        .collect();
    // Fix the sum by adjusting from the back.
    let mut diff = l - split.iter().sum::<i64>();
    let mut g = n - 1;
    while diff != 0 {
        let delta = diff.signum();
        split[g as usize] += delta;
        diff -= delta;
        g = if g == 0 { n - 1 } else { g - 1 };
    }
    if split.iter().any(|&s| s < 1) {
        return None;
    }
    Some(split.into_iter().map(|s| s as u32).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_pp_is_even_and_complete() {
        let s = ParallelismStrategy::default_pp(ModelKind::Gpt3_3B, 8);
        assert_eq!(s.pipeline_layers(), Some(32));
        if let ParallelismStrategy::Pipeline(split) = &s {
            assert_eq!(split, &vec![4, 4, 4, 4, 4, 4, 4, 4]);
        }
        let s = ParallelismStrategy::default_pp(ModelKind::Gpt3Medium, 5);
        // 24 layers over 5 GPUs: front stages get the remainder.
        assert_eq!(s.pipeline_layers(), Some(24));
        if let ParallelismStrategy::Pipeline(split) = &s {
            assert_eq!(split, &vec![5, 5, 5, 5, 4]);
        }
    }

    #[test]
    fn front_light_split_is_valid_and_ascending() {
        let s = front_light_split(32, 8, 1).unwrap();
        assert_eq!(s.iter().sum::<u32>(), 32);
        assert!(s.first().unwrap() < s.last().unwrap(), "{s:?}");
        // skew=1 over GPT3-3B reproduces the paper's shape: light front,
        // heavy back, e.g. (3,3,3,4,4,5,5,5)-like.
        assert!(s[0] <= 3, "{s:?}");
    }

    #[test]
    fn candidates_for_llm_include_all_families() {
        let c = ParallelismStrategy::candidates(ModelKind::Gpt3_3B, 8);
        assert!(c.contains(&ParallelismStrategy::DataParallel));
        assert!(c.contains(&ParallelismStrategy::TensorParallel));
        assert!(c.iter().filter(|s| matches!(s, ParallelismStrategy::Pipeline(_))).count() >= 2);
        // All pipeline candidates cover every layer exactly once.
        for s in &c {
            if let Some(total) = s.pipeline_layers() {
                assert_eq!(total, 32, "{}", s.name());
            }
        }
    }

    #[test]
    fn non_llm_only_dp() {
        let c = ParallelismStrategy::candidates(ModelKind::ResNet50, 8);
        assert_eq!(c, vec![ParallelismStrategy::DataParallel]);
    }

    #[test]
    fn single_gpu_only_dp() {
        let c = ParallelismStrategy::candidates(ModelKind::Gpt3_3B, 1);
        assert_eq!(c, vec![ParallelismStrategy::DataParallel]);
    }

    #[test]
    fn infeasible_split_rejected() {
        assert!(front_light_split(4, 8, 1).is_none());
    }

    #[test]
    fn names_render() {
        assert_eq!(ParallelismStrategy::DataParallel.name(), "DP");
        assert_eq!(
            ParallelismStrategy::Pipeline(vec![3, 3, 3, 4, 4, 5, 5, 5]).name(),
            "PP(3,3,3,4,4,5,5,5)"
        );
    }
}

//! Policies — the paper's §3 decomposition: *scheduling* policies produce a
//! priority order over active jobs; *placement* policies (allocation,
//! packing, migration) decide where those jobs land on the cluster.

pub mod placement;
pub mod scheduling;

use crate::jobs::{JobId, ModelKind};

/// A snapshot of one active job's scheduling-relevant state, assembled by
/// the simulator / coordinator each round and consumed by every policy.
#[derive(Debug, Clone)]
pub struct JobInfo {
    pub id: JobId,
    pub model: ModelKind,
    pub num_gpus: u32,
    pub arrival_time: f64,
    /// Attained service in GPU-seconds (Tiresias' 2D-LAS metric).
    pub attained_service: f64,
    pub total_iters: f64,
    pub completed_iters: f64,
    /// Rounds in which the job held GPUs.
    pub rounds_received: u64,
    /// Current simulation / wall time (s).
    pub now: f64,
    /// Scheduler-visible best isolated throughput at the job's scale.
    pub iso_tput: f64,
}

impl JobInfo {
    pub fn remaining_iters(&self) -> f64 {
        (self.total_iters - self.completed_iters).max(0.0)
    }

    /// Estimated remaining runtime if run in isolation.
    pub fn remaining_time(&self) -> f64 {
        self.remaining_iters() / self.iso_tput.max(1e-9)
    }

    pub fn waiting_time(&self) -> f64 {
        (self.now - self.arrival_time).max(0.0)
    }

    /// Themis-style finish-time-fairness ratio estimate ρ = T_shared/T_ideal:
    /// projected completion time under current treatment vs completion time
    /// in an isolated fair share of the cluster.
    pub fn ftf_rho(&self, fair_share_fraction: f64) -> f64 {
        let ideal = self.total_iters / self.iso_tput.max(1e-9);
        // Observed service rate so far (gpu-seconds per wall second).
        let elapsed = self.waiting_time().max(1.0);
        let full_service = self.num_gpus as f64 * elapsed;
        let service_rate = if full_service > 0.0 {
            (self.attained_service / full_service).clamp(1e-3, 1.0)
        } else {
            1e-3
        };
        // Projected shared completion: time to finish at the observed rate.
        let shared = elapsed + self.remaining_time() / service_rate;
        let fair = ideal / fair_share_fraction.clamp(1e-3, 1.0);
        shared / fair.max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn info(attained: f64, now: f64) -> JobInfo {
        JobInfo {
            id: 1,
            model: ModelKind::ResNet50,
            num_gpus: 2,
            arrival_time: 0.0,
            attained_service: attained,
            total_iters: 1000.0,
            completed_iters: 100.0,
            rounds_received: 1,
            now,
            iso_tput: 10.0,
        }
    }

    #[test]
    fn remaining_math() {
        let j = info(100.0, 50.0);
        assert_eq!(j.remaining_iters(), 900.0);
        assert!((j.remaining_time() - 90.0).abs() < 1e-9);
        assert_eq!(j.waiting_time(), 50.0);
    }

    #[test]
    fn ftf_rho_increases_when_starved() {
        let served = info(2.0 * 100.0, 100.0); // full service the whole time
        let starved = info(2.0 * 10.0, 100.0); // 10% service
        assert!(starved.ftf_rho(1.0) > served.ftf_rho(1.0));
    }

    #[test]
    fn ftf_rho_near_one_for_perfect_service() {
        // Full service + full fair share: shared time ≈ ideal time.
        let mut j = info(0.0, 100.0);
        j.attained_service = j.num_gpus as f64 * 100.0;
        let rho = j.ftf_rho(1.0);
        assert!(rho > 0.5 && rho < 4.0, "rho {rho}");
    }
}

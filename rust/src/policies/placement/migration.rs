//! Migration minimization (§4.1): relabel the new round's GPU ids so the
//! physical plan aligns with the previous round, minimizing Definition 1
//! migrations while preserving consolidation.
//!
//! * [`MigrationMode::Tesserae`] — Algorithms 2 + 3: node-level GPU
//!   matching (Hungarian per node pair), then node matching (Hungarian over
//!   the node cost matrix). Consolidated jobs stay consolidated because
//!   GPUs are only permuted *within* matched node pairs (§4.3).
//! * [`MigrationMode::Flat`] — Algorithm 5: one Hungarian over all GPUs
//!   (may break consolidation for multi-node jobs, Example 5).
//! * [`MigrationMode::GavelBaseline`] — no remapping: a job migrates iff
//!   its GPU set changed (the policy Fig. 1 criticizes).
//! * [`MigrationMode::None`] — identity (for ablations).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::jobs::JobId;
use crate::linalg::Matrix;
use crate::matching::{AssignmentResult, MatchingEngine};

/// Which migration policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationMode {
    Tesserae,
    Flat,
    GavelBaseline,
    None,
}

/// Result of the migration policy.
#[derive(Debug, Clone)]
pub struct MigrationOutcome {
    /// The new round's plan, relabeled onto physical GPUs.
    pub plan: PlacementPlan,
    /// Jobs (present in both rounds) whose physical GPU set changed.
    pub migrations: usize,
    /// Total matching cost (≈ #migrations, from Algorithm 2's objective).
    pub cost: f64,
    /// Wall time spent deciding.
    pub decide_time_s: f64,
}

/// Algorithm 3: optimal GPU matching between one previous-round node and
/// one new-round node. Returns (cost, assignment prev_gpu -> next_gpu).
/// Job sizes come straight from the plans' live job→GPU indexes.
fn node_level_matching(
    prev: &PlacementPlan,
    next: &PlacementPlan,
    prev_gpus: &[usize],
    next_gpus: &[usize],
    engine: &dyn MatchingEngine,
) -> (f64, AssignmentResult) {
    let k = prev_gpus.len();
    let mut c = Matrix::zeros(k, k);
    for (a, &u) in prev_gpus.iter().enumerate() {
        for (b, &v) in next_gpus.iter().enumerate() {
            c.set(
                a,
                b,
                gpu_pair_cost(
                    prev.jobs_on(u),
                    next.jobs_on(v),
                    prev.job_gpu_map(),
                    next.job_gpu_map(),
                ),
            );
        }
    }
    let sol = engine.solve_min_cost(&c);
    (sol.cost, sol)
}

/// Per-GPU migration cost between GPU `u`'s job set and GPU `v`'s job set
/// (Algorithm 3 lines 4–7): each job in the symmetric difference costs
/// 1/(2·num_gpus(job)). A job's amortization divisor is its own GPU count,
/// read from the plans' job→GPU indexes (the two rounds agree on common
/// jobs, so consult either).
fn gpu_pair_cost(
    jobs_u: &[JobId],
    jobs_v: &[JobId],
    prev_map: &BTreeMap<JobId, Vec<usize>>,
    next_map: &BTreeMap<JobId, Vec<usize>>,
) -> f64 {
    let mut cost = 0.0;
    let lookup = |j: JobId| {
        prev_map
            .get(&j)
            .or_else(|| next_map.get(&j))
            .map(|gpus| gpus.len())
            .unwrap_or(1)
            .max(1)
    };
    for &j in jobs_u {
        if !jobs_v.contains(&j) {
            cost += 1.0 / (2.0 * lookup(j) as f64);
        }
    }
    for &j in jobs_v {
        if !jobs_u.contains(&j) {
            cost += 1.0 / (2.0 * lookup(j) as f64);
        }
    }
    cost
}

/// Run the selected migration policy: produce the physical realization of
/// `next` given the physical `prev`.
pub fn migrate(
    spec: &ClusterSpec,
    prev: &PlacementPlan,
    next: &PlacementPlan,
    mode: MigrationMode,
    engine: &dyn MatchingEngine,
) -> MigrationOutcome {
    let t0 = Instant::now();
    assert_eq!(prev.num_gpus(), spec.total_gpus());
    assert_eq!(next.num_gpus(), spec.total_gpus());

    let outcome = match mode {
        MigrationMode::None | MigrationMode::GavelBaseline => MigrationOutcome {
            plan: next.clone(),
            migrations: next.migrations_from(prev),
            cost: next.migrations_from(prev) as f64,
            decide_time_s: 0.0,
        },
        MigrationMode::Flat => flat_migrate(prev, next, engine),
        MigrationMode::Tesserae => tesserae_migrate(spec, prev, next, engine),
    };
    MigrationOutcome {
        decide_time_s: t0.elapsed().as_secs_f64(),
        ..outcome
    }
}

/// Algorithm 2: remove jobs absent from either round, match GPUs within
/// node pairs (Alg. 3), then match nodes with the Hungarian algorithm.
fn tesserae_migrate(
    spec: &ClusterSpec,
    prev: &PlacementPlan,
    next: &PlacementPlan,
    engine: &dyn MatchingEngine,
) -> MigrationOutcome {
    // Line 2: restrict both plans to jobs present in both rounds.
    let common: std::collections::BTreeSet<JobId> =
        prev.jobs().intersection(&next.jobs()).copied().collect();
    let mut prev_f = prev.clone();
    let gone_prev: std::collections::BTreeSet<JobId> =
        prev.jobs().difference(&common).copied().collect();
    prev_f.remove_jobs(&gone_prev);
    let mut next_f = next.clone();
    let gone_next: std::collections::BTreeSet<JobId> =
        next.jobs().difference(&common).copied().collect();
    next_f.remove_jobs(&gone_next);

    let nodes = spec.num_nodes;
    // Lines 3-5: per node pair, Algorithm 3.
    let mut node_cost = Matrix::zeros(nodes, nodes);
    let mut node_plans: Vec<Vec<Option<AssignmentResult>>> = vec![vec![None; nodes]; nodes];
    for k in 0..nodes {
        let prev_gpus: Vec<usize> = spec.gpus_of_node(k).collect();
        for l in 0..nodes {
            let next_gpus: Vec<usize> = spec.gpus_of_node(l).collect();
            let (c, m) =
                node_level_matching(&prev_f, &next_f, &prev_gpus, &next_gpus, engine);
            node_cost.set(k, l, c);
            node_plans[k][l] = Some(m);
        }
    }
    // Line 6: Hungarian over the node cost matrix.
    let node_sol = engine.solve_min_cost(&node_cost);

    // Compose: logical GPU g (on logical node l) is realized on the
    // physical GPU chosen by the matched node pair's GPU assignment.
    let mut new_gpu_of = vec![usize::MAX; spec.total_gpus()];
    for (k, &l) in node_sol.row_to_col.iter().enumerate() {
        let m = node_plans[k][l].as_ref().unwrap();
        // m.row_to_col[a] = b: physical gpu (node k, slot a) hosts the job
        // set of logical gpu (node l, slot b).
        for (a, &b) in m.row_to_col.iter().enumerate() {
            let physical = spec.gpus_of_node(k).nth(a).unwrap();
            let logical = spec.gpus_of_node(l).nth(b).unwrap();
            new_gpu_of[logical] = physical;
        }
    }
    let plan = next.relabeled(&new_gpu_of);
    MigrationOutcome {
        migrations: plan.migrations_from(prev),
        cost: node_sol.cost,
        plan,
        decide_time_s: 0.0,
    }
}

/// Algorithm 5: flat GPU-level matching over the whole cluster.
fn flat_migrate(
    prev: &PlacementPlan,
    next: &PlacementPlan,
    engine: &dyn MatchingEngine,
) -> MigrationOutcome {
    let common: std::collections::BTreeSet<JobId> =
        prev.jobs().intersection(&next.jobs()).copied().collect();
    let mut prev_f = prev.clone();
    prev_f.remove_jobs(&prev.jobs().difference(&common).copied().collect());
    let mut next_f = next.clone();
    next_f.remove_jobs(&next.jobs().difference(&common).copied().collect());

    let n = prev.num_gpus();
    let mut c = Matrix::zeros(n, n);
    for u in 0..n {
        for v in 0..n {
            c.set(
                u,
                v,
                gpu_pair_cost(
                    prev_f.jobs_on(u),
                    next_f.jobs_on(v),
                    prev_f.job_gpu_map(),
                    next_f.job_gpu_map(),
                ),
            );
        }
    }
    let sol = engine.solve_min_cost(&c);
    // sol.row_to_col[u] = v: physical gpu u hosts logical gpu v's jobs.
    let mut new_gpu_of = vec![usize::MAX; n];
    for (u, &v) in sol.row_to_col.iter().enumerate() {
        new_gpu_of[v] = u;
    }
    let plan = next.relabeled(&new_gpu_of);
    MigrationOutcome {
        migrations: plan.migrations_from(prev),
        cost: sol.cost,
        plan,
        decide_time_s: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::matching::HungarianEngine;

    fn one_node(gpus: usize) -> ClusterSpec {
        ClusterSpec::new(1, gpus, GpuType::A100)
    }

    fn plan(total: usize, placements: &[(JobId, &[usize])]) -> PlacementPlan {
        let mut p = PlacementPlan::new(total);
        for (j, gpus) in placements {
            p.place(*j, gpus);
        }
        p
    }

    #[test]
    fn paper_example2_zero_migrations() {
        // P_i = {(0,1),(1,2),(2,3),(3,4)}, P_{i+1} = {(0,4),(1,1),(2,2),(3,3)}
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert_eq!(out.migrations, 0);
        assert!((out.cost - 0.0).abs() < 1e-9);
        // Gavel's baseline migrates all four.
        let gavel = migrate(&spec, &prev, &next, MigrationMode::GavelBaseline, &HungarianEngine);
        assert_eq!(gavel.migrations, 4);
    }

    #[test]
    fn paper_example3_one_migration_with_packing() {
        // P_i = {(0,(1,5)),(1,2),(2,3),(3,4)},
        // P_{i+1} = {(0,(4,5)),(1,1),(2,2),(3,3)} -> minimum migration 1
        // (job 5 relocates next to job 4).
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (5, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (5, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert!((out.cost - 1.0).abs() < 1e-9, "cost {}", out.cost);
        assert_eq!(out.migrations, 1);
    }

    #[test]
    fn paper_example4_disappearing_jobs_removed_first() {
        // Jobs 5 and 6 are not in both rounds: removing them first makes the
        // remap free.
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (6, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (5, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert_eq!(out.migrations, 0);
        assert!((out.cost - 0.0).abs() < 1e-9);
    }

    #[test]
    fn figure6_example_three_migrations() {
        // Figure 6 / Example 1 shape: two nodes of two GPUs; total cost 3.
        // Round i:  node0 = {g0: j1, g1: j4}, node1 = {g2: j2, g3: j3}
        // Round i+1: node0 = {g0: j6, g1: j2}, node1 = {g2: j1, g3: j5}
        // Common jobs: 1, 2 (j3/j4 leave, j5/j6 arrive). Best alignment
        // keeps j1 and j2 in place by matching prev-node0 with next-node1
        // ... one of the optimal plans relocates nothing that is common.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0]), (4, &[1]), (2, &[2]), (3, &[3])]);
        let next = plan(4, &[(6, &[0]), (2, &[1]), (1, &[2]), (5, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        // Jobs 1 and 2 can both stay put (j1: prev g0 / next node with j1
        // can map back). Migrations should be 0 here after remap.
        assert_eq!(out.migrations, 0, "plan {:?}", out.plan);
    }

    #[test]
    fn multi_gpu_job_moves_as_a_unit() {
        // A 2-GPU job relocating across nodes costs 2 × (0.5+0.5) × 1/2 = 1.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0, 1]), (2, &[2]), (3, &[3])]);
        let next = plan(4, &[(2, &[0]), (3, &[1]), (1, &[2, 3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        // Optimal: swap the node roles so nobody migrates.
        assert_eq!(out.migrations, 0);
    }

    #[test]
    fn tesserae_never_worse_than_gavel_baseline() {
        use crate::util::prop::forall;
        use crate::util::rng::Pcg64;
        forall(
            "tesserae migrations <= gavel baseline",
            71,
            40,
            |r: &mut Pcg64| {
                let spec = ClusterSpec::new(2 + r.below(3) as usize, 2, GpuType::A100);
                let total = spec.total_gpus();
                // Random single-GPU jobs in both rounds with overlap.
                let njobs = total.min(2 + r.below(total as u64) as usize);
                let mut prev = PlacementPlan::new(total);
                let mut next = PlacementPlan::new(total);
                let prev_slots = r.sample_indices(total, njobs);
                let next_slots = r.sample_indices(total, njobs);
                for j in 0..njobs {
                    prev.place(j as JobId, &[prev_slots[j]]);
                    next.place(j as JobId, &[next_slots[j]]);
                }
                (spec, prev, next)
            },
            |(spec, prev, next)| {
                let t = migrate(spec, prev, next, MigrationMode::Tesserae, &HungarianEngine);
                let g = migrate(spec, prev, next, MigrationMode::GavelBaseline, &HungarianEngine);
                if t.migrations <= g.migrations {
                    Ok(())
                } else {
                    Err(format!("{} > {}", t.migrations, g.migrations))
                }
            },
        );
    }

    #[test]
    fn tesserae_preserves_consolidation_where_flat_may_not() {
        // Example 5 shape: two 4-GPU jobs packed into one plan. The flat
        // Algorithm 5 may split them across nodes; Algorithm 2+3 must not.
        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let prev = plan(
            8,
            &[(1, &[0, 1, 2, 3]), (2, &[4, 5, 6, 7])],
        );
        // Next round packs jobs 1 and 2 on node 0's GPUs.
        let mut next = PlacementPlan::new(8);
        next.place(1, &[0, 1, 2, 3]);
        next.place(2, &[0, 1, 2, 3]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert!(out.plan.is_consolidated(1, &spec));
        assert!(out.plan.is_consolidated(2, &spec));
        out.plan.validate().unwrap();
    }

    #[test]
    fn plans_preserve_all_jobs_and_shapes() {
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2, 3])]);
        let next = plan(4, &[(3, &[0, 1]), (9, &[2]), (1, &[3])]);
        for mode in [
            MigrationMode::Tesserae,
            MigrationMode::Flat,
            MigrationMode::GavelBaseline,
            MigrationMode::None,
        ] {
            let out = migrate(&spec, &prev, &next, mode, &HungarianEngine);
            assert_eq!(out.plan.jobs(), next.jobs(), "{mode:?}");
            for j in next.jobs() {
                assert_eq!(
                    out.plan.gpus_of(j).len(),
                    next.gpus_of(j).len(),
                    "{mode:?} job {j}"
                );
            }
        }
    }

    #[test]
    fn flat_matches_tesserae_on_single_node() {
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let t = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        let f = migrate(&spec, &prev, &next, MigrationMode::Flat, &HungarianEngine);
        assert_eq!(t.migrations, f.migrations);
    }
}

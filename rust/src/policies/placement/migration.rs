//! Migration minimization (§4.1): relabel the new round's GPU ids so the
//! physical plan aligns with the previous round, minimizing Definition 1
//! migrations while preserving consolidation.
//!
//! * [`MigrationMode::Tesserae`] — Algorithms 2 + 3: node-level GPU
//!   matching (Hungarian per node pair), then node matching (Hungarian over
//!   the node cost matrix). Consolidated jobs stay consolidated because
//!   GPUs are only permuted *within* matched node pairs (§4.3).
//! * [`MigrationMode::Flat`] — Algorithm 5: one Hungarian over all GPUs
//!   (may break consolidation for multi-node jobs, Example 5).
//! * [`MigrationMode::GavelBaseline`] — no remapping: a job migrates iff
//!   its GPU set changed (the policy Fig. 1 criticizes).
//! * [`MigrationMode::None`] — identity (for ablations).

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::faults::{ClusterHealth, BLOCKER_BASE};
use crate::jobs::JobId;
use crate::matching::{
    node_sig, MatchingEngine, MatchingService, MatchingServiceStats, NodeSig,
};

/// Which migration policy to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationMode {
    Tesserae,
    Flat,
    GavelBaseline,
    None,
}

/// Result of the migration policy.
#[derive(Debug, Clone)]
pub struct MigrationOutcome {
    /// The new round's plan, relabeled onto physical GPUs.
    pub plan: PlacementPlan,
    /// Jobs (present in both rounds) whose physical GPU set changed.
    pub migrations: usize,
    /// Total matching cost (≈ #migrations, from Algorithm 2's objective).
    pub cost: f64,
    /// Wall time spent deciding.
    pub decide_time_s: f64,
    /// Matching-service counters drained at the end of the round (this is
    /// the round's last matching consumer, so with a shared service these
    /// include the packing stage's solves too).
    pub service: MatchingServiceStats,
}

/// Run the selected migration policy with a throwaway default-config
/// matching service. Same results as [`migrate_with`]; schedulers that
/// decide every round hold a persistent service instead so the
/// cross-round cost-matrix cache actually carries over.
pub fn migrate(
    spec: &ClusterSpec,
    prev: &PlacementPlan,
    next: &PlacementPlan,
    mode: MigrationMode,
    engine: &dyn MatchingEngine,
) -> MigrationOutcome {
    let mut service = MatchingService::with_defaults();
    migrate_with(spec, prev, next, mode, engine, &mut service)
}

/// Run the selected migration policy: produce the physical realization of
/// `next` given the physical `prev`. Every matching instance is routed
/// through `service` (pruned/deduped/cached/batched per its config).
pub fn migrate_with(
    spec: &ClusterSpec,
    prev: &PlacementPlan,
    next: &PlacementPlan,
    mode: MigrationMode,
    engine: &dyn MatchingEngine,
    service: &mut MatchingService,
) -> MigrationOutcome {
    migrate_masked(spec, prev, next, mode, engine, service, None)
}

/// [`migrate_with`] on a cluster with failed GPUs. Plans stay full-width
/// (a dead GPU is a GPU that must host nothing, not a missing column):
/// each dead GPU is pinned in both filtered rounds by a blocker
/// pseudo-job (`BLOCKER_BASE - gpu`), so the matcher aligns dead GPUs
/// with each other at zero cost, and any logical slot the permutation
/// still lands on a dead GPU is swapped onto an empty healthy GPU in
/// deterministic index order before migrations are counted. `health:
/// None` (or an all-healthy state) is exactly [`migrate_with`].
#[allow(clippy::too_many_arguments)]
pub fn migrate_masked(
    spec: &ClusterSpec,
    prev: &PlacementPlan,
    next: &PlacementPlan,
    mode: MigrationMode,
    engine: &dyn MatchingEngine,
    service: &mut MatchingService,
    health: Option<&ClusterHealth>,
) -> MigrationOutcome {
    let t0 = Instant::now();
    assert_eq!(prev.num_gpus(), spec.total_gpus());
    assert_eq!(next.num_gpus(), spec.total_gpus());
    let health = health.filter(|h| !h.all_healthy());

    let outcome = match mode {
        MigrationMode::None | MigrationMode::GavelBaseline => MigrationOutcome {
            plan: next.clone(),
            migrations: next.migrations_from(prev),
            cost: next.migrations_from(prev) as f64,
            decide_time_s: 0.0,
            service: service.take_round_stats(),
        },
        MigrationMode::Flat => flat_migrate(prev, next, engine, service, health),
        MigrationMode::Tesserae => {
            tesserae_migrate(spec, prev, next, engine, service, health)
        }
    };
    if let Some(h) = health {
        debug_assert!(
            matches!(mode, MigrationMode::None | MigrationMode::GavelBaseline)
                || h.validate_plan(&outcome.plan).is_ok(),
            "migration realized a job on a dead GPU: {:?}",
            h.validate_plan(&outcome.plan)
        );
    }
    MigrationOutcome {
        decide_time_s: t0.elapsed().as_secs_f64(),
        ..outcome
    }
}

/// Pin every dead GPU in both filtered rounds: evict real jobs touching a
/// dead GPU (from both plans, keeping the job sets common), then place
/// the GPU's blocker pseudo-job in both — present on the same GPU in both
/// rounds, it matches itself at zero cost and keeps the dead GPU out of
/// the real jobs' alignment.
fn inject_blockers(
    prev_f: &mut PlacementPlan,
    next_f: &mut PlacementPlan,
    health: &ClusterHealth,
) {
    let dead = health.dead_gpus();
    let mut evicted: BTreeSet<JobId> = BTreeSet::new();
    for &g in &dead {
        evicted.extend(prev_f.jobs_on(g).iter().copied());
        evicted.extend(next_f.jobs_on(g).iter().copied());
    }
    if !evicted.is_empty() {
        prev_f.remove_jobs(&evicted);
        next_f.remove_jobs(&evicted);
    }
    for &g in &dead {
        let blocker = BLOCKER_BASE - g as JobId;
        prev_f.place(blocker, &[g]);
        next_f.place(blocker, &[g]);
    }
}

/// After relabeling, displace any occupied dead GPU onto an empty healthy
/// GPU (both scanned in ascending index order — deterministic). Healthy
/// capacity always suffices: `next` placed every job on healthy GPUs, so
/// occupied GPUs number at most the healthy count.
fn repair_onto_healthy(plan: PlacementPlan, health: &ClusterHealth) -> PlacementPlan {
    let n = plan.num_gpus();
    let occupied_dead: Vec<usize> = (0..n)
        .filter(|&g| !health.is_healthy(g) && !plan.jobs_on(g).is_empty())
        .collect();
    if occupied_dead.is_empty() {
        return plan;
    }
    let mut perm: Vec<usize> = (0..n).collect();
    let mut free_healthy =
        (0..n).filter(|&g| health.is_healthy(g) && plan.jobs_on(g).is_empty());
    for g in occupied_dead {
        let h = free_healthy
            .next()
            .expect("healthy GPUs must cover every occupied slot");
        perm.swap(g, h);
    }
    plan.relabeled(&perm)
}

/// Restrict both plans to the jobs present in both rounds (Algorithm 2
/// line 2).
fn filter_to_common(
    prev: &PlacementPlan,
    next: &PlacementPlan,
) -> (PlacementPlan, PlacementPlan) {
    let common: BTreeSet<JobId> = prev.jobs().intersection(&next.jobs()).copied().collect();
    let mut prev_f = prev.clone();
    let gone_prev: BTreeSet<JobId> = prev.jobs().difference(&common).copied().collect();
    prev_f.remove_jobs(&gone_prev);
    let mut next_f = next.clone();
    let gone_next: BTreeSet<JobId> = next.jobs().difference(&common).copied().collect();
    next_f.remove_jobs(&gone_next);
    (prev_f, next_f)
}

/// Algorithm 2: remove jobs absent from either round, match GPUs within
/// node pairs (Alg. 3) — all `num_nodes²` instances as one service batch —
/// then match nodes with the Hungarian algorithm.
fn tesserae_migrate(
    spec: &ClusterSpec,
    prev: &PlacementPlan,
    next: &PlacementPlan,
    engine: &dyn MatchingEngine,
    service: &mut MatchingService,
    health: Option<&ClusterHealth>,
) -> MigrationOutcome {
    let (mut prev_f, mut next_f) = filter_to_common(prev, next);
    if let Some(h) = health {
        inject_blockers(&mut prev_f, &mut next_f, h);
    }

    let nodes = spec.num_nodes;
    // Each node's GPU list, collected once — the compose loop below indexes
    // into these instead of re-enumerating `gpus_of_node` per matched slot.
    let node_gpus: Vec<Vec<usize>> = (0..nodes)
        .map(|k| spec.gpus_of_node(k).collect())
        .collect();
    // Each signature built once and Arc-shared with the service: its n²
    // cache-key probes are then refcount bumps, not deep copies.
    let prev_sigs: Vec<Arc<NodeSig>> = node_gpus
        .iter()
        .map(|g| Arc::new(node_sig(&prev_f, g, &prev_f, &next_f)))
        .collect();
    let next_sigs: Vec<Arc<NodeSig>> = node_gpus
        .iter()
        .map(|g| Arc::new(node_sig(&next_f, g, &prev_f, &next_f)))
        .collect();

    // Lines 3-5: every node pair's Algorithm 3 instance, batched.
    let round = service.node_pair_round(engine, &prev_sigs, &next_sigs);
    // Line 6: Hungarian over the node cost matrix.
    let node_sol = service.solve_square(engine, &round.node_cost);

    // Compose: logical GPU g (on logical node l) is realized on the
    // physical GPU chosen by the matched node pair's GPU assignment.
    let mut new_gpu_of = vec![usize::MAX; spec.total_gpus()];
    for (k, &l) in node_sol.row_to_col.iter().enumerate() {
        let m = match round.assignment(k, l) {
            Some(sol) => Arc::clone(sol),
            // The pair's cost was pruned; its assignment is solved lazily
            // (and content-cached) only because the node matching chose it.
            None => service.pair_assignment(engine, &prev_sigs[k], &next_sigs[l]),
        };
        let prev_g = &node_gpus[k];
        let next_g = &node_gpus[l];
        assert_eq!(
            m.row_to_col.len(),
            prev_g.len(),
            "node-pair assignment width diverged from the node's GPU count"
        );
        // m.row_to_col[a] = b: physical gpu (node k, slot a) hosts the job
        // set of logical gpu (node l, slot b).
        for (a, &b) in m.row_to_col.iter().enumerate() {
            new_gpu_of[next_g[b]] = prev_g[a];
        }
    }
    let mut plan = next.relabeled(&new_gpu_of);
    if let Some(h) = health {
        plan = repair_onto_healthy(plan, h);
    }
    MigrationOutcome {
        migrations: plan.migrations_from(prev),
        cost: node_sol.cost,
        plan,
        decide_time_s: 0.0,
        service: service.take_round_stats(),
    }
}

/// Algorithm 5: flat GPU-level matching over the whole cluster — one
/// whole-cluster "node pair" instance of the service, content-cached so a
/// steady-state round whose filtered plans did not change is a lookup.
fn flat_migrate(
    prev: &PlacementPlan,
    next: &PlacementPlan,
    engine: &dyn MatchingEngine,
    service: &mut MatchingService,
    health: Option<&ClusterHealth>,
) -> MigrationOutcome {
    let (mut prev_f, mut next_f) = filter_to_common(prev, next);
    if let Some(h) = health {
        inject_blockers(&mut prev_f, &mut next_f, h);
    }

    let n = prev.num_gpus();
    let all_gpus: Vec<usize> = (0..n).collect();
    let prev_sig = Arc::new(node_sig(&prev_f, &all_gpus, &prev_f, &next_f));
    let next_sig = Arc::new(node_sig(&next_f, &all_gpus, &prev_f, &next_f));
    let sol = service.solve_pair(engine, &prev_sig, &next_sig);
    // sol.row_to_col[u] = v: physical gpu u hosts logical gpu v's jobs.
    let mut new_gpu_of = vec![usize::MAX; n];
    for (u, &v) in sol.row_to_col.iter().enumerate() {
        new_gpu_of[v] = u;
    }
    let mut plan = next.relabeled(&new_gpu_of);
    if let Some(h) = health {
        plan = repair_onto_healthy(plan, h);
    }
    MigrationOutcome {
        migrations: plan.migrations_from(prev),
        cost: sol.cost,
        plan,
        decide_time_s: 0.0,
        service: service.take_round_stats(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::matching::HungarianEngine;

    fn one_node(gpus: usize) -> ClusterSpec {
        ClusterSpec::new(1, gpus, GpuType::A100)
    }

    fn plan(total: usize, placements: &[(JobId, &[usize])]) -> PlacementPlan {
        let mut p = PlacementPlan::new(total);
        for (j, gpus) in placements {
            p.place(*j, gpus);
        }
        p
    }

    #[test]
    fn paper_example2_zero_migrations() {
        // P_i = {(0,1),(1,2),(2,3),(3,4)}, P_{i+1} = {(0,4),(1,1),(2,2),(3,3)}
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert_eq!(out.migrations, 0);
        assert!((out.cost - 0.0).abs() < 1e-9);
        // Gavel's baseline migrates all four.
        let gavel = migrate(&spec, &prev, &next, MigrationMode::GavelBaseline, &HungarianEngine);
        assert_eq!(gavel.migrations, 4);
    }

    #[test]
    fn paper_example3_one_migration_with_packing() {
        // P_i = {(0,(1,5)),(1,2),(2,3),(3,4)},
        // P_{i+1} = {(0,(4,5)),(1,1),(2,2),(3,3)} -> minimum migration 1
        // (job 5 relocates next to job 4).
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (5, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (5, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert!((out.cost - 1.0).abs() < 1e-9, "cost {}", out.cost);
        assert_eq!(out.migrations, 1);
    }

    #[test]
    fn paper_example4_disappearing_jobs_removed_first() {
        // Jobs 5 and 6 are not in both rounds: removing them first makes the
        // remap free.
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (6, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (5, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert_eq!(out.migrations, 0);
        assert!((out.cost - 0.0).abs() < 1e-9);
    }

    #[test]
    fn figure6_example_three_migrations() {
        // Figure 6 / Example 1 shape: two nodes of two GPUs; total cost 3.
        // Round i:  node0 = {g0: j1, g1: j4}, node1 = {g2: j2, g3: j3}
        // Round i+1: node0 = {g0: j6, g1: j2}, node1 = {g2: j1, g3: j5}
        // Common jobs: 1, 2 (j3/j4 leave, j5/j6 arrive). Best alignment
        // keeps j1 and j2 in place by matching prev-node0 with next-node1
        // ... one of the optimal plans relocates nothing that is common.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0]), (4, &[1]), (2, &[2]), (3, &[3])]);
        let next = plan(4, &[(6, &[0]), (2, &[1]), (1, &[2]), (5, &[3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        // Jobs 1 and 2 can both stay put (j1: prev g0 / next node with j1
        // can map back). Migrations should be 0 here after remap.
        assert_eq!(out.migrations, 0, "plan {:?}", out.plan);
    }

    #[test]
    fn multi_gpu_job_moves_as_a_unit() {
        // A 2-GPU job relocating across nodes costs 2 × (0.5+0.5) × 1/2 = 1.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0, 1]), (2, &[2]), (3, &[3])]);
        let next = plan(4, &[(2, &[0]), (3, &[1]), (1, &[2, 3])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        // Optimal: swap the node roles so nobody migrates.
        assert_eq!(out.migrations, 0);
    }

    #[test]
    fn tesserae_never_worse_than_gavel_baseline() {
        use crate::util::prop::forall;
        use crate::util::rng::Pcg64;
        forall(
            "tesserae migrations <= gavel baseline",
            71,
            40,
            |r: &mut Pcg64| {
                let spec = ClusterSpec::new(2 + r.below(3) as usize, 2, GpuType::A100);
                let total = spec.total_gpus();
                // Random single-GPU jobs in both rounds with overlap.
                let njobs = total.min(2 + r.below(total as u64) as usize);
                let mut prev = PlacementPlan::new(total);
                let mut next = PlacementPlan::new(total);
                let prev_slots = r.sample_indices(total, njobs);
                let next_slots = r.sample_indices(total, njobs);
                for j in 0..njobs {
                    prev.place(j as JobId, &[prev_slots[j]]);
                    next.place(j as JobId, &[next_slots[j]]);
                }
                (spec, prev, next)
            },
            |(spec, prev, next)| {
                let t = migrate(spec, prev, next, MigrationMode::Tesserae, &HungarianEngine);
                let g = migrate(spec, prev, next, MigrationMode::GavelBaseline, &HungarianEngine);
                if t.migrations <= g.migrations {
                    Ok(())
                } else {
                    Err(format!("{} > {}", t.migrations, g.migrations))
                }
            },
        );
    }

    #[test]
    fn tesserae_preserves_consolidation_where_flat_may_not() {
        // Example 5 shape: two 4-GPU jobs packed into one plan. The flat
        // Algorithm 5 may split them across nodes; Algorithm 2+3 must not.
        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let prev = plan(
            8,
            &[(1, &[0, 1, 2, 3]), (2, &[4, 5, 6, 7])],
        );
        // Next round packs jobs 1 and 2 on node 0's GPUs.
        let mut next = PlacementPlan::new(8);
        next.place(1, &[0, 1, 2, 3]);
        next.place(2, &[0, 1, 2, 3]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        assert!(out.plan.is_consolidated(1, &spec));
        assert!(out.plan.is_consolidated(2, &spec));
        out.plan.validate().unwrap();
    }

    #[test]
    fn plans_preserve_all_jobs_and_shapes() {
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2, 3])]);
        let next = plan(4, &[(3, &[0, 1]), (9, &[2]), (1, &[3])]);
        for mode in [
            MigrationMode::Tesserae,
            MigrationMode::Flat,
            MigrationMode::GavelBaseline,
            MigrationMode::None,
        ] {
            let out = migrate(&spec, &prev, &next, mode, &HungarianEngine);
            assert_eq!(out.plan.jobs(), next.jobs(), "{mode:?}");
            for j in next.jobs() {
                assert_eq!(
                    out.plan.gpus_of(j).len(),
                    next.gpus_of(j).len(),
                    "{mode:?} job {j}"
                );
            }
        }
    }

    #[test]
    fn flat_matches_tesserae_on_single_node() {
        let spec = one_node(4);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(4, &[0]), (1, &[1]), (2, &[2]), (3, &[3])]);
        let t = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        let f = migrate(&spec, &prev, &next, MigrationMode::Flat, &HungarianEngine);
        assert_eq!(t.migrations, f.migrations);
    }

    #[test]
    fn service_stats_surface_per_round() {
        // A 4-node cluster with 2 busy nodes: the stats must account for
        // every generated instance (16 node pairs + 1 node matrix) and the
        // empty pairs must prune rather than solve.
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let prev = plan(8, &[(1, &[0]), (2, &[2])]);
        let next = plan(8, &[(2, &[0]), (1, &[2])]);
        let out = migrate(&spec, &prev, &next, MigrationMode::Tesserae, &HungarianEngine);
        let s = out.service;
        assert_eq!(s.instances, 16 + 1, "16 node pairs + node matrix");
        // 4 empty×empty + 8 empty×busy pairs prune; 4 busy×busy pairs and
        // the node matrix solve eagerly; the matched empty pairs resolve
        // lazily (one zero-matrix solve, then a content-cache hit).
        assert_eq!(s.pruned, 12, "{s:?}");
        assert_eq!(s.built, s.solved, "every built matrix is solved: {s:?}");
        assert!(s.solved >= 5, "{s:?}");
        assert!(
            s.pruned + s.deduped + s.cache_hits + s.built >= s.instances,
            "every instance resolved somehow: {s:?}"
        );
        assert!(s.solve_wall_s >= 0.0);
    }

    #[test]
    fn masked_migration_keeps_jobs_off_dead_gpus() {
        use crate::matching::MatchingService;
        // Node 1 entirely dead: the next plan packs everything onto node 0,
        // and the realized plan must too — dead GPUs host nothing.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let mut health = ClusterHealth::new(4);
        health.fail_node(&spec, 1);
        let prev = plan(4, &[(1, &[0]), (2, &[1]), (3, &[2])]); // job 3 evicted
        let next = plan(4, &[(2, &[0]), (1, &[1])]);
        for mode in [MigrationMode::Tesserae, MigrationMode::Flat] {
            let mut svc = MatchingService::with_defaults();
            let out = migrate_masked(
                &spec,
                &prev,
                &next,
                mode,
                &HungarianEngine,
                &mut svc,
                Some(&health),
            );
            out.plan.validate().unwrap();
            health.validate_plan(&out.plan).unwrap();
            assert_eq!(out.plan.jobs(), next.jobs(), "{mode:?}");
            // Blockers never leak into the realized plan.
            assert!(out.plan.jobs().iter().all(|&j| j < 1_000_000), "{mode:?}");
        }
    }

    #[test]
    fn masked_migration_is_deterministic_and_minimizes() {
        use crate::matching::MatchingService;
        // GPU 1 dies; jobs keep their healthy slots, so a fault round with
        // an unchanged remainder must realize zero migrations — twice,
        // identically.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let mut health = ClusterHealth::new(4);
        health.fail_gpu(1);
        let prev = plan(4, &[(1, &[0]), (3, &[2]), (4, &[3])]);
        let next = plan(4, &[(1, &[0]), (3, &[2]), (4, &[3])]);
        let run = || {
            let mut svc = MatchingService::with_defaults();
            migrate_masked(
                &spec,
                &prev,
                &next,
                MigrationMode::Tesserae,
                &HungarianEngine,
                &mut svc,
                Some(&health),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.plan, b.plan, "masked migration must replay identically");
        assert_eq!(a.migrations, 0, "stable jobs must not migrate: {:?}", a.plan);
    }

    #[test]
    fn masked_none_health_matches_unmasked_bitwise() {
        use crate::matching::MatchingService;
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let prev = plan(4, &[(1, &[0]), (4, &[1]), (2, &[2]), (3, &[3])]);
        let next = plan(4, &[(6, &[0]), (2, &[1]), (1, &[2]), (5, &[3])]);
        let all_healthy = ClusterHealth::new(4);
        for mode in [MigrationMode::Tesserae, MigrationMode::Flat] {
            let mut s1 = MatchingService::with_defaults();
            let mut s2 = MatchingService::with_defaults();
            let mut s3 = MatchingService::with_defaults();
            let plain = migrate_with(&spec, &prev, &next, mode, &HungarianEngine, &mut s1);
            let none =
                migrate_masked(&spec, &prev, &next, mode, &HungarianEngine, &mut s2, None);
            let healthy = migrate_masked(
                &spec,
                &prev,
                &next,
                mode,
                &HungarianEngine,
                &mut s3,
                Some(&all_healthy),
            );
            assert_eq!(plain.plan, none.plan, "{mode:?}");
            assert_eq!(plain.plan, healthy.plan, "{mode:?}");
            assert_eq!(plain.migrations, none.migrations, "{mode:?}");
            assert_eq!(plain.cost.to_bits(), healthy.cost.to_bits(), "{mode:?}");
        }
    }

    #[test]
    fn persistent_service_matches_throwaway_service() {
        // A service carried across rounds (cache warm) must produce exactly
        // what per-call throwaway services produce.
        use crate::matching::MatchingService;
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let rounds = [
            plan(4, &[(1, &[0]), (2, &[1]), (3, &[2])]),
            plan(4, &[(3, &[0]), (1, &[1]), (2, &[2])]),
            // Three identical rounds at the tail: the second and third
            // replay of the same contents must hit the warm cache.
            plan(4, &[(1, &[0]), (2, &[1]), (4, &[3])]),
            plan(4, &[(1, &[0]), (2, &[1]), (4, &[3])]),
            plan(4, &[(1, &[0]), (2, &[1]), (4, &[3])]),
        ];
        let mut svc = MatchingService::with_defaults();
        let mut total_hits = 0;
        for w in rounds.windows(2) {
            let warm = migrate_with(
                &spec,
                &w[0],
                &w[1],
                MigrationMode::Tesserae,
                &HungarianEngine,
                &mut svc,
            );
            let cold = migrate(&spec, &w[0], &w[1], MigrationMode::Tesserae, &HungarianEngine);
            assert_eq!(warm.plan, cold.plan);
            assert_eq!(warm.migrations, cold.migrations);
            assert_eq!(warm.cost.to_bits(), cold.cost.to_bits());
            total_hits += warm.service.cache_hits;
        }
        assert!(total_hits > 0, "stable rounds should hit the warm cache");
    }
}

//! Job packing as maximum-weight bipartite matching (§4.2, Algorithm 4,
//! Fig. 7): placed jobs on one side, pending jobs on the other; an edge
//! connects jobs that request the *same* number of GPUs and fit together in
//! memory; the edge weight is the profiled combined normalized throughput.
//! When the strategy dimension is enabled (Fig. 7(b), Fig. 15), the weight
//! of each edge is maximized over the LLM candidates' parallelism
//! strategies, and the chosen strategies ride along with the match.

use std::collections::BTreeSet;
use std::time::Instant;

use crate::estimator::ThroughputSource;
use crate::jobs::{JobId, ParallelismStrategy};
use crate::matching::{Edge, MatchingEngine, MatchingService};
use crate::policies::JobInfo;

/// How packed LLMs pick their parallelism strategy (Fig. 15's arms).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyMode {
    /// Always data-parallel.
    DpOnly,
    /// Megatron-LM's default (even) pipeline split.
    DefaultPp,
    /// Search the candidate set for the best packed combination.
    Best,
}

/// Packing policy configuration.
#[derive(Debug, Clone)]
pub struct PackingConfig {
    /// Only pack jobs requesting at most this many GPUs (Tiresias (Single)
    /// packs only 1-GPU jobs, §6.1).
    pub max_pack_gpus: u32,
    pub strategy_mode: StrategyMode,
    /// Jobs that must not be packed (high priority / deadline, §4.3).
    pub exempt: BTreeSet<JobId>,
    /// Minimum combined normalized throughput for an edge to exist.
    /// 1.0 = "packing must beat running the placed job alone" (default;
    /// the weight>1 ablation is benchmarked in bench_packing).
    pub min_weight: f64,
}

impl Default for PackingConfig {
    fn default() -> Self {
        PackingConfig {
            max_pack_gpus: 8,
            strategy_mode: StrategyMode::Best,
            exempt: BTreeSet::new(),
            min_weight: 1.0,
        }
    }
}

/// A chosen packing: pending job `pending` shares `placed`'s GPUs, with the
/// strategies that maximized the pair's combined normalized throughput.
#[derive(Debug, Clone)]
pub struct PackedPair {
    pub placed: JobId,
    pub pending: JobId,
    pub weight: f64,
    pub placed_strategy: ParallelismStrategy,
    pub pending_strategy: ParallelismStrategy,
    pub decide_time_s: f64,
}

/// Strategy candidates for a job under a strategy mode.
fn candidates(info: &JobInfo, mode: StrategyMode) -> Vec<ParallelismStrategy> {
    if !info.model.is_llm() || info.num_gpus == 1 {
        return vec![ParallelismStrategy::DataParallel];
    }
    match mode {
        StrategyMode::DpOnly => vec![ParallelismStrategy::DataParallel],
        StrategyMode::DefaultPp => {
            vec![ParallelismStrategy::default_pp(info.model, info.num_gpus)]
        }
        StrategyMode::Best => ParallelismStrategy::candidates(info.model, info.num_gpus),
    }
}

/// Best (weight, strategy_a, strategy_b) over the candidate cross product;
/// `None` if every combination OOMs. Candidate strategy sets are computed
/// once per job by the caller (not per pair) — with n placed and m pending
/// jobs the edge loop evaluates n·m pairs, and re-enumerating pipeline
/// splits inside it dominated packing decision time at paper scale.
fn best_edge(
    a: &JobInfo,
    b: &JobInfo,
    a_cands: &[ParallelismStrategy],
    b_cands: &[ParallelismStrategy],
    source: &dyn ThroughputSource,
) -> Option<(f64, ParallelismStrategy, ParallelismStrategy)> {
    let n = a.num_gpus;
    let mut best: Option<(f64, ParallelismStrategy, ParallelismStrategy)> = None;
    for sa in a_cands {
        for sb in b_cands {
            if let Some((wa, wb)) = source.normalized_pair((a.model, sa), (b.model, sb), n) {
                let w = wa + wb;
                if best.as_ref().map(|(bw, _, _)| w > *bw).unwrap_or(true) {
                    best = Some((w, sa.clone(), sb.clone()));
                }
            }
        }
    }
    best
}

/// Algorithm 4: build the bipartite graph and solve maximum-weight matching.
///
/// Edges only connect jobs with equal GPU counts, so the global matching
/// decomposes exactly into one independent matching per GPU-count group —
/// solving per group shrinks the Hungarian instances from
/// (placed+pending)² to the group sizes (a large hot-path win at paper
/// scale; see EXPERIMENTS.md §Perf).
pub fn pack(
    placed: &[&JobInfo],
    pending: &[&JobInfo],
    source: &dyn ThroughputSource,
    cfg: &PackingConfig,
    engine: &dyn MatchingEngine,
) -> Vec<PackedPair> {
    let mut service = MatchingService::with_defaults();
    pack_with(placed, pending, source, cfg, engine, &mut service)
}

/// [`pack`] with the matching solves routed through a caller-owned
/// [`MatchingService`], so packing's matchings land in the same per-round
/// service stats as the migration stage's.
pub fn pack_with(
    placed: &[&JobInfo],
    pending: &[&JobInfo],
    source: &dyn ThroughputSource,
    cfg: &PackingConfig,
    engine: &dyn MatchingEngine,
    service: &mut MatchingService,
) -> Vec<PackedPair> {
    let t0 = Instant::now();
    if placed.is_empty() || pending.is_empty() {
        return vec![];
    }
    let mut groups: std::collections::BTreeMap<u32, (Vec<usize>, Vec<usize>)> =
        std::collections::BTreeMap::new();
    for (i, pl) in placed.iter().enumerate() {
        if !cfg.exempt.contains(&pl.id) && pl.num_gpus <= cfg.max_pack_gpus {
            groups.entry(pl.num_gpus).or_default().0.push(i);
        }
    }
    for (j, pe) in pending.iter().enumerate() {
        if !cfg.exempt.contains(&pe.id) && pe.num_gpus <= cfg.max_pack_gpus {
            groups.entry(pe.num_gpus).or_default().1.push(j);
        }
    }

    let mut out = Vec::new();
    for (_gpus, (pl_idx, pe_idx)) in groups {
        if pl_idx.is_empty() || pe_idx.is_empty() {
            continue;
        }
        // Strategy candidates once per job, not once per edge.
        let pl_cands: Vec<Vec<ParallelismStrategy>> = pl_idx
            .iter()
            .map(|&i| candidates(placed[i], cfg.strategy_mode))
            .collect();
        let pe_cands: Vec<Vec<ParallelismStrategy>> = pe_idx
            .iter()
            .map(|&j| candidates(pending[j], cfg.strategy_mode))
            .collect();
        // The group's placed × pending candidate-edge evaluations are
        // independent throughput lookups — the packing hot path at paper
        // scale — so they shard per placed-side row across the shared
        // worker pool. Each worker filters its own row (packing only
        // helps if the combined throughput beats the configured
        // threshold; default 1.0: running the placed job alone), so only
        // surviving edges are ever materialized; rows concatenate
        // in-order, keeping the edge list bit-identical to an inline
        // double loop.
        let row_edges = crate::util::pool::WorkerPool::global().map(&pl_idx, 0, 8, |gi, &i| {
            pe_idx
                .iter()
                .enumerate()
                .filter_map(|(gj, &j)| {
                    best_edge(placed[i], pending[j], &pl_cands[gi], &pe_cands[gj], source)
                        .filter(|(w, _, _)| *w > cfg.min_weight)
                        .map(|(w, sa, sb)| (gj, w, sa, sb))
                })
                .collect::<Vec<_>>()
        });
        let mut edges: Vec<Edge> = Vec::new();
        let mut meta: Vec<(usize, usize, ParallelismStrategy, ParallelismStrategy)> = Vec::new();
        for (gi, row) in row_edges.into_iter().enumerate() {
            for (gj, w, sa, sb) in row {
                edges.push((gi, gj, w));
                meta.push((gi, gj, sa, sb));
            }
        }
        if edges.is_empty() {
            continue;
        }
        let matches = service.max_weight(engine, pl_idx.len(), pe_idx.len(), &edges);
        for m in matches {
            let (_, _, sa, sb) = meta
                .iter()
                .find(|(i, j, _, _)| *i == m.left && *j == m.right)
                .expect("matched edge must exist");
            out.push(PackedPair {
                placed: placed[pl_idx[m.left]].id,
                pending: pending[pe_idx[m.right]].id,
                weight: m.weight,
                placed_strategy: sa.clone(),
                pending_strategy: sb.clone(),
                decide_time_s: 0.0,
            });
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    for p in &mut out {
        p.decide_time_s = dt;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind::{self, *};
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, model: ModelKind, gpus: u32) -> JobInfo {
        JobInfo {
            id,
            model,
            num_gpus: gpus,
            arrival_time: 0.0,
            attained_service: 0.0,
            total_iters: 1000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 0.0,
            iso_tput: 10.0,
        }
    }

    fn oracle() -> OracleEstimator {
        OracleEstimator::new(Profiler::new(GpuType::A100, 42))
    }

    #[test]
    fn packs_only_equal_gpu_counts() {
        let placed = [info(1, PointNet, 1), info(2, ResNet50, 2)];
        let pending = [info(3, Dcgan, 4)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let out = pack(&pl, &pe, &oracle(), &PackingConfig::default(), &HungarianEngine);
        assert!(out.is_empty());
    }

    #[test]
    fn beneficial_pairs_get_packed() {
        let placed = [info(1, PointNet, 1)];
        let pending = [info(2, Dcgan, 1)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let out = pack(&pl, &pe, &oracle(), &PackingConfig::default(), &HungarianEngine);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].placed, 1);
        assert_eq!(out[0].pending, 2);
        assert!(out[0].weight > 1.0);
    }

    #[test]
    fn each_job_packed_at_most_once() {
        let placed = [info(1, PointNet, 1), info(2, Dcgan, 1)];
        let pending = [info(3, ResNet50, 1), info(4, PointNet, 1), info(5, Dcgan, 1)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let out = pack(&pl, &pe, &oracle(), &PackingConfig::default(), &HungarianEngine);
        assert!(out.len() <= 2);
        let mut seen = BTreeSet::new();
        for p in &out {
            assert!(seen.insert(p.placed));
            assert!(seen.insert(p.pending));
        }
    }

    #[test]
    fn exempt_jobs_never_packed() {
        let placed = [info(1, PointNet, 1)];
        let pending = [info(2, Dcgan, 1)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let cfg = PackingConfig {
            exempt: [1u64].into_iter().collect(),
            ..Default::default()
        };
        assert!(pack(&pl, &pe, &oracle(), &cfg, &HungarianEngine).is_empty());
    }

    #[test]
    fn single_mode_skips_distributed_jobs() {
        // Tiresias (Single): only 1-GPU jobs pack.
        let placed = [info(1, ResNet50, 2), info(2, PointNet, 1)];
        let pending = [info(3, Dcgan, 2), info(4, Dcgan, 1)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let cfg = PackingConfig {
            max_pack_gpus: 1,
            ..Default::default()
        };
        let out = pack(&pl, &pe, &oracle(), &cfg, &HungarianEngine);
        assert_eq!(out.len(), 1);
        assert_eq!((out[0].placed, out[0].pending), (2, 4));
    }

    #[test]
    fn strategy_search_beats_default_pp() {
        // Fig. 8 / Fig. 15: GPT3-3B packed with ResNet-50 on 8 GPUs gains
        // from a non-default pipeline split.
        let placed = [info(1, Gpt3_3B, 8)];
        let pending = [info(2, ResNet50, 8)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let src = oracle();
        let best = pack(
            &pl,
            &pe,
            &src,
            &PackingConfig {
                strategy_mode: StrategyMode::Best,
                ..Default::default()
            },
            &HungarianEngine,
        );
        let default = pack(
            &pl,
            &pe,
            &src,
            &PackingConfig {
                strategy_mode: StrategyMode::DefaultPp,
                ..Default::default()
            },
            &HungarianEngine,
        );
        assert_eq!(best.len(), 1);
        let bw = best[0].weight;
        let dw = default.first().map(|p| p.weight).unwrap_or(0.0);
        assert!(bw > dw, "best {bw} vs default {dw}");
        // And the chosen split is not the even default.
        assert_ne!(
            best[0].placed_strategy,
            ParallelismStrategy::default_pp(Gpt3_3B, 8)
        );
    }

    #[test]
    fn oom_pairs_excluded() {
        // VGG-19 + GPT3-3B at default PP OOMs; with DefaultPp mode the edge
        // must be dropped entirely.
        let placed = [info(1, Gpt3_3B, 8)];
        let pending = [info(2, Vgg19, 8)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let out = pack(
            &pl,
            &pe,
            &oracle(),
            &PackingConfig {
                strategy_mode: StrategyMode::DefaultPp,
                ..Default::default()
            },
            &HungarianEngine,
        );
        assert!(out.is_empty());
    }

    #[test]
    fn pack_with_service_matches_direct_engine_path() {
        let placed = [info(1, PointNet, 1), info(2, Dcgan, 1), info(5, ResNet50, 2)];
        let pending = [info(3, ResNet50, 1), info(4, PointNet, 1), info(6, Dcgan, 2)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let src = oracle();
        let cfg = PackingConfig::default();
        let direct = pack(&pl, &pe, &src, &cfg, &HungarianEngine);
        let mut service = MatchingService::with_defaults();
        let routed = pack_with(&pl, &pe, &src, &cfg, &HungarianEngine, &mut service);
        assert_eq!(direct.len(), routed.len());
        for (a, b) in direct.iter().zip(&routed) {
            assert_eq!((a.placed, a.pending), (b.placed, b.pending));
            assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        }
        // The service saw one matching instance per GPU-count group.
        let stats = service.take_round_stats();
        assert!(stats.instances >= 1);
        assert_eq!(stats.instances, stats.solved);
    }

    #[test]
    fn harmful_packing_rejected() {
        // Two VGG-19s barely exceed 1.0 combined; whether packed depends on
        // the weight threshold — either way the outcome is consistent with
        // the weight rule (packed iff weight > 1).
        let placed = [info(1, Vgg19, 1)];
        let pending = [info(2, Vgg19, 1)];
        let pl: Vec<&JobInfo> = placed.iter().collect();
        let pe: Vec<&JobInfo> = pending.iter().collect();
        let src = oracle();
        let out = pack(&pl, &pe, &src, &PackingConfig::default(), &HungarianEngine);
        let dp = ParallelismStrategy::DataParallel;
        let truth = src
            .normalized_pair((Vgg19, &dp), (Vgg19, &dp), 1)
            .map(|(a, b)| a + b)
            .unwrap_or(0.0);
        assert_eq!(out.len(), usize::from(truth > 1.0));
    }
}

//! Placement policies (§3.2 Listing 1, §4): consolidated allocation without
//! packing, graph-matching job packing (Algorithm 4) and graph-matching
//! migration minimization (Algorithms 2, 3, 5).

pub mod allocate;
pub mod migration;
pub mod packing;

pub use allocate::{allocate_masked, allocate_without_packing, Allocation};
pub use migration::{
    migrate, migrate_masked, migrate_with, MigrationMode, MigrationOutcome,
};
pub use packing::{pack, pack_with, PackedPair, PackingConfig, StrategyMode};

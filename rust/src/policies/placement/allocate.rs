//! Allocation without packing (Listing 1 lines 5–12, Fig. 5): walk the
//! priority-ordered jobs and give each a *consolidated* placement while
//! GPUs remain; jobs that cannot be placed become `pending` (packing
//! candidates).

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::faults::ClusterHealth;
use crate::jobs::JobId;
use crate::policies::JobInfo;

/// Result of the no-packing allocation pass.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub plan: PlacementPlan,
    /// Jobs placed, in priority order.
    pub placed: Vec<JobId>,
    /// Jobs that could not be placed, in priority order.
    pub pending: Vec<JobId>,
}

/// Place as many jobs as possible, in the given priority order, without GPU
/// sharing and under the consolidation constraint:
///
/// * a job with `k ≤ gpus_per_node` GPUs must fit on one node (best-fit:
///   the feasible node with the fewest free GPUs, to limit fragmentation);
/// * a job with `k > gpus_per_node` takes whole empty nodes.
pub fn allocate_without_packing(
    spec: &ClusterSpec,
    ordered: &[&JobInfo],
) -> Allocation {
    allocate_masked(spec, ordered, None)
}

/// [`allocate_without_packing`] over the healthy subset of the cluster:
/// dead GPUs are excluded from every node's free list (a node with a dead
/// GPU can never satisfy a whole-node placement), so no job is ever
/// allocated onto a failed GPU. `health: None` is byte-for-byte the
/// unmasked walk.
pub fn allocate_masked(
    spec: &ClusterSpec,
    ordered: &[&JobInfo],
    health: Option<&ClusterHealth>,
) -> Allocation {
    let mut plan = PlacementPlan::new(spec.total_gpus());
    let mut free_per_node: Vec<Vec<usize>> = (0..spec.num_nodes)
        .map(|n| {
            spec.gpus_of_node(n)
                .filter(|&g| match health {
                    Some(h) => h.is_healthy(g),
                    None => true,
                })
                .collect()
        })
        .collect();
    let mut remaining: usize = free_per_node.iter().map(Vec::len).sum();
    let mut placed = Vec::new();
    let mut pending = Vec::new();

    for info in ordered {
        let k = info.num_gpus as usize;
        if remaining == 0 {
            pending.push(info.id);
            continue;
        }
        if k <= spec.gpus_per_node {
            // Best fit: feasible node with minimum free GPUs.
            let node = free_per_node
                .iter()
                .enumerate()
                .filter(|(_, free)| free.len() >= k)
                .min_by_key(|(_, free)| free.len())
                .map(|(n, _)| n);
            match node {
                Some(n) => {
                    let gpus: Vec<usize> = free_per_node[n].drain(..k).collect();
                    plan.place(info.id, &gpus);
                    remaining -= k;
                    placed.push(info.id);
                }
                None => pending.push(info.id),
            }
        } else {
            // Whole-node placement for jobs larger than a node.
            let nodes_needed = k.div_ceil(spec.gpus_per_node);
            let full_nodes: Vec<usize> = free_per_node
                .iter()
                .enumerate()
                .filter(|(_, free)| free.len() == spec.gpus_per_node)
                .map(|(n, _)| n)
                .take(nodes_needed)
                .collect();
            if full_nodes.len() == nodes_needed {
                let mut gpus = Vec::with_capacity(k);
                for &n in &full_nodes {
                    gpus.append(&mut free_per_node[n]);
                }
                gpus.truncate(k);
                plan.place(info.id, &gpus);
                remaining -= k;
                placed.push(info.id);
            } else {
                pending.push(info.id);
            }
        }
    }

    // The allocator only ever appends via `place`, which keeps the plan's
    // job→GPU index in lockstep with the slots; cross-check in debug builds.
    debug_assert!(plan.validate().is_ok());
    Allocation {
        plan,
        placed,
        pending,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::jobs::ModelKind;

    fn job(id: u64, gpus: u32) -> JobInfo {
        JobInfo {
            id,
            model: ModelKind::ResNet50,
            num_gpus: gpus,
            arrival_time: 0.0,
            attained_service: 0.0,
            total_iters: 100.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 0.0,
            iso_tput: 10.0,
        }
    }

    fn spec() -> ClusterSpec {
        ClusterSpec::new(2, 4, GpuType::A100) // 8 GPUs
    }

    #[test]
    fn fills_in_priority_order() {
        let s = spec();
        let jobs = vec![job(1, 4), job(2, 2), job(3, 1), job(4, 1)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        assert_eq!(a.placed, vec![1, 2, 3, 4]);
        assert!(a.pending.is_empty());
        a.plan.validate().unwrap();
        for j in &a.placed {
            assert!(a.plan.is_consolidated(*j, &s), "job {j} not consolidated");
        }
    }

    #[test]
    fn lower_priority_fills_leftover_gpus() {
        // Listing 1's `continue`: a big job that does not fit must not stop
        // smaller, lower-priority jobs from using the remaining GPUs.
        let s = spec();
        let jobs = vec![job(1, 8), job(2, 8), job(3, 1)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        assert_eq!(a.placed, vec![1]);
        assert_eq!(a.pending, vec![2, 3]);

        let jobs = vec![job(1, 4), job(2, 8), job(3, 2)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        // Job 2 (8 GPUs) can't fit after job 1 takes a node; job 3 still
        // lands on the free node.
        assert_eq!(a.placed, vec![1, 3]);
        assert_eq!(a.pending, vec![2]);
    }

    #[test]
    fn best_fit_limits_fragmentation() {
        let s = spec();
        // Job 1 leaves node 0 with 2 free; job 2 (2 GPUs) should take those
        // instead of breaking the empty node.
        let jobs = vec![job(1, 2), job(2, 2), job(3, 4)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        assert_eq!(a.placed, vec![1, 2, 3]);
        // Jobs 1+2 share node 0; job 3 gets node 1 intact.
        let g3 = a.plan.gpus_of(3);
        assert_eq!(g3, vec![4, 5, 6, 7]);
    }

    #[test]
    fn eight_gpu_job_takes_two_full_nodes() {
        let s = spec();
        let jobs = vec![job(1, 8)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        assert_eq!(a.placed, vec![1]);
        assert_eq!(a.plan.gpus_of(1).len(), 8);
        assert!(a.plan.is_consolidated(1, &s));
    }

    #[test]
    fn no_space_all_pending() {
        let s = spec();
        let jobs = vec![job(1, 8), job(2, 4), job(3, 4), job(4, 1)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        assert_eq!(a.placed, vec![1]);
        assert_eq!(a.pending, vec![2, 3, 4]);
    }

    #[test]
    fn masked_allocation_avoids_dead_gpus() {
        let s = spec();
        let mut health = ClusterHealth::new(s.total_gpus());
        health.fail_gpu(1); // node 0 loses a GPU
        let jobs = vec![job(1, 4), job(2, 2), job(3, 1), job(4, 1), job(5, 1)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_masked(&s, &refs, Some(&health));
        // 7 healthy GPUs: the 4-GPU job must take the intact node 1.
        assert_eq!(a.plan.gpus_of(1), vec![4, 5, 6, 7]);
        health.validate_plan(&a.plan).unwrap();
        // All 7 healthy GPUs are used; nothing lands on GPU 1.
        assert_eq!(a.placed.len(), 5);
        assert!(a.plan.jobs_on(1).is_empty());
    }

    #[test]
    fn masked_whole_node_jobs_skip_degraded_nodes() {
        let s = spec();
        let mut health = ClusterHealth::new(s.total_gpus());
        health.fail_gpu(6); // node 1 degraded: no full node pair remains
        let jobs = vec![job(1, 8), job(2, 1)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_masked(&s, &refs, Some(&health));
        assert_eq!(a.pending, vec![1], "8-GPU job needs two intact nodes");
        assert_eq!(a.placed, vec![2]);
    }

    #[test]
    fn none_health_is_identical_to_unmasked() {
        let s = spec();
        let jobs = vec![job(1, 4), job(2, 2), job(3, 1), job(4, 8)];
        let refs: Vec<&JobInfo> = jobs.iter().collect();
        let a = allocate_without_packing(&s, &refs);
        let b = allocate_masked(&s, &refs, None);
        assert_eq!(a.plan, b.plan);
        assert_eq!(a.placed, b.placed);
        assert_eq!(a.pending, b.pending);
    }
}

//! Scheduling policies: each produces a priority order over active jobs
//! (index 0 = highest priority). Tesserae's design (§3.2, Listing 1 line 3)
//! lets any of these compose with the placement policies unchanged.

use super::JobInfo;

/// A scheduling policy orders active jobs by priority.
pub trait SchedulingPolicy: Send + Sync {
    fn name(&self) -> &'static str;
    /// Return indices into `jobs`, highest priority first.
    fn order(&self, jobs: &[JobInfo]) -> Vec<usize>;
}

fn sort_by_key<F: FnMut(&JobInfo) -> f64>(jobs: &[JobInfo], mut key: F) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..jobs.len()).collect();
    idx.sort_by(|&a, &b| {
        key(&jobs[a])
            .partial_cmp(&key(&jobs[b]))
            .unwrap()
            .then(jobs[a].id.cmp(&jobs[b].id))
    });
    idx
}

/// First-in-first-out by arrival time.
#[derive(Debug, Default)]
pub struct Fifo;

impl SchedulingPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn order(&self, jobs: &[JobInfo]) -> Vec<usize> {
        sort_by_key(jobs, |j| j.arrival_time)
    }
}

/// Shortest remaining time first.
#[derive(Debug, Default)]
pub struct Srtf;

impl SchedulingPolicy for Srtf {
    fn name(&self) -> &'static str {
        "srtf"
    }

    fn order(&self, jobs: &[JobInfo]) -> Vec<usize> {
        sort_by_key(jobs, |j| j.remaining_time())
    }
}

/// Tiresias' discretized 2D-LAS: attained service (GPU-seconds) bucketed
/// into exponentially growing queues; FIFO within a queue. New jobs (lowest
/// attained service) get the highest priority, which is what makes LAS
/// favour short jobs.
#[derive(Debug)]
pub struct TiresiasLas {
    /// Attained-service width of the first queue (GPU-seconds).
    pub queue_threshold: f64,
}

impl Default for TiresiasLas {
    fn default() -> Self {
        // One round (6 min) on one GPU lands a job in queue 1.
        TiresiasLas {
            queue_threshold: 360.0,
        }
    }
}

impl TiresiasLas {
    fn queue_level(&self, attained: f64) -> u32 {
        if attained < self.queue_threshold {
            0
        } else {
            1 + (attained / self.queue_threshold).log2().floor() as u32
        }
    }
}

impl SchedulingPolicy for TiresiasLas {
    fn name(&self) -> &'static str {
        "tiresias-las"
    }

    fn order(&self, jobs: &[JobInfo]) -> Vec<usize> {
        sort_by_key(jobs, |j| {
            // (queue level, arrival) lexicographic via scaled composite.
            self.queue_level(j.attained_service) as f64 * 1e12 + j.arrival_time
        })
    }
}

/// Themis-style finish-time fairness: schedule the jobs with the *worst*
/// (largest) projected FTF ratio ρ first.
#[derive(Debug)]
pub struct ThemisFtf {
    /// Fraction of the cluster a job would get in an equal-share ideal.
    pub fair_share_fraction: f64,
}

impl Default for ThemisFtf {
    fn default() -> Self {
        ThemisFtf {
            fair_share_fraction: 1.0,
        }
    }
}

impl SchedulingPolicy for ThemisFtf {
    fn name(&self) -> &'static str {
        "themis-ftf"
    }

    fn order(&self, jobs: &[JobInfo]) -> Vec<usize> {
        sort_by_key(jobs, |j| -j.ftf_rho(self.fair_share_fraction))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::jobs::ModelKind;

    fn job(id: u64, arrival: f64, attained: f64, remaining_iters: f64) -> JobInfo {
        JobInfo {
            id,
            model: ModelKind::ResNet50,
            num_gpus: 1,
            arrival_time: arrival,
            attained_service: attained,
            total_iters: remaining_iters,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 10_000.0,
            iso_tput: 10.0,
        }
    }

    #[test]
    fn fifo_orders_by_arrival() {
        let jobs = vec![job(1, 50.0, 0.0, 10.0), job(2, 10.0, 0.0, 10.0)];
        assert_eq!(Fifo.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn srtf_orders_by_remaining() {
        let jobs = vec![job(1, 0.0, 0.0, 1000.0), job(2, 0.0, 0.0, 10.0)];
        assert_eq!(Srtf.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn las_prefers_low_attained_service() {
        let p = TiresiasLas::default();
        let jobs = vec![
            job(1, 0.0, 100_000.0, 10.0), // long-served job
            job(2, 500.0, 0.0, 10.0),     // fresh job
        ];
        assert_eq!(p.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn las_fifo_within_queue() {
        let p = TiresiasLas::default();
        let jobs = vec![job(1, 50.0, 10.0, 10.0), job(2, 10.0, 20.0, 10.0)];
        // Same queue (both < threshold) -> FIFO by arrival.
        assert_eq!(p.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn las_queue_levels_grow_exponentially() {
        let p = TiresiasLas::default();
        assert_eq!(p.queue_level(0.0), 0);
        assert_eq!(p.queue_level(359.0), 0);
        assert_eq!(p.queue_level(360.0), 1);
        assert_eq!(p.queue_level(720.0), 2);
        assert_eq!(p.queue_level(1440.0), 3);
    }

    #[test]
    fn ftf_prefers_starved_jobs() {
        let p = ThemisFtf::default();
        let mut starved = job(1, 0.0, 10.0, 1000.0);
        starved.completed_iters = 1.0;
        let mut served = job(2, 0.0, 9_900.0, 1000.0);
        served.completed_iters = 900.0;
        let jobs = vec![served, starved];
        assert_eq!(p.order(&jobs), vec![1, 0]);
    }

    #[test]
    fn deterministic_tiebreak_by_id() {
        let jobs = vec![job(5, 1.0, 0.0, 10.0), job(3, 1.0, 0.0, 10.0)];
        assert_eq!(Fifo.order(&jobs), vec![1, 0]);
    }
}

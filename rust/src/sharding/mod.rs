//! Sharded multi-cluster coordinator: the 10k-node / 100k-job scale-out.
//!
//! POP proved the placement decisions decompose — k partition LPs stitched
//! back together lose little quality. [`ShardedCoordinator`] promotes that
//! from a POP-internal trick to a first-class subsystem over *any* inner
//! scheduler:
//!
//! * **Deterministic routing** — every job is owned by exactly one shard.
//!   [`Routing::Hashed`] routes by a seeded splitmix64 over the job id;
//!   [`Routing::Locality`] keeps a job on the shard that already holds its
//!   GPUs (falling back to the hash for new arrivals). Routes are sticky:
//!   once assigned, a job stays on its shard until a rebalance round moves
//!   it, so per-shard warm state (LP bases, matching caches) survives.
//! * **Parallel per-shard rounds** — each shard runs its *full*
//!   `Estimate → Schedule → Pack → Migrate → Commit` round via the inner
//!   scheduler's own `pipeline::run_round`, all shards concurrently on the
//!   process-wide shared [`WorkerPool`] (deterministic chunked map, bit-
//!   identical to the sequential loop for any thread budget).
//! * **Cross-shard rebalancing** — every `rebalance_interval` rounds the
//!   coordinator solves a coarse max-weight matching (through the existing
//!   [`MatchingService`]) between overloaded shards' candidate jobs and
//!   underloaded shards' capacity slots, weighted by the utilization gap a
//!   move closes minus a migration penalty for jobs that already hold
//!   GPUs. Whole jobs move only at rebalance rounds, so per-shard plans
//!   stay independently valid in between.
//! * **Fault isolation** — each shard's round inherits `run_round`'s
//!   catch-unwind: a panicking shard degrades *alone* (previous sub-plan
//!   minus departed/dead jobs) while healthy shards commit fresh plans.
//!   The merged decision is flagged degraded so callers can count it.
//!   Global [`ClusterHealth`] is sliced per shard exactly like POP —
//!   fully-healthy shards see `None` and stay on the pre-fault code path.
//! * **Per-shard circuit breakers** — every shard carries its own
//!   [`CircuitBreaker`]: `trip_after` consecutive degraded rounds switch
//!   *that shard alone* to the greedy fallback placer for the cooldown,
//!   then a half-open probe hands the round back to the real inner
//!   scheduler. One flaky shard cannot thrash the whole cluster, and the
//!   healthy shards never notice. Fallback eligibility is decided on the
//!   caller thread before the parallel dispatch (the breaker mutates on
//!   `use_fallback`), keeping shard rounds bit-identical for any thread
//!   budget.
//! * **Validated merge** — per-shard plans own disjoint GPU ranges by
//!   construction; the stitch asserts no job is produced by two shards and
//!   `validate()`s the merged [`PlacementPlan`] so a double-owned GPU can
//!   never escape the coordinator.
//!
//! Telemetry: each shard publishes `shard.round_s` (all-shard histogram),
//! per-shard `shard.<id>.round_s` / `shard.<id>.jobs` / `shard.<id>.degraded`
//! series plus a `shard.<id>.degraded_streak` gauge (with a one-shot warn
//! when a shard degrades a second consecutive round), and rebalance rounds
//! publish `shard.rebalance_moves`. The per-shard names are explicit (not
//! metric scopes): worker threads don't inherit the caller's thread-local
//! scope prefix.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::estimator::ThroughputSource;
use crate::faults::ClusterHealth;
use crate::jobs::JobId;
use crate::matching::{Edge, MatchingEngine, MatchingService, ServiceConfig};
use crate::obs::metrics;
use crate::policies::JobInfo;
use crate::recovery::breaker::greedy_fallback_decision;
use crate::recovery::{BreakerConfig, CircuitBreaker};
use crate::schedulers::pipeline::{self, RoundContext, StageProvider};
use crate::schedulers::{
    DecisionTimings, RoundDecision, RoundInput, Scheduler, TesseraeScheduler,
};
use crate::util::json::Json;
use crate::util::pool::WorkerPool;

/// How jobs are assigned to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Routing {
    /// Seeded splitmix64 over the job id: uniform, stateless, stable.
    Hashed,
    /// Keep a job on the shard whose GPU range holds its previous
    /// placement; hash new arrivals. Minimizes cross-shard churn when the
    /// coordinator takes over an already-placed cluster.
    Locality,
}

/// Coordinator knobs. `ShardedConfig::new(k)` gives the defaults used by
/// the `Sharded-k` experiment arm.
#[derive(Debug, Clone, Copy)]
pub struct ShardedConfig {
    /// Requested shard count; clamped per round so every shard can host
    /// the largest active job (the POP shrink rule).
    pub shards: usize,
    pub routing: Routing,
    /// Seed for hashed routing.
    pub seed: u64,
    /// Solve the cross-shard rebalance matching every this many rounds
    /// (`0` = never). Round 0 never rebalances — there is no load yet.
    pub rebalance_interval: u64,
    /// Cap on jobs a single shard can *receive* in one rebalance round:
    /// bounds migration pressure per shard per round.
    pub max_moves_per_shard: usize,
    /// Run shard rounds on the shared worker pool (bit-identical to the
    /// sequential path; the toggle exists for parity tests).
    pub parallel: bool,
}

impl ShardedConfig {
    pub fn new(shards: usize) -> ShardedConfig {
        assert!(shards >= 1);
        ShardedConfig {
            shards,
            routing: Routing::Hashed,
            seed: 0x7e55_e4ae,
            rebalance_interval: 10,
            max_moves_per_shard: 8,
            parallel: true,
        }
    }
}

/// Builds the inner scheduler for one shard (called once per shard, again
/// after `reset_after_failure`). The index is provided so factories can
/// vary per-shard configuration deterministically.
pub type ShardFactory = Arc<dyn Fn(usize) -> Box<dyn Scheduler> + Send + Sync>;

/// Estimate-stage output carried to Schedule: the shard split of one round.
struct ShardRound {
    k: usize,
    groups: Vec<Vec<JobInfo>>,
    sub_specs: Vec<ClusterSpec>,
    sub_prev: Vec<PlacementPlan>,
    node_base: Vec<usize>,
    /// Per-shard slice of the global GPU health; `None` for shards whose
    /// slice is fully healthy (the rate-0 parity contract).
    sub_health: Vec<Option<ClusterHealth>>,
}

/// The sharded coordinator. Implements [`StageProvider`], so a coordinator
/// round is itself a staged pipeline: Estimate routes + rebalances + builds
/// the shard slices, Schedule runs the per-shard rounds and stitches,
/// Migrate counts the Definition-1 diff, Commit assembles the decision.
pub struct ShardedCoordinator {
    pub cfg: ShardedConfig,
    /// Tuning for the per-shard circuit breakers (configuration, not
    /// state — snapshots persist breaker *state* only).
    pub breaker_cfg: BreakerConfig,
    factory: ShardFactory,
    inner_label: String,
    /// Retained per-shard schedulers (index p owns shard p's warm state);
    /// rebuilt only when the effective shard count changes.
    subs: Vec<Box<dyn Scheduler>>,
    /// One breaker per shard: a shard that degrades `trip_after` rounds in
    /// a row serves the greedy fallback alone while its neighbours keep
    /// running the real inner scheduler.
    breakers: Vec<CircuitBreaker>,
    /// Consecutive degraded rounds per shard (the `shard.<p>.degraded_streak`
    /// gauge; reset on any clean round).
    degraded_streaks: Vec<u32>,
    /// Sticky job→shard routes. Pruned to the active window each round;
    /// entries ≥ the effective k are re-routed.
    assignment: BTreeMap<JobId, usize>,
    /// Solves the rebalance matching (and counts it in round stats).
    service: MatchingService,
    engine: Arc<dyn MatchingEngine>,
    round: Option<ShardRound>,
    /// Timing buckets absorbed from this round's shard decisions (max
    /// across shards — they ran concurrently).
    sub_timings: DecisionTimings,
    degraded_shards: usize,
    /// Per-shard wall clock of the most recent round, indexed by shard.
    last_shard_s: Vec<f64>,
    last_rebalance_moves: usize,
}

impl ShardedCoordinator {
    pub fn new(
        cfg: ShardedConfig,
        inner_label: &str,
        factory: ShardFactory,
        engine: Arc<dyn MatchingEngine>,
    ) -> ShardedCoordinator {
        ShardedCoordinator {
            cfg,
            breaker_cfg: BreakerConfig::default(),
            factory,
            inner_label: inner_label.to_string(),
            subs: Vec::new(),
            breakers: Vec::new(),
            degraded_streaks: Vec::new(),
            assignment: BTreeMap::new(),
            service: MatchingService::new(ServiceConfig::default()),
            engine,
            round: None,
            sub_timings: DecisionTimings::default(),
            degraded_shards: 0,
            last_shard_s: Vec::new(),
            last_rebalance_moves: 0,
        }
    }

    /// The standard arm: `k` shards each running Tesserae-T.
    pub fn tesserae_t(
        shards: usize,
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> ShardedCoordinator {
        let factory_engine = Arc::clone(&engine);
        let factory: ShardFactory = Arc::new(move |_shard| {
            Box::new(TesseraeScheduler::tesserae_t(
                Arc::clone(&source),
                Arc::clone(&factory_engine),
            ))
        });
        ShardedCoordinator::new(ShardedConfig::new(shards), "tesserae-t", factory, engine)
    }

    /// Per-shard wall clock of the most recent decided round (empty before
    /// the first round). The scale sweep reports max/mean over this.
    pub fn shard_round_times(&self) -> &[f64] {
        &self.last_shard_s
    }

    /// Jobs moved by the most recent rebalance round.
    pub fn last_rebalance_moves(&self) -> usize {
        self.last_rebalance_moves
    }

    fn ensure_subs(&mut self, k: usize) {
        if self.subs.len() != k {
            self.subs = (0..k).map(|p| (self.factory)(p)).collect();
        }
        // Sized independently of `subs` so a snapshot restore (which sets
        // breakers/streaks before the first round builds the subs) is not
        // clobbered here.
        if self.breakers.len() != k {
            self.breakers = (0..k)
                .map(|_| CircuitBreaker::new(self.breaker_cfg))
                .collect();
        }
        if self.degraded_streaks.len() != k {
            self.degraded_streaks = vec![0; k];
        }
    }

    /// The route for one job this round, before rebalancing: the sticky
    /// assignment if present, otherwise the configured routing policy.
    fn route_job(
        &self,
        job: &JobInfo,
        prev_plan: &PlacementPlan,
        spec: &ClusterSpec,
        k: usize,
        nodes_per: usize,
    ) -> usize {
        if let Some(&p) = self.assignment.get(&job.id) {
            if p < k {
                return p;
            }
        }
        if self.cfg.routing == Routing::Locality {
            if let Some(&g) = prev_plan.gpus_of(job.id).first() {
                return (spec.node_of(g) / nodes_per).min(k - 1);
            }
        }
        (splitmix64(job.id ^ self.cfg.seed) % k as u64) as usize
    }

    /// Cross-shard rebalance: a coarse max-weight matching between donor
    /// shards' candidate jobs and receiver shards' capacity slots.
    ///
    /// Per-shard load is `Σ num_gpus / capacity`. Shards above the mean
    /// utilization donate, shards below receive — each receiver exposes at
    /// most `max_moves_per_shard` single-job slots, and an edge's weight is
    /// the utilization gap it closes (scaled by the job's GPU demand)
    /// minus a penalty for moving a job that already holds GPUs (a real
    /// migration). Non-positive edges are never matched, so a balanced
    /// cluster is a no-op. Whole jobs move; plans stay per-shard valid.
    fn rebalance(
        &mut self,
        active: &[JobInfo],
        prev_plan: &PlacementPlan,
        routes: &mut [usize],
        caps: &[usize],
        k: usize,
    ) -> usize {
        let mut demand = vec![0.0f64; k];
        for (j, &p) in active.iter().zip(routes.iter()) {
            demand[p] += j.num_gpus as f64;
        }
        let total_cap: f64 = caps.iter().map(|&c| c as f64).sum();
        let total_demand: f64 = demand.iter().sum();
        if total_cap <= 0.0 || total_demand <= 0.0 {
            return 0;
        }
        let util: Vec<f64> = (0..k).map(|p| demand[p] / caps[p] as f64).collect();
        let mean = total_demand / total_cap;

        // Receiver slots: one entry per job a below-mean shard can absorb.
        let mut slots: Vec<usize> = Vec::new();
        for p in 0..k {
            let deficit = mean * caps[p] as f64 - demand[p];
            if deficit < 1.0 {
                continue;
            }
            let want = (deficit.floor() as usize).min(self.cfg.max_moves_per_shard);
            slots.extend(std::iter::repeat(p).take(want));
        }
        if slots.is_empty() {
            return 0;
        }

        // Donor candidates: jobs on above-mean shards, cheapest moves
        // first (unplaced jobs migrate for free, then larger jobs shift
        // more load per move), bounded to keep the matching coarse.
        let mut cands: Vec<usize> = (0..active.len())
            .filter(|&i| util[routes[i]] > mean + 1e-9)
            .collect();
        cands.sort_by_key(|&i| {
            let placed = !prev_plan.gpus_of(active[i].id).is_empty();
            (placed as u8, u32::MAX - active[i].num_gpus, active[i].id)
        });
        cands.truncate(2 * slots.len());
        if cands.is_empty() {
            return 0;
        }

        let mut edges: Vec<Edge> = Vec::new();
        for (ci, &i) in cands.iter().enumerate() {
            let from = routes[i];
            let gpus = active[i].num_gpus as f64;
            let placed = !prev_plan.gpus_of(active[i].id).is_empty();
            for (si, &to) in slots.iter().enumerate() {
                if to == from {
                    continue;
                }
                let gain = (util[from] - util[to]) * gpus;
                let penalty = if placed { 0.25 * gpus } else { 0.0 };
                let w = gain - penalty;
                if w > 1e-9 {
                    edges.push((ci, si, w));
                }
            }
        }
        let pairs =
            self.service
                .max_weight(self.engine.as_ref(), cands.len(), slots.len(), &edges);
        for pair in &pairs {
            let i = cands[pair.left];
            let to = slots[pair.right];
            routes[i] = to;
            self.assignment.insert(active[i].id, to);
        }
        pairs.len()
    }
}

impl StageProvider for ShardedCoordinator {
    /// Route jobs to shards (rebalancing when due) and build the per-shard
    /// slices: contiguous node ranges, previous-plan slices restricted to
    /// each shard's own jobs, and per-shard health views.
    fn estimate(&mut self, cx: &mut RoundContext) {
        let input = cx.input;
        let max_job_nodes = input
            .active
            .iter()
            .map(|j| (j.num_gpus as usize).div_ceil(input.spec.gpus_per_node))
            .max()
            .unwrap_or(1);
        let mut k = self.cfg.shards.min(input.spec.num_nodes.max(1));
        while k > 1 && input.spec.num_nodes / k < max_job_nodes {
            k -= 1;
        }
        self.ensure_subs(k);
        let nodes_per = input.spec.num_nodes / k;

        // Prune routes for departed jobs and stale shard indices.
        let active_ids: BTreeSet<JobId> = input.active.iter().map(|j| j.id).collect();
        self.assignment
            .retain(|id, p| active_ids.contains(id) && *p < k);

        let mut routes: Vec<usize> = input
            .active
            .iter()
            .map(|j| self.route_job(j, input.prev_plan, input.spec, k, nodes_per))
            .collect();
        for (j, &p) in input.active.iter().zip(routes.iter()) {
            self.assignment.insert(j.id, p);
        }

        let caps: Vec<usize> = (0..k)
            .map(|p| {
                let extra = if p == k - 1 {
                    input.spec.num_nodes - nodes_per * k
                } else {
                    0
                };
                (nodes_per + extra).max(1) * input.spec.gpus_per_node
            })
            .collect();
        let due = self.cfg.rebalance_interval > 0
            && input.round > 0
            && input.round % self.cfg.rebalance_interval == 0;
        self.last_rebalance_moves = if due && k > 1 {
            let moves =
                self.rebalance(input.active, input.prev_plan, &mut routes, &caps, k);
            metrics::counter_add("shard.rebalance_moves", moves as u64);
            moves
        } else {
            0
        };

        let mut groups: Vec<Vec<JobInfo>> = vec![Vec::new(); k];
        for (j, &p) in input.active.iter().zip(routes.iter()) {
            groups[p].push(j.clone());
        }
        let sub_specs: Vec<ClusterSpec> = (0..k)
            .map(|p| {
                let extra = if p == k - 1 {
                    input.spec.num_nodes - nodes_per * k
                } else {
                    0
                };
                ClusterSpec::new(
                    (nodes_per + extra).max(1),
                    input.spec.gpus_per_node,
                    input.spec.gpu_type,
                )
            })
            .collect();
        let node_base: Vec<usize> = (0..k).map(|p| p * nodes_per).collect();

        // k == 1 hands the inner scheduler the round verbatim — the
        // bit-parity contract with the unsharded pipeline rests on taking
        // no slicing detour at all.
        let (sub_prev, sub_health) = if k == 1 {
            (
                vec![input.prev_plan.clone()],
                vec![input.health.cloned()],
            )
        } else {
            let sub_prev: Vec<PlacementPlan> = (0..k)
                .map(|p| {
                    let spec = &sub_specs[p];
                    let members: BTreeSet<JobId> =
                        groups[p].iter().map(|j| j.id).collect();
                    let mut plan = PlacementPlan::new(spec.total_gpus());
                    let base_gpu = node_base[p] * input.spec.gpus_per_node;
                    for g in 0..spec.total_gpus() {
                        let src = base_gpu + g;
                        let src_dead = input.health.is_some_and(|h| !h.is_healthy(src));
                        if src < input.prev_plan.num_gpus() && !src_dead {
                            for &j in input.prev_plan.jobs_on(src) {
                                // A job routed (or rebalanced) elsewhere
                                // must not linger in this shard's slice —
                                // its new shard owns it now.
                                if !members.contains(&j) || plan.jobs_on(g).contains(&j)
                                {
                                    continue;
                                }
                                plan.place(j, &[g]);
                            }
                        }
                    }
                    plan
                })
                .collect();
            let sub_health: Vec<Option<ClusterHealth>> = (0..k)
                .map(|p| {
                    let h = input.health?;
                    let spec = &sub_specs[p];
                    let base_gpu = node_base[p] * input.spec.gpus_per_node;
                    let mut sub = ClusterHealth::new(spec.total_gpus());
                    for g in 0..spec.total_gpus() {
                        if !h.is_healthy(base_gpu + g) {
                            sub.fail_gpu(g);
                        }
                    }
                    (!sub.all_healthy()).then_some(sub)
                })
                .collect();
            (sub_prev, sub_health)
        };
        self.round = Some(ShardRound {
            k,
            groups,
            sub_specs,
            sub_prev,
            node_base,
            sub_health,
        });
    }

    /// Run every shard's full round (concurrently on the shared pool) and
    /// stitch the sub-plans into the global plan, asserting single
    /// ownership and validating the merge.
    fn schedule(&mut self, cx: &mut RoundContext) {
        let input = cx.input;
        let round = self.round.take().expect("estimate stage ran");
        let inputs: Vec<RoundInput> = (0..round.k)
            .map(|p| RoundInput {
                now: input.now,
                round: input.round,
                active: &round.groups[p],
                prev_plan: &round.sub_prev[p],
                spec: &round.sub_specs[p],
                health: round.sub_health[p].as_ref(),
            })
            .collect();
        // Breaker transitions mutate, so fallback eligibility is decided
        // here on the caller thread, in shard order, before the parallel
        // dispatch — deterministic for any pool thread budget.
        let fallback: Vec<bool> = (0..round.k)
            .map(|p| self.breakers[p].use_fallback(input.round))
            .collect();
        let results = decide_shards(&mut self.subs, &inputs, &fallback, self.cfg.parallel);

        let mut timings = DecisionTimings::default();
        self.degraded_shards = 0;
        self.last_shard_s = vec![0.0; round.k];
        for (p, (d, wall)) in results.into_iter().enumerate() {
            self.last_shard_s[p] = wall;
            if !fallback[p] {
                self.breakers[p].record(input.round, d.degraded);
            }
            if d.degraded {
                self.degraded_shards += 1;
                self.degraded_streaks[p] += 1;
                if self.degraded_streaks[p] == 2 {
                    crate::obs_log!(
                        warn,
                        "shard {p} degraded a second consecutive round (round {})",
                        input.round
                    );
                }
            } else {
                self.degraded_streaks[p] = 0;
            }
            if crate::obs::enabled() {
                metrics::gauge_set(
                    &format!("shard.{p}.degraded_streak"),
                    self.degraded_streaks[p] as f64,
                );
            }
            let base_gpu = round.node_base[p] * input.spec.gpus_per_node;
            for j in d.plan.jobs() {
                assert!(
                    cx.plan.gpus_of(j).is_empty(),
                    "job {j} produced by two shards"
                );
                let gpus: Vec<usize> =
                    d.plan.gpus_of(j).iter().map(|g| g + base_gpu).collect();
                cx.plan.place(j, &gpus);
            }
            cx.strategies.extend(d.strategies);
            cx.packed_pairs.extend(d.packed_pairs);
            // Shards ran concurrently: wall buckets take the max, the
            // matching-service counts add (solve wall takes the max).
            timings.scheduling_s = timings.scheduling_s.max(d.timings.scheduling_s);
            timings.packing_s = timings.packing_s.max(d.timings.packing_s);
            timings.migration_s = timings.migration_s.max(d.timings.migration_s);
            timings.matching.absorb_parallel(&d.timings.matching);
        }
        timings
            .matching
            .absorb_parallel(&self.service.take_round_stats());
        self.sub_timings = timings;
        cx.plan
            .validate()
            .expect("merged shard plans double-own a GPU");
    }

    /// Packing happened inside the shard rounds.
    fn pack(&mut self, _cx: &mut RoundContext) {}

    /// Shards realized their slices physically already; the global count
    /// is the Definition-1 diff against the previous plan.
    fn migrate(&mut self, cx: &mut RoundContext) {
        cx.migrations = cx.plan.migrations_from(cx.input.prev_plan);
    }

    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
        let timings = std::mem::take(&mut self.sub_timings);
        RoundDecision {
            plan: std::mem::replace(
                &mut cx.plan,
                PlacementPlan::new(cx.input.spec.total_gpus()),
            ),
            strategies: std::mem::take(&mut cx.strategies),
            packed_pairs: std::mem::take(&mut cx.packed_pairs),
            migrations: cx.migrations,
            // One degraded shard degrades the merged decision — callers
            // count it, but the healthy shards' fresh plans still land.
            degraded: self.degraded_shards > 0,
            timings,
        }
    }

    /// Drop the retained shard schedulers (the factory recreates them next
    /// round) and the sticky routes: a panic in the coordinator's own
    /// stages may have left the split half-applied.
    fn reset_after_failure(&mut self) {
        self.subs.clear();
        self.breakers.clear();
        self.degraded_streaks.clear();
        self.assignment.clear();
        self.round = None;
        self.sub_timings = DecisionTimings::default();
        self.degraded_shards = 0;
    }
}

impl Scheduler for ShardedCoordinator {
    fn name(&self) -> String {
        format!("sharded-{}x{}", self.cfg.shards, self.inner_label)
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        pipeline::run_round(self, input)
    }

    /// Hard coordinator state: sticky routes, per-shard breaker state and
    /// degraded streaks, plus whatever the shard schedulers persist.
    fn snapshot_state(&self) -> Option<Json> {
        Some(Json::obj(vec![
            (
                "assignment",
                Json::Obj(
                    self.assignment
                        .iter()
                        .map(|(id, p)| (id.to_string(), Json::num(*p as f64)))
                        .collect(),
                ),
            ),
            (
                "degraded_streaks",
                Json::arr(
                    self.degraded_streaks
                        .iter()
                        .map(|&s| Json::num(s as f64))
                        .collect(),
                ),
            ),
            (
                "breakers",
                Json::arr(self.breakers.iter().map(CircuitBreaker::to_json).collect()),
            ),
            (
                "subs",
                Json::arr(
                    self.subs
                        .iter()
                        .map(|s| s.snapshot_state().unwrap_or(Json::Null))
                        .collect(),
                ),
            ),
        ]))
    }

    fn restore_state(&mut self, state: &Json) {
        if let Some(map) = state.get("assignment").and_then(Json::as_obj) {
            self.assignment = map
                .iter()
                .filter_map(|(id, p)| Some((id.parse().ok()?, p.as_usize()?)))
                .collect();
        }
        if let Some(arr) = state.get("degraded_streaks").and_then(Json::as_arr) {
            self.degraded_streaks = arr
                .iter()
                .filter_map(|v| v.as_f64().map(|f| f as u32))
                .collect();
        }
        if let Some(arr) = state.get("breakers").and_then(Json::as_arr) {
            self.breakers = arr
                .iter()
                .map(|b| CircuitBreaker::from_json(self.breaker_cfg, b))
                .collect();
        }
        if let Some(arr) = state.get("subs").and_then(Json::as_arr) {
            self.ensure_subs(arr.len());
            for (sub, st) in self.subs.iter_mut().zip(arr) {
                if !matches!(st, Json::Null) {
                    sub.restore_state(st);
                }
            }
        }
    }
}

/// Run each shard's round, sequentially or across the shared worker pool.
/// Shards share no state (fallback flags were precomputed by the caller),
/// so the pooled map is bit-identical to the sequential loop (asserted by
/// `sharded_parallel_matches_sequential`).
fn decide_shards(
    subs: &mut [Box<dyn Scheduler>],
    inputs: &[RoundInput],
    fallback: &[bool],
    parallel: bool,
) -> Vec<(RoundDecision, f64)> {
    let k = inputs.len();
    assert_eq!(subs.len(), k);
    assert_eq!(fallback.len(), k);
    if !parallel || k <= 1 {
        return subs
            .iter_mut()
            .zip(inputs)
            .enumerate()
            .map(|(p, (sub, input))| decide_shard(p, sub.as_mut(), input, fallback[p]))
            .collect();
    }
    let mut slots: Vec<(usize, &mut Box<dyn Scheduler>, &RoundInput)> = subs
        .iter_mut()
        .zip(inputs)
        .enumerate()
        .map(|(p, (sub, input))| (p, sub, input))
        .collect();
    WorkerPool::global().map_mut(&mut slots, 0, 1, |_, slot| {
        decide_shard(slot.0, slot.1.as_mut(), slot.2, fallback[slot.0])
    })
}

/// One shard's round: the inner scheduler's own staged pipeline (with its
/// catch-unwind degraded fallback) — or, when this shard's breaker is
/// open, the greedy fallback placer over the shard slice — wrapped in a
/// span and the per-shard metric series.
fn decide_shard(
    p: usize,
    sub: &mut dyn Scheduler,
    input: &RoundInput,
    fallback: bool,
) -> (RoundDecision, f64) {
    let t0 = Instant::now();
    let decision = if fallback {
        metrics::counter_add("breaker.fallback_rounds", 1);
        greedy_fallback_decision(input)
    } else {
        crate::obs_span!("shard.round", { shard: p, jobs: input.active.len() });
        sub.decide(input)
    };
    let wall = t0.elapsed().as_secs_f64();
    if crate::obs::enabled() {
        metrics::observe("shard.round_s", wall);
        metrics::observe(&format!("shard.{p}.round_s"), wall);
        metrics::gauge_set(&format!("shard.{p}.jobs"), input.active.len() as f64);
        if decision.degraded {
            metrics::counter_add(&format!("shard.{p}.degraded"), 1);
        }
    }
    (decision, wall)
}

/// SplitMix64: the routing hash. Pure and seed-stable, so routes are
/// reproducible across processes and thread budgets.
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind;
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, gpus: u32) -> JobInfo {
        JobInfo {
            id,
            model: ModelKind::ResNet50,
            num_gpus: gpus,
            arrival_time: id as f64,
            attained_service: id as f64 * 10.0,
            total_iters: 10_000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 100.0,
            iso_tput: 10.0,
        }
    }

    fn sharded(k: usize) -> ShardedCoordinator {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        ShardedCoordinator::tesserae_t(k, source, Arc::new(HungarianEngine))
    }

    fn input<'a>(
        round: u64,
        active: &'a [JobInfo],
        prev: &'a PlacementPlan,
        spec: &'a ClusterSpec,
        health: Option<&'a ClusterHealth>,
    ) -> RoundInput<'a> {
        RoundInput {
            now: round as f64 * 360.0,
            round,
            active,
            prev_plan: prev,
            spec,
            health,
        }
    }

    #[test]
    fn stitched_plan_is_valid_and_places_jobs() {
        let spec = ClusterSpec::new(8, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..20).map(|i| info(i, 1 + (i % 2) as u32)).collect();
        let prev = PlacementPlan::new(16);
        let mut s = sharded(4);
        let d = s.decide(&input(0, &active, &prev, &spec, None));
        assert!(!d.degraded);
        d.plan.validate().unwrap();
        assert!(!d.plan.jobs().is_empty());
        assert_eq!(s.shard_round_times().len(), 4);
    }

    #[test]
    fn shard_count_clamps_to_nodes_and_job_size() {
        // 64 requested shards on 4 nodes clamp to 4; an 8-GPU job on
        // 2-GPU nodes needs 4 nodes, collapsing the split to one shard.
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let small: Vec<JobInfo> = (0..8).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(8);
        let mut s = sharded(64);
        let d = s.decide(&input(0, &small, &prev, &spec, None));
        d.plan.validate().unwrap();
        assert_eq!(s.shard_round_times().len(), 4);

        let big = vec![info(0, 8)];
        let d = s.decide(&input(1, &big, &prev, &spec, None));
        d.plan.validate().unwrap();
        assert_eq!(s.shard_round_times().len(), 1);
    }

    #[test]
    fn sharded_parallel_matches_sequential() {
        let spec = ClusterSpec::new(8, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..32).map(|i| info(i, 1 + (i % 2) as u32)).collect();
        let mut par = sharded(4);
        let mut seq = sharded(4);
        seq.cfg.parallel = false;
        let mut prev_par = PlacementPlan::new(16);
        let mut prev_seq = PlacementPlan::new(16);
        for round in 0..4 {
            let drifted: Vec<JobInfo> = active
                .iter()
                .map(|j| {
                    let mut j = j.clone();
                    j.attained_service += round as f64 * 360.0;
                    if round >= 2 && j.id == 5 {
                        j.id = 500 + round;
                    }
                    j
                })
                .collect();
            let dp = par.decide(&input(round, &drifted, &prev_par, &spec, None));
            let ds = seq.decide(&input(round, &drifted, &prev_seq, &spec, None));
            assert_eq!(dp.plan, ds.plan, "round {round} plans diverge");
            assert_eq!(dp.migrations, ds.migrations, "round {round} migrations");
            assert_eq!(dp.strategies, ds.strategies, "round {round} strategies");
            prev_par = dp.plan;
            prev_seq = ds.plan;
        }
    }

    #[test]
    fn faulted_shards_keep_jobs_off_dead_gpus() {
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..8).map(|i| info(i, 1)).collect();
        // Dead GPUs land in two different shards; the others stay fully
        // healthy and must take the unmasked path.
        let mut health = ClusterHealth::new(8);
        health.fail_gpu(1);
        health.fail_gpu(6);
        let mut s = sharded(4);
        let mut prev = PlacementPlan::new(8);
        for round in 0..3u64 {
            let d = s.decide(&input(round, &active, &prev, &spec, Some(&health)));
            assert!(!d.degraded);
            d.plan.validate().unwrap();
            health.validate_plan(&d.plan).unwrap();
            assert!(d.plan.jobs_on(1).is_empty(), "round {round} used dead GPU 1");
            assert!(d.plan.jobs_on(6).is_empty(), "round {round} used dead GPU 6");
            prev = d.plan;
        }
    }

    #[test]
    fn rebalance_moves_jobs_off_an_overloaded_shard() {
        // Locality routing + a previous plan that crams every job into
        // shard 0's GPU range: the first rebalance round must move load
        // toward the idle shard.
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..4).map(|i| info(i, 1)).collect();
        let mut prev = PlacementPlan::new(8);
        for i in 0..4u64 {
            prev.place(i, &[i as usize]); // GPUs 0..4 = shard 0 of 2
        }
        let mut s = sharded(2);
        s.cfg.routing = Routing::Locality;
        s.cfg.rebalance_interval = 1;
        let d0 = s.decide(&input(0, &active, &prev, &spec, None));
        assert_eq!(s.last_rebalance_moves(), 0, "round 0 never rebalances");
        let d1 = s.decide(&input(1, &active, &d0.plan, &spec, None));
        assert!(
            s.last_rebalance_moves() > 0,
            "overloaded shard 0 donated nothing"
        );
        d1.plan.validate().unwrap();
        // At least one job now lives in shard 1's GPU range (4..8).
        let moved = d1
            .plan
            .jobs()
            .iter()
            .any(|&j| d1.plan.gpus_of(j).iter().any(|&g| g >= 4));
        assert!(moved, "no job landed on shard 1's GPUs: {:?}", d1.plan.job_gpu_map());
    }

    #[test]
    fn balanced_shards_rebalance_to_a_noop() {
        // Hashed routing spreads these jobs evenly; the rebalance matching
        // must find no positive-weight move.
        let spec = ClusterSpec::new(8, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..32).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(16);
        let mut s = sharded(4);
        s.cfg.rebalance_interval = 1;
        let d0 = s.decide(&input(0, &active, &prev, &spec, None));
        let _d1 = s.decide(&input(1, &active, &d0.plan, &spec, None));
        // Not asserting exactly zero (hash spread is only approximately
        // even) — but a near-balanced cluster must not churn wholesale.
        assert!(
            s.last_rebalance_moves() <= 4,
            "balanced cluster moved {} jobs",
            s.last_rebalance_moves()
        );
    }

    /// Inner scheduler for the isolation tests: a trivial greedy placer
    /// that panics in its Schedule stage for rounds in
    /// `explode_after..explode_until`.
    struct Bomb {
        explode_after: u64,
        explode_until: u64,
    }

    impl StageProvider for Bomb {
        fn estimate(&mut self, _cx: &mut RoundContext) {}
        fn schedule(&mut self, cx: &mut RoundContext) {
            if cx.input.round >= self.explode_after && cx.input.round < self.explode_until {
                panic!("bomb shard exploded");
            }
            let mut next = 0usize;
            for j in cx.input.active {
                let need = j.num_gpus as usize;
                if next + need <= cx.input.spec.total_gpus() {
                    let gpus: Vec<usize> = (next..next + need).collect();
                    cx.plan.place(j.id, &gpus);
                    next += need;
                }
            }
        }
        fn pack(&mut self, _cx: &mut RoundContext) {}
        fn migrate(&mut self, cx: &mut RoundContext) {
            cx.migrations = cx.plan.migrations_from(cx.input.prev_plan);
        }
        fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
            RoundDecision {
                plan: std::mem::replace(
                    &mut cx.plan,
                    PlacementPlan::new(cx.input.spec.total_gpus()),
                ),
                strategies: std::mem::take(&mut cx.strategies),
                packed_pairs: std::mem::take(&mut cx.packed_pairs),
                migrations: cx.migrations,
                degraded: false,
                timings: DecisionTimings::default(),
            }
        }
    }

    struct BombScheduler {
        inner: Bomb,
    }

    impl Scheduler for BombScheduler {
        fn name(&self) -> String {
            "bomb".into()
        }
        fn decide(&mut self, input: &RoundInput) -> RoundDecision {
            pipeline::run_round(&mut self.inner, input)
        }
    }

    #[test]
    fn panicking_shard_degrades_alone() {
        // Shard 1 explodes from round 1 on; shard 0 stays healthy. The
        // merged decision is flagged degraded, shard 1's jobs keep their
        // round-0 placements, and shard 0's jobs are still freshly placed.
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let factory: ShardFactory = Arc::new(|shard| {
            Box::new(BombScheduler {
                inner: Bomb {
                    explode_after: if shard == 1 { 1 } else { u64::MAX },
                    explode_until: u64::MAX,
                },
            })
        });
        let mut cfg = ShardedConfig::new(2);
        cfg.rebalance_interval = 0;
        let mut s =
            ShardedCoordinator::new(cfg, "bomb", factory, Arc::new(HungarianEngine));
        let active: Vec<JobInfo> = (0..6).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(8);
        let d0 = s.decide(&input(0, &active, &prev, &spec, None));
        assert!(!d0.degraded);
        let shard1_jobs: Vec<JobId> = d0
            .plan
            .jobs()
            .into_iter()
            .filter(|&j| d0.plan.gpus_of(j).iter().all(|&g| g >= 4))
            .collect();
        assert!(!shard1_jobs.is_empty(), "hash routed nothing to shard 1");

        let d1 = s.decide(&input(1, &active, &d0.plan, &spec, None));
        assert!(d1.degraded, "a degraded shard must flag the merged decision");
        d1.plan.validate().unwrap();
        // Shard 1's jobs survived at their previous placements.
        for &j in &shard1_jobs {
            assert_eq!(
                d1.plan.gpus_of(j),
                d0.plan.gpus_of(j),
                "degraded shard moved job {j}"
            );
        }
        // Shard 0 committed a fresh plan: its jobs are still placed.
        let shard0_placed = d1
            .plan
            .jobs()
            .iter()
            .any(|&j| d1.plan.gpus_of(j).iter().all(|&g| g < 4));
        assert!(shard0_placed, "healthy shard lost its placements");
    }

    #[test]
    fn per_shard_metric_series_are_published() {
        let _guard = crate::obs::enabled_guard(true);
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..8).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(8);
        let mut s = sharded(2);
        let _ = s.decide(&input(0, &active, &prev, &spec, None));
        let snap = metrics::snapshot();
        for p in 0..2 {
            assert!(
                snap.histograms.contains_key(&format!("shard.{p}.round_s")),
                "missing shard.{p}.round_s"
            );
            assert!(
                snap.gauges.contains_key(&format!("shard.{p}.jobs")),
                "missing shard.{p}.jobs"
            );
            assert!(
                snap.gauges.contains_key(&format!("shard.{p}.degraded_streak")),
                "missing shard.{p}.degraded_streak"
            );
        }
        assert!(snap.histograms.contains_key("shard.round_s"));
    }

    #[test]
    fn routes_are_sticky_across_rounds() {
        let spec = ClusterSpec::new(8, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..16).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(16);
        let mut s = sharded(4);
        s.cfg.rebalance_interval = 0;
        let d0 = s.decide(&input(0, &active, &prev, &spec, None));
        let before = s.assignment.clone();
        let _d1 = s.decide(&input(1, &active, &d0.plan, &spec, None));
        assert_eq!(before, s.assignment, "routes churned without a rebalance");
    }

    #[test]
    fn tripped_shard_serves_fallback_then_recovers() {
        // Shard 1's bomb explodes rounds 1..4: three consecutive degraded
        // rounds trip its breaker at round 3 (Open until round 9). Rounds
        // 4..9 are served by the greedy fallback — *not* degraded — and
        // the round-9 half-open probe finds the bomb defused and closes.
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let factory: ShardFactory = Arc::new(|shard| {
            Box::new(BombScheduler {
                inner: Bomb {
                    explode_after: if shard == 1 { 1 } else { u64::MAX },
                    explode_until: 4,
                },
            })
        });
        let mut cfg = ShardedConfig::new(2);
        cfg.rebalance_interval = 0;
        let mut s =
            ShardedCoordinator::new(cfg, "bomb", factory, Arc::new(HungarianEngine));
        let active: Vec<JobInfo> = (0..6).map(|i| info(i, 1)).collect();
        let mut prev = PlacementPlan::new(8);
        let mut degraded_rounds = Vec::new();
        for round in 0..10u64 {
            let d = s.decide(&input(round, &active, &prev, &spec, None));
            if d.degraded {
                degraded_rounds.push(round);
            }
            d.plan.validate().unwrap();
            prev = d.plan;
        }
        assert_eq!(degraded_rounds, vec![1, 2, 3], "fallback rounds must not degrade");
        assert_eq!(s.breakers[1].trips(), 1);
        assert_eq!(
            s.breakers[1].state(),
            crate::recovery::BreakerState::Closed,
            "clean probe closes the breaker"
        );
        assert_eq!(s.breakers[0].trips(), 0, "healthy shard's breaker untouched");
    }

    #[test]
    fn coordinator_snapshot_state_round_trips_routes_and_breakers() {
        let spec = ClusterSpec::new(8, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..16).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(16);
        let mut s = sharded(4);
        s.cfg.rebalance_interval = 0;
        let d0 = s.decide(&input(0, &active, &prev, &spec, None));
        let state = s.snapshot_state().expect("coordinator persists state");

        let mut fresh = sharded(4);
        fresh.cfg.rebalance_interval = 0;
        fresh.restore_state(&state);
        assert_eq!(s.assignment, fresh.assignment, "routes round-trip");
        assert_eq!(fresh.breakers.len(), 4);
        assert_eq!(fresh.degraded_streaks, vec![0; 4]);

        // Restored routes + cold inner caches are decision-equivalent to
        // the warm original (the warm-vs-cold parity contract).
        let d1a = s.decide(&input(1, &active, &d0.plan, &spec, None));
        let d1b = fresh.decide(&input(1, &active, &d0.plan, &spec, None));
        assert_eq!(d1a.plan, d1b.plan);
        assert_eq!(d1a.strategies, d1b.strategies);
        assert_eq!(d1a.migrations, d1b.migrations);
    }
}

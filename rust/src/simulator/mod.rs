//! Round-based discrete-event cluster simulator (§6's simulation mode).
//!
//! The simulator advances in fixed rounds (6 minutes in the paper): each
//! round it snapshots active jobs, invokes the scheduler under test, then
//! advances every placed job by its *true* throughput (from the ground
//! truth [`Profiler`]) for the round's effective duration. Migration and
//! job-start overheads (Fig. 3) are charged against the effective duration.
//!
//! Jobs keep their GPUs until the end of the round in which they finish
//! (preemption only happens at round boundaries, §5), but their JCT is the
//! instant their final iteration completes.
//!
//! Idle gaps — stretches with no active jobs — are skipped directly to the
//! round admitting the next arrival instead of spinning one empty round per
//! iteration; on sparse traces at large cluster scale this removes
//! thousands of no-op rounds per run. `SimConfig::skip_idle_gaps` can
//! disable the skip to reproduce the spin behaviour; metrics are identical
//! either way (asserted by `gap_skipping_preserves_metrics`).
//!
//! `total_migrations` is derived from plan diffs (Definition 1) as the
//! single source of truth; the scheduler's self-reported count is
//! cross-checked against it in debug builds.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::faults::{ClusterHealth, FaultKind, FaultPlan};
use crate::jobs::{Job, JobId, ParallelismStrategy};
use crate::obs::{metrics, recorder, MetricsSnapshot};
use crate::policies::JobInfo;
use crate::profiler::Profiler;
use crate::recovery::{SnapshotStore, SNAPSHOT_VERSION};
use crate::schedulers::{DecisionTimings, RoundInput, Scheduler};
use crate::trace::Trace;
use crate::util::json::Json;
use crate::util::pool::WorkerPool;
use crate::util::stats;

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub spec: ClusterSpec,
    /// Round length in seconds (paper: 360).
    pub round_duration: f64,
    /// Seconds charged to a migrated job: checkpoint save + load + warmup
    /// (Fig. 3 measures these at tens of seconds).
    pub migration_overhead_s: f64,
    /// Seconds charged to a job the first time it starts on new GPUs.
    pub startup_overhead_s: f64,
    /// Hard stop (rounds) as a runaway guard.
    pub max_rounds: u64,
    /// Jump idle gaps straight to the next arrival's round instead of
    /// spinning one empty round per loop iteration. Metrics are identical
    /// with the flag on or off; `false` exists so tests can prove that.
    pub skip_idle_gaps: bool,
    /// Deterministic fault script applied between rounds: GPU/node
    /// failures evict the affected jobs back into the window, preemptions
    /// kick one placed job, stragglers slow one job's progress rate. The
    /// empty plan is bit-identical to pre-fault behaviour.
    pub faults: FaultPlan,
}

impl SimConfig {
    pub fn new(spec: ClusterSpec) -> SimConfig {
        SimConfig {
            spec,
            round_duration: 360.0,
            migration_overhead_s: 40.0,
            startup_overhead_s: 10.0,
            max_rounds: 200_000,
            skip_idle_gaps: true,
            faults: FaultPlan::none(),
        }
    }
}

/// Per-job outcome.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub jct: f64,
    /// Finish-time-fairness ratio: JCT / isolated exclusive duration.
    pub ftf: f64,
    pub migrations: u64,
    pub rounds_run: u64,
}

/// Aggregate simulation result.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub scheduler: String,
    pub outcomes: BTreeMap<JobId, JobOutcome>,
    pub avg_jct: f64,
    pub makespan: f64,
    pub total_migrations: usize,
    pub rounds: u64,
    /// Per-round decision-time breakdown (busy rounds only).
    pub timings: Vec<DecisionTimings>,
    /// Jobs that never completed within `max_rounds` (should be 0).
    pub unfinished: usize,
    /// Jobs evicted by GPU/node failures (a job hit twice counts twice).
    pub evictions: u64,
    /// Jobs kicked off the cluster by injected preemption events.
    pub preemptions: u64,
    /// Evicted/preempted jobs the scheduler placed again afterwards.
    pub replacements: u64,
    /// Straggler events that latched onto a running job.
    pub stragglers: u64,
    /// Rounds answered by the pipeline's degraded-mode fallback.
    pub degraded_rounds: u64,
    /// Rounds×jobs where a realized packed pair was infeasible on true
    /// throughputs (the job thrashes instead of crashing the run).
    pub infeasible_pairs: u64,
    /// What the telemetry registry accumulated over this run; `None`
    /// unless telemetry was enabled for the whole simulation.
    pub metrics: Option<MetricsSnapshot>,
}

impl SimResult {
    pub fn jcts(&self) -> Vec<f64> {
        self.outcomes.values().map(|o| o.jct).collect()
    }

    pub fn ftfs(&self) -> Vec<f64> {
        self.outcomes.values().map(|o| o.ftf).collect()
    }

    pub fn worst_ftf(&self) -> f64 {
        stats::max(&self.ftfs())
    }

    pub fn avg_decision_time(&self) -> f64 {
        stats::mean(&self.timings.iter().map(|t| t.total_s).collect::<Vec<_>>())
    }
}

struct JobState {
    job: Job,
    completed_iters: f64,
    attained_service: f64,
    rounds_received: u64,
    migrations: u64,
    finish_time: Option<f64>,
    /// Best achievable isolated throughput (FTF denominator).
    best_iso: f64,
}

/// Crash-recovery knobs threaded through [`simulate_recoverable`]. The
/// `Default` (no state dir) is exactly the plain [`simulate`] loop.
#[derive(Debug, Clone, Default)]
pub struct RecoveryOptions {
    /// Directory for generation-numbered snapshots; `None` disables them.
    pub state_dir: Option<PathBuf>,
    /// Snapshot cadence in rounds (0 is treated as 1).
    pub snapshot_every: u64,
    /// Resume from the newest parseable snapshot in `state_dir` instead
    /// of starting cold.
    pub restore: bool,
    /// Stop right after executing this round — the in-process crash
    /// emulation restore-parity tests kill with (CI uses a real SIGKILL).
    pub stop_after_round: Option<u64>,
}

// ---- snapshot codec -----------------------------------------------------
//
// The snapshot holds the simulator's *hard* state — everything the loop
// carries across rounds that is not a pure function of (trace, truth,
// cfg): the committed plan, cursors into the trace and fault script,
// per-job dynamic progress, straggler windows, counters, and the
// scheduler's own sticky state. Deliberately *not* stored: cluster health
// (replayed from the fault-event prefix), per-job specs and `best_iso`
// (re-derived from the trace and ground truth), decision timings and
// telemetry (wall-clock, excluded from the bit-parity contract), and
// every scheduler soft cache (`LpCache`, matching caches) — those rebuild
// cold, which the warm-vs-cold parity property tests keep bit-identical.

fn strategy_to_json(s: &ParallelismStrategy) -> Json {
    match s {
        ParallelismStrategy::DataParallel => Json::obj(vec![("kind", Json::str("dp"))]),
        ParallelismStrategy::TensorParallel => Json::obj(vec![("kind", Json::str("tp"))]),
        ParallelismStrategy::Pipeline(split) => Json::obj(vec![
            ("kind", Json::str("pp")),
            (
                "split",
                Json::arr(split.iter().map(|&x| Json::num(x as f64)).collect()),
            ),
        ]),
    }
}

fn strategy_from_json(doc: &Json) -> Option<ParallelismStrategy> {
    match doc.get("kind")?.as_str()? {
        "dp" => Some(ParallelismStrategy::DataParallel),
        "tp" => Some(ParallelismStrategy::TensorParallel),
        "pp" => {
            let split: Option<Vec<u32>> = doc
                .get("split")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64().map(|v| v as u32))
                .collect();
            Some(ParallelismStrategy::Pipeline(split?))
        }
        _ => None,
    }
}

/// Plans are serialized slot-first (GPU -> ordered tenant list), *not* as
/// the job -> GPU index: several consumers (`jobs_on` walks in packing,
/// POP's locality pass, the sharded rebalancer) iterate tenants in slot
/// order, so a restored plan must reproduce the exact within-slot order to
/// keep post-restore decisions bit-identical to the uninterrupted run.
fn plan_to_json(plan: &PlacementPlan) -> Json {
    Json::obj(vec![(
        "slots",
        Json::arr(
            (0..plan.num_gpus())
                .map(|g| {
                    Json::arr(
                        plan.jobs_on(g)
                            .iter()
                            .map(|&j| Json::num(j as f64))
                            .collect(),
                    )
                })
                .collect(),
        ),
    )])
}

fn plan_from_json(doc: &Json) -> Option<PlacementPlan> {
    let slots = doc.get("slots")?.as_arr()?;
    let mut plan = PlacementPlan::new(slots.len());
    // Replaying `place` per (gpu, tenant) in slot order rebuilds both the
    // slot view verbatim and the (sorted) job->GPU index.
    for (g, slot) in slots.iter().enumerate() {
        for job in slot.as_arr()? {
            plan.place(job.as_usize()? as JobId, &[g]);
        }
    }
    Some(plan)
}

/// Borrowing view of the loop state, encoded after a round commits (so
/// `round` is always "the next round to execute").
struct SnapshotView<'a> {
    round: u64,
    arrived: usize,
    next_fault: usize,
    total_migrations: usize,
    makespan: f64,
    evictions: u64,
    preemptions: u64,
    replacements: u64,
    straggle_events: u64,
    degraded_rounds: u64,
    infeasible_pairs: u64,
    prev_plan: &'a PlacementPlan,
    states: &'a BTreeMap<JobId, JobState>,
    stragglers: &'a BTreeMap<JobId, (f64, u64)>,
    pending_replacement: &'a BTreeSet<JobId>,
    last_strategies: &'a BTreeMap<JobId, ParallelismStrategy>,
}

fn snapshot_to_json(v: &SnapshotView, scheduler: &dyn Scheduler) -> Json {
    let states = Json::Obj(
        v.states
            .iter()
            .map(|(id, s)| {
                (
                    id.to_string(),
                    Json::obj(vec![
                        ("completed_iters", Json::num(s.completed_iters)),
                        ("attained_service", Json::num(s.attained_service)),
                        ("rounds_received", Json::num(s.rounds_received as f64)),
                        ("migrations", Json::num(s.migrations as f64)),
                        (
                            "finish_time",
                            s.finish_time.map(Json::num).unwrap_or(Json::Null),
                        ),
                    ]),
                )
            })
            .collect(),
    );
    let stragglers = Json::Obj(
        v.stragglers
            .iter()
            .map(|(id, &(factor, until))| {
                (
                    id.to_string(),
                    Json::arr(vec![Json::num(factor), Json::num(until as f64)]),
                )
            })
            .collect(),
    );
    let strategies = Json::Obj(
        v.last_strategies
            .iter()
            .map(|(id, s)| (id.to_string(), strategy_to_json(s)))
            .collect(),
    );
    let mut pairs = vec![
        ("version", Json::num(SNAPSHOT_VERSION as f64)),
        ("scheduler", Json::str(&scheduler.name())),
        ("round", Json::num(v.round as f64)),
        ("arrived", Json::num(v.arrived as f64)),
        ("fault_cursor", Json::num(v.next_fault as f64)),
        ("total_migrations", Json::num(v.total_migrations as f64)),
        ("makespan", Json::num(v.makespan)),
        ("evictions", Json::num(v.evictions as f64)),
        ("preemptions", Json::num(v.preemptions as f64)),
        ("replacements", Json::num(v.replacements as f64)),
        ("stragglers_seen", Json::num(v.straggle_events as f64)),
        ("degraded_rounds", Json::num(v.degraded_rounds as f64)),
        ("infeasible_pairs", Json::num(v.infeasible_pairs as f64)),
        ("plan", plan_to_json(v.prev_plan)),
        ("states", states),
        ("straggler_windows", stragglers),
        (
            "pending_replacement",
            Json::arr(
                v.pending_replacement
                    .iter()
                    .map(|&id| Json::num(id as f64))
                    .collect(),
            ),
        ),
        ("last_strategies", strategies),
    ];
    if let Some(state) = scheduler.snapshot_state() {
        pairs.push(("scheduler_state", state));
    }
    Json::obj(pairs)
}

/// Owned decode of a snapshot document; `None` on any shape mismatch
/// (the caller falls back to a cold start with a warning).
struct RestoredSim {
    scheduler: String,
    round: u64,
    arrived: usize,
    next_fault: usize,
    total_migrations: usize,
    makespan: f64,
    evictions: u64,
    preemptions: u64,
    replacements: u64,
    straggle_events: u64,
    degraded_rounds: u64,
    infeasible_pairs: u64,
    prev_plan: PlacementPlan,
    /// id → (completed_iters, attained_service, rounds_received,
    /// migrations, finish_time).
    states: BTreeMap<JobId, (f64, f64, u64, u64, Option<f64>)>,
    stragglers: BTreeMap<JobId, (f64, u64)>,
    pending_replacement: BTreeSet<JobId>,
    last_strategies: BTreeMap<JobId, ParallelismStrategy>,
    scheduler_state: Option<Json>,
}

fn snapshot_from_json(doc: &Json) -> Option<RestoredSim> {
    let num = |k: &str| doc.get(k).and_then(Json::as_f64);
    if num("version")? as u64 != SNAPSHOT_VERSION {
        return None;
    }
    let mut states = BTreeMap::new();
    for (id, s) in doc.get("states")?.as_obj()? {
        let id: JobId = id.parse().ok()?;
        let field = |k: &str| s.get(k).and_then(Json::as_f64);
        let finish = match s.get("finish_time")? {
            Json::Null => None,
            t => Some(t.as_f64()?),
        };
        states.insert(
            id,
            (
                field("completed_iters")?,
                field("attained_service")?,
                field("rounds_received")? as u64,
                field("migrations")? as u64,
                finish,
            ),
        );
    }
    let mut stragglers = BTreeMap::new();
    for (id, w) in doc.get("straggler_windows")?.as_obj()? {
        let id: JobId = id.parse().ok()?;
        let w = w.as_arr()?;
        stragglers.insert(id, (w.first()?.as_f64()?, w.get(1)?.as_f64()? as u64));
    }
    let pending_replacement: BTreeSet<JobId> = doc
        .get("pending_replacement")?
        .as_arr()?
        .iter()
        .map(|x| x.as_f64().map(|v| v as JobId))
        .collect::<Option<_>>()?;
    let mut last_strategies = BTreeMap::new();
    for (id, s) in doc.get("last_strategies")?.as_obj()? {
        last_strategies.insert(id.parse::<JobId>().ok()?, strategy_from_json(s)?);
    }
    Some(RestoredSim {
        scheduler: doc.get("scheduler")?.as_str()?.to_string(),
        round: num("round")? as u64,
        arrived: num("arrived")? as usize,
        next_fault: num("fault_cursor")? as usize,
        total_migrations: num("total_migrations")? as usize,
        makespan: num("makespan")?,
        evictions: num("evictions")? as u64,
        preemptions: num("preemptions")? as u64,
        replacements: num("replacements")? as u64,
        straggle_events: num("stragglers_seen")? as u64,
        degraded_rounds: num("degraded_rounds")? as u64,
        infeasible_pairs: num("infeasible_pairs")? as u64,
        prev_plan: plan_from_json(doc.get("plan")?)?,
        states,
        stragglers,
        pending_replacement,
        last_strategies,
        scheduler_state: doc.get("scheduler_state").cloned(),
    })
}

/// Smallest round index `k > round` whose start time admits an arrival at
/// `next_arrival` (i.e. `k * round_duration >= next_arrival`). Computed by
/// division, then corrected so the result is bit-identical to spinning one
/// round at a time regardless of floating-point rounding.
fn next_admitting_round(round: u64, next_arrival: f64, round_duration: f64) -> u64 {
    let mut target = ((next_arrival / round_duration).ceil() as u64).max(round + 1);
    while target > round + 1 && (target - 1) as f64 * round_duration >= next_arrival {
        target -= 1;
    }
    while (target as f64) * round_duration < next_arrival {
        target += 1;
    }
    target
}

/// Run a trace under a scheduler. `truth` is the ground-truth profiler used
/// to advance jobs; the scheduler sees whatever `ThroughputSource` it was
/// built with (possibly noisy or estimated).
pub fn simulate(
    trace: &Trace,
    scheduler: &mut dyn Scheduler,
    truth: &Profiler,
    cfg: &SimConfig,
) -> SimResult {
    simulate_recoverable(trace, scheduler, truth, cfg, &RecoveryOptions::default())
}

/// [`simulate`] with crash recovery: optional generation-numbered state
/// snapshots every N rounds, restore-from-snapshot, and an in-process
/// kill point for restore-parity tests. A restored run finishes
/// bit-identical (per-job JCTs, migration counts, fault counters) to the
/// uninterrupted run — snapshots capture the loop's hard state and
/// everything else is a deterministic function of (trace, truth, cfg).
pub fn simulate_recoverable(
    trace: &Trace,
    scheduler: &mut dyn Scheduler,
    truth: &Profiler,
    cfg: &SimConfig,
    recovery: &RecoveryOptions,
) -> SimResult {
    let total_gpus = cfg.spec.total_gpus();
    let mut states: BTreeMap<JobId, JobState> = BTreeMap::new();
    let mut arrived = 0usize;
    let mut prev_plan = PlacementPlan::new(total_gpus);
    let mut timings = Vec::new();
    let mut total_migrations = 0usize;
    let mut makespan: f64 = 0.0;
    let mut round: u64 = 0;
    // Per-round scratch buffer, reused across rounds.
    let mut active: Vec<JobInfo> = Vec::new();
    // Registry baseline so the result reports only this run's telemetry.
    let metrics_base = crate::obs::enabled().then(metrics::snapshot);

    // Fault state. With an empty plan none of this is ever touched and
    // `health` stays all-healthy, so `RoundInput.health` is `None` every
    // round — the rate-0 bit-parity contract.
    let mut health = ClusterHealth::new(total_gpus);
    let fault_events = cfg.faults.events();
    let mut next_fault = 0usize;
    // job → (progress factor, first round no longer affected).
    let mut stragglers: BTreeMap<JobId, (f64, u64)> = BTreeMap::new();
    let mut last_strategies: BTreeMap<JobId, ParallelismStrategy> = BTreeMap::new();
    let mut pending_replacement: BTreeSet<JobId> = BTreeSet::new();
    let mut evictions = 0u64;
    let mut preemptions = 0u64;
    let mut replacements = 0u64;
    let mut straggle_events = 0u64;
    let mut degraded_rounds = 0u64;
    let mut infeasible_pairs = 0u64;

    let store = recovery
        .state_dir
        .as_ref()
        .map(|dir| SnapshotStore::new(dir).expect("snapshot state dir must be creatable"));

    if recovery.restore {
        let latest = store.as_ref().and_then(SnapshotStore::latest);
        match latest.as_ref().and_then(|(_, doc)| snapshot_from_json(doc)) {
            Some(rs) if rs.scheduler != scheduler.name() => {
                crate::obs_log!(
                    warn,
                    "snapshot was taken under scheduler '{}', this run uses '{}'; starting cold",
                    rs.scheduler,
                    scheduler.name()
                );
            }
            Some(rs) if rs.arrived <= trace.jobs.len() => {
                // Rebuild per-job state: the static spec and `best_iso`
                // come from the trace prefix and ground truth, the
                // dynamic progress from the snapshot.
                let mut restored_states = BTreeMap::new();
                let mut complete = true;
                for job in &trace.jobs[..rs.arrived] {
                    let Some(&(completed, attained, rounds_received, migrations, finish)) =
                        rs.states.get(&job.id)
                    else {
                        complete = false;
                        break;
                    };
                    let (_, best_iso) = truth.best_isolated(job.model, job.num_gpus);
                    restored_states.insert(
                        job.id,
                        JobState {
                            job: job.clone(),
                            completed_iters: completed,
                            attained_service: attained,
                            rounds_received,
                            migrations,
                            finish_time: finish,
                            best_iso,
                        },
                    );
                }
                if complete {
                    states = restored_states;
                    arrived = rs.arrived;
                    round = rs.round;
                    next_fault = rs.next_fault.min(fault_events.len());
                    total_migrations = rs.total_migrations;
                    makespan = rs.makespan;
                    evictions = rs.evictions;
                    preemptions = rs.preemptions;
                    replacements = rs.replacements;
                    straggle_events = rs.straggle_events;
                    degraded_rounds = rs.degraded_rounds;
                    infeasible_pairs = rs.infeasible_pairs;
                    prev_plan = rs.prev_plan;
                    stragglers = rs.stragglers;
                    pending_replacement = rs.pending_replacement;
                    last_strategies = rs.last_strategies;
                    // Health is replayed, not stored: re-apply the
                    // health-affecting prefix of the fault script in
                    // order (preempt/straggle events never touch it).
                    for ev in &fault_events[..next_fault] {
                        match &ev.kind {
                            FaultKind::Preempt { .. } | FaultKind::Straggle { .. } => {}
                            kind => {
                                let _ = health.apply(&cfg.spec, kind);
                            }
                        }
                    }
                    if let Some(state) = &rs.scheduler_state {
                        scheduler.restore_state(state);
                    }
                    metrics::counter_add("snapshot.restores", 1);
                    crate::obs_log!(
                        info,
                        "restored scheduler state at round {round} from {}",
                        store.as_ref().unwrap().dir().display()
                    );
                } else {
                    crate::obs_log!(
                        warn,
                        "snapshot job states incomplete for this trace; starting cold"
                    );
                }
            }
            Some(_) => {
                crate::obs_log!(
                    warn,
                    "snapshot admits more jobs than this trace holds; starting cold"
                );
            }
            None => {
                if store.is_some() {
                    crate::obs_log!(info, "no usable snapshot found; starting cold");
                }
            }
        }
    }

    loop {
        let now = round as f64 * cfg.round_duration;

        // Apply every fault event scheduled up to this round. Events that
        // fell inside a skipped idle gap land here in order; the gap held
        // no placed jobs (the plan resets at gap entry), so preemption and
        // straggler draws resolve identically to the spin path.
        while next_fault < fault_events.len() && fault_events[next_fault].round <= round {
            let ev = &fault_events[next_fault];
            next_fault += 1;
            match &ev.kind {
                FaultKind::Preempt { pick } => {
                    let candidates: Vec<JobId> = prev_plan.jobs().into_iter().collect();
                    if candidates.is_empty() {
                        continue;
                    }
                    let victim = candidates[(pick % candidates.len() as u64) as usize];
                    let one: BTreeSet<JobId> = [victim].into_iter().collect();
                    prev_plan.remove_jobs(&one);
                    pending_replacement.insert(victim);
                    preemptions += 1;
                    metrics::counter_add("sim.preemptions", 1);
                }
                FaultKind::Straggle { pick, factor, rounds } => {
                    let candidates: Vec<JobId> = prev_plan.jobs().into_iter().collect();
                    if candidates.is_empty() {
                        continue;
                    }
                    let victim = candidates[(pick % candidates.len() as u64) as usize];
                    stragglers.insert(victim, (*factor, round + rounds));
                    straggle_events += 1;
                    metrics::counter_add("sim.stragglers", 1);
                }
                kind => {
                    let flipped = health.apply(&cfg.spec, kind);
                    let failing =
                        matches!(kind, FaultKind::GpuFail(_) | FaultKind::NodeFail(_));
                    if !failing || flipped.is_empty() {
                        continue;
                    }
                    // Evict everything on the GPUs that just died: the
                    // jobs leave the committed plan and re-enter the
                    // window unplaced (re-placement charges the startup
                    // overhead, like any cold start).
                    let mut dead_jobs: BTreeSet<JobId> = BTreeSet::new();
                    for &g in &flipped {
                        dead_jobs.extend(prev_plan.jobs_on(g).iter().copied());
                    }
                    if !dead_jobs.is_empty() {
                        evictions += dead_jobs.len() as u64;
                        metrics::counter_add("sim.evictions", dead_jobs.len() as u64);
                        pending_replacement.extend(dead_jobs.iter().copied());
                        prev_plan.remove_jobs(&dead_jobs);
                    }
                }
            }
        }
        if !stragglers.is_empty() {
            stragglers.retain(|_, &mut (_, until)| until > round);
        }
        // Admit arrivals up to `now`.
        while arrived < trace.jobs.len() && trace.jobs[arrived].arrival_time <= now {
            let job = trace.jobs[arrived].clone();
            let (_, best_iso) = truth.best_isolated(job.model, job.num_gpus);
            states.insert(
                job.id,
                JobState {
                    completed_iters: 0.0,
                    attained_service: 0.0,
                    rounds_received: 0,
                    migrations: 0,
                    finish_time: None,
                    best_iso,
                    job,
                },
            );
            arrived += 1;
        }

        active.clear();
        active.extend(
            states
                .values()
                .filter(|s| s.finish_time.is_none())
                .map(|s| JobInfo {
                    id: s.job.id,
                    model: s.job.model,
                    num_gpus: s.job.num_gpus,
                    arrival_time: s.job.arrival_time,
                    attained_service: s.attained_service,
                    total_iters: s.job.total_iters,
                    completed_iters: s.completed_iters,
                    rounds_received: s.rounds_received,
                    now,
                    iso_tput: s.best_iso,
                }),
        );

        if active.is_empty() {
            if arrived >= trace.jobs.len() {
                break; // drained
            }
            // Idle gap until the next arrival. Either spin one empty round
            // (seed behaviour) or jump straight to the admitting round —
            // the intermediate rounds do nothing but reset the plan.
            prev_plan = PlacementPlan::new(total_gpus);
            round = if cfg.skip_idle_gaps {
                next_admitting_round(round, trace.jobs[arrived].arrival_time, cfg.round_duration)
            } else {
                round + 1
            };
            continue;
        }

        // Scheduler decision. The span covers the whole busy round —
        // decision plus job advancement — so a Chrome trace shows the
        // simulator's cadence around the pipeline's stage spans.
        crate::obs_span!("sim.round", { round: round, active: active.len() });
        let decision = scheduler.decide(&RoundInput {
            now,
            round,
            active: &active,
            prev_plan: &prev_plan,
            spec: &cfg.spec,
            health: (!health.all_healthy()).then_some(&health),
        });
        timings.push(decision.timings);
        if decision.degraded {
            degraded_rounds += 1;
        }
        if cfg!(debug_assertions) && !health.all_healthy() {
            if let Err(e) = health.validate_plan(&decision.plan) {
                recorder::dump_on_failure("simulator: decision placed a job on a dead GPU");
                panic!("scheduler '{}' round {round}: {e}", scheduler.name());
            }
        }

        // Advance placed jobs, counting migrations from the plan diff.
        // Each job's throughput and overhead derivation is pure reads over
        // the plan, job states and ground truth, so that half shards
        // across the shared worker pool; the state mutations are then
        // applied sequentially in the same job-id order, making the round
        // bit-identical to the inline loop for any thread budget.
        let plan = &decision.plan;
        let dp = ParallelismStrategy::DataParallel;
        // A degraded round carries no strategies (the fallback never ran
        // the estimator); jobs keep last round's strategies rather than
        // all collapsing to data-parallel for one round.
        let strategies = if decision.degraded {
            &last_strategies
        } else {
            &decision.strategies
        };
        struct Advance {
            job: JobId,
            tput: f64,
            overhead: f64,
            moved: bool,
            started: bool,
            infeasible: bool,
        }
        let placed: Vec<(JobId, &Vec<usize>)> = plan
            .job_gpu_map()
            .iter()
            .filter(|(_, gpus)| !gpus.is_empty())
            .map(|(&j, gpus)| (j, gpus))
            .collect();
        let advances: Vec<Advance> =
            WorkerPool::global().map(&placed, 0, 64, |_, &(job_id, job_gpus)| {
                let gpus: &[usize] = job_gpus;
                // Identify a packing partner (a job sharing the first GPU).
                let partner: Option<JobId> = plan
                    .jobs_on(gpus[0])
                    .iter()
                    .copied()
                    .find(|&j| j != job_id);

                let s = &states[&job_id];
                let (model, n) = (s.job.model, s.job.num_gpus);
                let strategy = strategies
                    .get(&job_id)
                    .cloned()
                    .unwrap_or_else(|| dp.clone());

                let (tput, infeasible) = match partner {
                    Some(p) => {
                        let ps = &states[&p];
                        let pstrat = strategies
                            .get(&p)
                            .cloned()
                            .unwrap_or_else(|| dp.clone());
                        match truth.true_packed_tput(
                            (model, &strategy),
                            (ps.job.model, &pstrat),
                            n,
                        ) {
                            Some((ta, _)) => (ta, false),
                            // The scheduler packed an infeasible pair
                            // (possible only with bad estimates): the job
                            // thrashes and makes no progress this round.
                            // Counted and flight-dumped below, never a
                            // crash.
                            None => (0.0, true),
                        }
                    }
                    None => (truth.true_isolated_tput(model, &strategy, n), false),
                };
                // Straggling jobs progress at a reduced rate (GPU time is
                // still consumed at full rate).
                let tput = match stragglers.get(&job_id) {
                    Some(&(factor, _)) => tput * factor,
                    None => tput,
                };

                // Overheads: migration (present in both rounds, moved
                // GPUs) or cold start (absent from the previous plan).
                let prev_gpus = prev_plan.gpus_of(job_id);
                let was_placed = !prev_gpus.is_empty();
                let moved = was_placed && prev_gpus != gpus;
                let overhead = if moved {
                    cfg.migration_overhead_s
                } else if !was_placed {
                    cfg.startup_overhead_s
                } else {
                    0.0
                };
                Advance {
                    job: job_id,
                    tput,
                    overhead,
                    moved,
                    started: !was_placed,
                    infeasible,
                }
            });

        let mut round_migrations = 0usize;
        for adv in advances {
            if adv.infeasible {
                if infeasible_pairs == 0 {
                    // First occurrence ships its own evidence (no-op when
                    // telemetry is off and the ring is empty).
                    recorder::dump_on_failure("simulator: realized packed pair is infeasible");
                }
                infeasible_pairs += 1;
                metrics::counter_add("sim.infeasible_pack", 1);
                crate::obs_log!(
                    warn,
                    "round {round}: packed pair for job {} infeasible on true \
                     throughputs; job thrashes this round",
                    adv.job
                );
            }
            if adv.started && pending_replacement.remove(&adv.job) {
                replacements += 1;
                metrics::counter_add("sim.replacements", 1);
            }
            let effective = (cfg.round_duration - adv.overhead).max(0.0);
            let s = states.get_mut(&adv.job).unwrap();
            if adv.moved {
                s.migrations += 1;
                round_migrations += 1;
            }
            s.rounds_received += 1;
            s.attained_service += s.job.num_gpus as f64 * effective;
            if s.finish_time.is_none() && adv.tput > 0.0 {
                let remaining = s.job.total_iters - s.completed_iters;
                let needed = remaining / adv.tput;
                if needed <= effective {
                    let t_done = now + adv.overhead + needed;
                    s.finish_time = Some(t_done);
                    s.completed_iters = s.job.total_iters;
                    makespan = makespan.max(t_done);
                } else {
                    s.completed_iters += adv.tput * effective;
                }
            }
        }
        // Plan-diff counts are the single source of truth; the scheduler's
        // self-reported number must agree (Definition 1). On a mismatch the
        // flight recorder dumps the last rounds' spans and metric deltas
        // before the panic, so a failure deep in a long sweep ships its own
        // evidence.
        if cfg!(debug_assertions) {
            let plan_diff = decision.plan.migrations_from(&prev_plan);
            if round_migrations != plan_diff {
                recorder::dump_on_failure("simulator: per-job migration accounting vs plan diff");
                panic!(
                    "per-job migration accounting ({round_migrations}) diverged \
                     from the plan diff ({plan_diff})"
                );
            }
            if round_migrations != decision.migrations {
                recorder::dump_on_failure(
                    "simulator: scheduler self-reported migrations vs plan diff",
                );
                panic!(
                    "scheduler '{}' self-reported a migration count ({}) that \
                     disagrees with the plan diff ({round_migrations})",
                    scheduler.name(),
                    decision.migrations
                );
            }
        }
        total_migrations += round_migrations;

        if !decision.degraded {
            last_strategies = decision.strategies;
        }
        prev_plan = decision.plan;
        round += 1;
        // Snapshot after the round commits: `round` is now exactly "the
        // next round to execute", which is what restore resumes at.
        if let Some(store) = &store {
            if round % recovery.snapshot_every.max(1) == 0 {
                crate::obs_span!("snapshot.write", { round: round });
                let doc = snapshot_to_json(
                    &SnapshotView {
                        round,
                        arrived,
                        next_fault,
                        total_migrations,
                        makespan,
                        evictions,
                        preemptions,
                        replacements,
                        straggle_events,
                        degraded_rounds,
                        infeasible_pairs,
                        prev_plan: &prev_plan,
                        states: &states,
                        stragglers: &stragglers,
                        pending_replacement: &pending_replacement,
                        last_strategies: &last_strategies,
                    },
                    scheduler,
                );
                if let Err(e) = store.write(round, &doc) {
                    crate::obs_log!(warn, "snapshot write failed at round {round}: {e}");
                }
            }
        }
        if recovery.stop_after_round.is_some_and(|r| round > r) {
            break;
        }
        if round >= cfg.max_rounds {
            break;
        }
    }

    let mut outcomes = BTreeMap::new();
    let mut unfinished = 0usize;
    for (id, s) in &states {
        match s.finish_time {
            Some(t) => {
                let jct = t - s.job.arrival_time;
                let iso = s.job.total_iters / s.best_iso.max(1e-9);
                outcomes.insert(
                    *id,
                    JobOutcome {
                        jct,
                        ftf: jct / iso.max(1e-9),
                        migrations: s.migrations,
                        rounds_run: s.rounds_received,
                    },
                );
            }
            None => unfinished += 1,
        }
    }
    let jcts: Vec<f64> = outcomes.values().map(|o| o.jct).collect();

    SimResult {
        scheduler: scheduler.name(),
        avg_jct: stats::mean(&jcts),
        makespan,
        total_migrations,
        rounds: round,
        timings,
        unfinished,
        evictions,
        preemptions,
        replacements,
        stragglers: straggle_events,
        degraded_rounds,
        infeasible_pairs,
        outcomes,
        metrics: metrics_base.map(|base| metrics::snapshot().delta_since(&base)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::estimator::OracleEstimator;
    use crate::matching::HungarianEngine;
    use crate::schedulers::TesseraeScheduler;
    use crate::trace::TraceParams;
    use std::sync::Arc;

    fn small_trace(n: usize, seed: u64) -> Trace {
        Trace::shockwave(&TraceParams {
            num_jobs: n,
            jobs_per_hour: 120.0,
            seed,
        })
    }

    fn quick_cfg() -> SimConfig {
        SimConfig::new(ClusterSpec::new(2, 4, GpuType::A100))
    }

    fn tesserae_t() -> TesseraeScheduler {
        let p = Profiler::new(GpuType::A100, 42);
        TesseraeScheduler::tesserae_t(
            Arc::new(OracleEstimator::new(p)),
            Arc::new(HungarianEngine),
        )
    }

    fn tiresias() -> TesseraeScheduler {
        let p = Profiler::new(GpuType::A100, 42);
        TesseraeScheduler::tiresias(
            Arc::new(OracleEstimator::new(p)),
            Arc::new(HungarianEngine),
        )
    }

    #[test]
    fn all_jobs_complete() {
        let trace = small_trace(20, 3);
        let truth = Profiler::new(GpuType::A100, 42);
        let mut s = tesserae_t();
        let r = simulate(&trace, &mut s, &truth, &quick_cfg());
        assert_eq!(r.unfinished, 0);
        assert_eq!(r.outcomes.len(), 20);
        assert!(r.avg_jct > 0.0);
        assert!(r.makespan > 0.0);
    }

    #[test]
    fn jct_at_least_isolated_duration() {
        let trace = small_trace(15, 5);
        let truth = Profiler::new(GpuType::A100, 42);
        let mut s = tesserae_t();
        let r = simulate(&trace, &mut s, &truth, &quick_cfg());
        for (id, o) in &r.outcomes {
            // FTF = JCT / isolated >= ~1 (small tolerance for the jitter in
            // the profiled throughputs).
            assert!(o.ftf > 0.8, "job {id} ftf {}", o.ftf);
        }
    }

    #[test]
    fn packing_scheduler_beats_no_packing_on_contended_cluster() {
        // The headline effect (Fig. 9/12 shape): with more jobs than GPUs
        // and pack-friendly models, Tesserae-T's Avg JCT beats Tiresias.
        let trace = small_trace(40, 7);
        let truth = Profiler::new(GpuType::A100, 42);
        let cfg = quick_cfg();
        let r_t = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        let r_b = simulate(&trace, &mut tiresias(), &truth, &cfg);
        assert_eq!(r_t.unfinished, 0);
        assert_eq!(r_b.unfinished, 0);
        assert!(
            r_t.avg_jct < r_b.avg_jct,
            "tesserae {} vs tiresias {}",
            r_t.avg_jct,
            r_b.avg_jct
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let trace = small_trace(10, 11);
        let truth = Profiler::new(GpuType::A100, 42);
        let cfg = quick_cfg();
        let a = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        let b = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_eq!(a.avg_jct, b.avg_jct);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.total_migrations, b.total_migrations);
    }

    #[test]
    fn gap_skipping_preserves_metrics() {
        // A sparse trace (1 job/hour on 8 GPUs) has real idle gaps between
        // arrivals; skipping them must leave every metric bit-identical to
        // spinning one empty round at a time.
        let trace = Trace::shockwave(&TraceParams {
            num_jobs: 12,
            jobs_per_hour: 1.0,
            seed: 23,
        });
        let truth = Profiler::new(GpuType::A100, 42);
        let skip_cfg = quick_cfg();
        let mut spin_cfg = quick_cfg();
        spin_cfg.skip_idle_gaps = false;
        let a = simulate(&trace, &mut tesserae_t(), &truth, &skip_cfg);
        let b = simulate(&trace, &mut tesserae_t(), &truth, &spin_cfg);
        assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.total_migrations, b.total_migrations);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (id, oa) in &a.outcomes {
            assert_eq!(oa.jct.to_bits(), b.outcomes[id].jct.to_bits());
            assert_eq!(oa.migrations, b.outcomes[id].migrations);
        }
        // The trace must actually contain idle gaps for this test to mean
        // anything: busy rounds (one timing each) < total rounds.
        assert!(
            (a.timings.len() as u64) < a.rounds,
            "trace had no idle gaps: {} busy rounds of {}",
            a.timings.len(),
            a.rounds
        );
    }

    #[test]
    fn next_admitting_round_matches_spin_semantics() {
        let dur = 360.0;
        for (round, arrival) in [
            (0u64, 1.0),
            (0, 359.9),
            (0, 360.0),
            (0, 360.1),
            (3, 10_000.0),
            (7, 2520.0 + 1e-9),
        ] {
            let k = next_admitting_round(round, arrival, dur);
            assert!(k > round);
            assert!(k as f64 * dur >= arrival, "round {k} misses {arrival}");
            assert!(
                (k - 1) == round || ((k - 1) as f64) * dur < arrival,
                "round {} would already have admitted {arrival}",
                k - 1
            );
        }
    }

    #[test]
    fn migration_overhead_slows_jobs() {
        let trace = small_trace(25, 13);
        let truth = Profiler::new(GpuType::A100, 42);
        let mut cheap = quick_cfg();
        cheap.migration_overhead_s = 0.0;
        cheap.startup_overhead_s = 0.0;
        let mut costly = quick_cfg();
        costly.migration_overhead_s = 300.0;
        costly.startup_overhead_s = 60.0;
        let r_cheap = simulate(&trace, &mut tiresias(), &truth, &cheap);
        let r_costly = simulate(&trace, &mut tiresias(), &truth, &costly);
        assert!(
            r_costly.avg_jct >= r_cheap.avg_jct,
            "{} vs {}",
            r_costly.avg_jct,
            r_cheap.avg_jct
        );
    }

    #[test]
    fn timings_recorded_per_round() {
        let trace = small_trace(10, 17);
        let truth = Profiler::new(GpuType::A100, 42);
        let r = simulate(&trace, &mut tesserae_t(), &truth, &quick_cfg());
        assert!(!r.timings.is_empty());
        assert!(r.avg_decision_time() >= 0.0);
    }

    // ---- fault injection ------------------------------------------------

    use crate::faults::FaultEvent;
    use crate::jobs::ModelKind;
    use crate::schedulers::{run_round, RoundContext, RoundDecision, StageProvider};

    fn script(events: Vec<(u64, FaultKind)>) -> FaultPlan {
        FaultPlan::from_events(
            events
                .into_iter()
                .map(|(round, kind)| FaultEvent { round, kind })
                .collect(),
        )
    }

    fn assert_same_result(a: &SimResult, b: &SimResult) {
        assert_eq!(a.avg_jct.to_bits(), b.avg_jct.to_bits());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.total_migrations, b.total_migrations);
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.evictions, b.evictions);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.replacements, b.replacements);
        assert_eq!(a.stragglers, b.stragglers);
        assert_eq!(a.degraded_rounds, b.degraded_rounds);
        assert_eq!(a.infeasible_pairs, b.infeasible_pairs);
        assert_eq!(a.outcomes.len(), b.outcomes.len());
        for (id, oa) in &a.outcomes {
            assert_eq!(oa.jct.to_bits(), b.outcomes[id].jct.to_bits());
            assert_eq!(oa.migrations, b.outcomes[id].migrations);
        }
    }

    #[test]
    fn empty_fault_plan_matches_plain_run() {
        // `SimConfig::new` already carries `FaultPlan::none()`, so this is
        // the rate-0 identity at the config level: spelling the empty plan
        // explicitly changes nothing, bit for bit.
        let trace = small_trace(12, 19);
        let truth = Profiler::new(GpuType::A100, 42);
        let plain = quick_cfg();
        let mut explicit = quick_cfg();
        explicit.faults = FaultPlan::from_events(Vec::new());
        let a = simulate(&trace, &mut tesserae_t(), &truth, &plain);
        let b = simulate(&trace, &mut tesserae_t(), &truth, &explicit);
        assert_same_result(&a, &b);
        assert_eq!(a.evictions + a.preemptions + a.stragglers, 0);
        assert_eq!(a.degraded_rounds, 0);
    }

    #[test]
    fn gpu_and_node_failures_evict_and_replace_jobs() {
        // A contended cluster (16 jobs, 8 GPUs) guarantees every GPU is
        // busy when the failures land, so the evictions must fire; the
        // scheduler then re-places the victims (replacements) and every
        // job still completes despite half the cluster dying mid-run.
        let trace = small_trace(16, 3);
        let truth = Profiler::new(GpuType::A100, 42);
        let mut cfg = quick_cfg();
        cfg.faults = script(vec![
            (2, FaultKind::GpuFail(0)),
            (4, FaultKind::NodeFail(1)),
            (10, FaultKind::GpuRecover(0)),
            (12, FaultKind::NodeRecover(1)),
        ]);
        let r = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_eq!(r.unfinished, 0, "faulted run must still drain");
        assert!(r.evictions >= 1, "busy GPUs died but nothing was evicted");
        assert!(
            r.replacements >= 1,
            "evicted jobs were never placed again"
        );
        assert_eq!(r.degraded_rounds, 0, "no stage failed in this script");
        // Same script, same seed: bit-identical.
        let r2 = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_same_result(&r, &r2);
    }

    #[test]
    fn preempt_and_straggle_events_are_counted() {
        let trace = small_trace(12, 29);
        let truth = Profiler::new(GpuType::A100, 42);
        let mut cfg = quick_cfg();
        cfg.faults = script(vec![
            (
                2,
                FaultKind::Straggle {
                    pick: 1,
                    factor: 0.25,
                    rounds: 4,
                },
            ),
            (3, FaultKind::Preempt { pick: 3 }),
            (5, FaultKind::Preempt { pick: 7 }),
        ]);
        let r = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_eq!(r.unfinished, 0);
        assert_eq!(r.preemptions, 2);
        assert_eq!(r.stragglers, 1);
        assert!(r.replacements >= 1, "preempted jobs must come back");
        let r2 = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_same_result(&r, &r2);
    }

    #[test]
    fn generated_plan_runs_deterministically() {
        // Rate-driven plans (the fault-matrix path) through the full
        // simulator: per-seed determinism and a drained trace.
        let trace = small_trace(14, 31);
        let truth = Profiler::new(GpuType::A100, 42);
        let mut cfg = quick_cfg();
        cfg.faults = FaultPlan::generate(
            &crate::faults::FaultConfig {
                gpu_mtbf_rounds: 40.0,
                preempts_per_round: 0.05,
                stragglers_per_round: 0.05,
                ..Default::default()
            },
            &cfg.spec,
            400,
        );
        assert!(!cfg.faults.is_empty());
        let r = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_eq!(r.unfinished, 0);
        let r2 = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_same_result(&r, &r2);
    }

    /// Deliberately packs every job onto GPU 0 with memory-hungry models so
    /// the realized pair OOMs (`true_packed_tput` = `None`) — the
    /// regression case for the old `.unwrap_or(0.0)` silent-zero branch.
    struct MaliciousPacker;

    impl Scheduler for MaliciousPacker {
        fn name(&self) -> String {
            "malicious-packer".into()
        }

        fn decide(&mut self, input: &RoundInput) -> RoundDecision {
            let mut plan = PlacementPlan::new(input.spec.total_gpus());
            for info in input.active.iter().take(2) {
                plan.place(info.id, &[0]);
            }
            let migrations = plan.migrations_from(input.prev_plan);
            RoundDecision {
                plan,
                strategies: BTreeMap::new(),
                packed_pairs: Vec::new(),
                migrations,
                degraded: false,
                timings: DecisionTimings::default(),
            }
        }
    }

    #[test]
    fn infeasible_packed_pair_is_counted_not_fatal() {
        // Two 3B-parameter jobs need 38 GB each; packed on one 40 GB A100
        // the pair cannot exist, so the ground truth refuses it. The run
        // must keep going (jobs thrash, never finish) and count every
        // occurrence instead of silently zeroing throughput.
        let job = |id: u64| Job {
            id,
            model: ModelKind::Gpt3_3B,
            num_gpus: 1,
            arrival_time: 0.0,
            total_iters: 1_000.0,
            batch_size: 8,
        };
        let trace = Trace {
            jobs: vec![job(0), job(1)],
        };
        let truth = Profiler::new(GpuType::A100, 42);
        let mut cfg = quick_cfg();
        cfg.max_rounds = 40;
        let r = simulate(&trace, &mut MaliciousPacker, &truth, &cfg);
        assert_eq!(r.unfinished, 2, "an OOM pack must not make progress");
        // Both tenants of the impossible pair are flagged every round.
        assert_eq!(r.infeasible_pairs, 2 * r.rounds);
        assert_eq!(r.rounds, 40);
        let r2 = simulate(&trace, &mut MaliciousPacker, &truth, &cfg);
        assert_eq!(r.infeasible_pairs, r2.infeasible_pairs);
    }

    /// Tesserae-T with a pack stage that panics at one chosen round:
    /// exercises the degraded-mode fallback end-to-end inside the
    /// simulator (no env vars, so parallel tests can't collide).
    struct FlakyTesserae {
        inner: TesseraeScheduler,
        fail_round: u64,
    }

    impl StageProvider for FlakyTesserae {
        fn estimate(&mut self, cx: &mut RoundContext) {
            self.inner.estimate(cx);
        }
        fn schedule(&mut self, cx: &mut RoundContext) {
            self.inner.schedule(cx);
        }
        fn pack(&mut self, cx: &mut RoundContext) {
            if cx.input.round == self.fail_round {
                panic!("injected pack failure at round {}", self.fail_round);
            }
            self.inner.pack(cx);
        }
        fn migrate(&mut self, cx: &mut RoundContext) {
            self.inner.migrate(cx);
        }
        fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
            self.inner.commit(cx)
        }
        fn reset_after_failure(&mut self) {
            self.inner.reset_after_failure();
        }
    }

    impl Scheduler for FlakyTesserae {
        fn name(&self) -> String {
            "flaky-tesserae".into()
        }
        fn decide(&mut self, input: &RoundInput) -> RoundDecision {
            run_round(self, input)
        }
    }

    #[test]
    fn stage_failure_mid_run_degrades_one_round_and_recovers() {
        let trace = small_trace(12, 37);
        let truth = Profiler::new(GpuType::A100, 42);
        let cfg = quick_cfg();
        let mut flaky = FlakyTesserae {
            inner: tesserae_t(),
            fail_round: 3,
        };
        let r = simulate(&trace, &mut flaky, &truth, &cfg);
        assert_eq!(r.degraded_rounds, 1, "exactly one round fell back");
        assert_eq!(r.unfinished, 0, "the run must recover and drain");
        let mut flaky2 = FlakyTesserae {
            inner: tesserae_t(),
            fail_round: 3,
        };
        let r2 = simulate(&trace, &mut flaky2, &truth, &cfg);
        assert_same_result(&r, &r2);
    }

    #[test]
    fn faults_during_idle_gaps_resolve_like_spinning() {
        // Events landing inside a skipped idle gap must leave the run
        // bit-identical to spinning through the gap one round at a time:
        // the gap holds no placed jobs, so preempt/straggle draws are
        // no-ops either way and health flips apply in the same order.
        let trace = Trace::shockwave(&TraceParams {
            num_jobs: 10,
            jobs_per_hour: 1.0,
            seed: 23,
        });
        let truth = Profiler::new(GpuType::A100, 42);
        let faults = script(vec![
            (1, FaultKind::GpuFail(2)),
            (5, FaultKind::Preempt { pick: 2 }),
            (9, FaultKind::GpuRecover(2)),
            (
                20,
                FaultKind::Straggle {
                    pick: 0,
                    factor: 0.5,
                    rounds: 3,
                },
            ),
            (40, FaultKind::NodeFail(0)),
            (60, FaultKind::NodeRecover(0)),
        ]);
        let mut skip_cfg = quick_cfg();
        skip_cfg.faults = faults.clone();
        let mut spin_cfg = quick_cfg();
        spin_cfg.skip_idle_gaps = false;
        spin_cfg.faults = faults;
        let a = simulate(&trace, &mut tesserae_t(), &truth, &skip_cfg);
        let b = simulate(&trace, &mut tesserae_t(), &truth, &spin_cfg);
        assert_same_result(&a, &b);
        assert_eq!(a.unfinished, 0);
    }

    // ---- crash recovery -------------------------------------------------

    fn recovery_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tesserae-recovery-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// The faulted config the recovery tests share: failures, a recovery,
    /// a preemption and a straggler all land before and after the kill
    /// point, so the snapshot must carry every class of hard state.
    fn faulted_cfg() -> SimConfig {
        let mut cfg = quick_cfg();
        cfg.faults = script(vec![
            (2, FaultKind::GpuFail(1)),
            (
                3,
                FaultKind::Straggle {
                    pick: 2,
                    factor: 0.5,
                    rounds: 4,
                },
            ),
            (4, FaultKind::Preempt { pick: 5 }),
            (7, FaultKind::GpuRecover(1)),
            (9, FaultKind::Preempt { pick: 3 }),
        ]);
        cfg
    }

    #[test]
    fn snapshot_codec_round_trips_plan_slot_order_and_strategies() {
        // Slot order is semantic: job 5 was placed on GPU 1 before job 2
        // packed in, and the restored plan must reproduce exactly that.
        let mut plan = PlacementPlan::new(4);
        plan.place(5, &[1, 2]);
        plan.place(2, &[1]);
        plan.place(9, &[0]);
        let text = plan_to_json(&plan).to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        let back = plan_from_json(&parsed).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.jobs_on(1), &[5, 2], "within-slot order preserved");
        back.validate().unwrap();

        for s in [
            ParallelismStrategy::DataParallel,
            ParallelismStrategy::TensorParallel,
            ParallelismStrategy::Pipeline(vec![3, 2, 3]),
        ] {
            let text = strategy_to_json(&s).to_string_pretty();
            let parsed = Json::parse(&text).unwrap();
            assert_eq!(strategy_from_json(&parsed).unwrap(), s);
        }
    }

    #[test]
    fn killed_and_restored_run_matches_uninterrupted() {
        let trace = small_trace(16, 41);
        let truth = Profiler::new(GpuType::A100, 42);
        let cfg = faulted_cfg();
        let reference = simulate(&trace, &mut tesserae_t(), &truth, &cfg);
        assert_eq!(reference.unfinished, 0);
        assert!(reference.preemptions > 0, "script must actually preempt");

        let dir = recovery_dir("kill");
        let killed = simulate_recoverable(
            &trace,
            &mut tesserae_t(),
            &truth,
            &cfg,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 1,
                restore: false,
                stop_after_round: Some(5),
            },
        );
        assert!(
            killed.rounds < reference.rounds,
            "kill point must interrupt the run ({} vs {})",
            killed.rounds,
            reference.rounds
        );
        let resumed = simulate_recoverable(
            &trace,
            &mut tesserae_t(),
            &truth,
            &cfg,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 1,
                restore: true,
                stop_after_round: None,
            },
        );
        assert_same_result(&reference, &resumed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sparse_snapshots_replay_the_tail_to_parity() {
        // snapshot_every=3 means the kill at round 8 restores from round
        // 6 and replays rounds 6..8 — replayed rounds must land on the
        // same state the uninterrupted run passed through.
        let trace = small_trace(16, 41);
        let truth = Profiler::new(GpuType::A100, 42);
        let cfg = faulted_cfg();
        let reference = simulate(&trace, &mut tesserae_t(), &truth, &cfg);

        let dir = recovery_dir("sparse");
        let _ = simulate_recoverable(
            &trace,
            &mut tesserae_t(),
            &truth,
            &cfg,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 3,
                restore: false,
                stop_after_round: Some(8),
            },
        );
        let resumed = simulate_recoverable(
            &trace,
            &mut tesserae_t(),
            &truth,
            &cfg,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 3,
                restore: true,
                stop_after_round: None,
            },
        );
        assert_same_result(&reference, &resumed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_scheduler_snapshot_starts_cold() {
        // Snapshots taken under Tesserae-T must not poison a Tiresias
        // run: the restore detects the label mismatch and starts cold,
        // landing on the plain Tiresias result bit for bit.
        let trace = small_trace(12, 43);
        let truth = Profiler::new(GpuType::A100, 42);
        let cfg = quick_cfg();
        let dir = recovery_dir("mismatch");
        let _ = simulate_recoverable(
            &trace,
            &mut tesserae_t(),
            &truth,
            &cfg,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 1,
                restore: false,
                stop_after_round: Some(4),
            },
        );
        let plain = simulate(&trace, &mut tiresias(), &truth, &cfg);
        let restored = simulate_recoverable(
            &trace,
            &mut tiresias(),
            &truth,
            &cfg,
            &RecoveryOptions {
                state_dir: Some(dir.clone()),
                snapshot_every: 1,
                restore: true,
                stop_after_round: None,
            },
        );
        assert_same_result(&plain, &restored);
        let _ = std::fs::remove_dir_all(&dir);
    }
}

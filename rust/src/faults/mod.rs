//! Deterministic fault injection: seeded failure/recovery/preemption/
//! straggler event plans consumed by the simulator between rounds, plus
//! the per-GPU health state the schedulers consume during rounds.
//!
//! Two pieces:
//!
//! - [`FaultPlan`]: an ordered script of [`FaultEvent`]s, either written
//!   explicitly (tests, targeted scenarios) or generated from
//!   [`FaultConfig`] rates with a seeded [`Pcg64`] — the same config +
//!   seed always produces the same plan, so every faulted run replays
//!   exactly.
//! - [`ClusterHealth`]: a per-GPU *down-counter* (not a bool): node
//!   failures and single-GPU failures compose — a GPU inside a failed
//!   node that also failed individually stays dead until **both**
//!   recoveries land. `RoundInput.health` carries `Some(&ClusterHealth)`
//!   only when at least one GPU is down; `None` keeps every scheduler on
//!   its pre-fault code path, which is what makes the rate-0 bit-parity
//!   contract trivial to uphold and test.
//!
//! Eviction/re-placement semantics live in the simulator (jobs on dead
//! GPUs leave the committed plan and re-enter the job window); degraded-
//! mode fallback lives in `pipeline::run_round`.

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::jobs::JobId;
use crate::util::rng::Pcg64;

/// Job ids from this value down are reserved for the migration matcher's
/// dead-GPU blocker pseudo-jobs (`BLOCKER_BASE - gpu`); real workloads
/// never reach them.
pub const BLOCKER_BASE: JobId = u64::MAX;

/// One kind of injected fault. `Preempt`/`Straggle` carry a raw `pick`
/// draw instead of a job id so plans generated before the simulation
/// starts stay meaningful: the simulator resolves `pick % candidates`
/// against the deterministic, id-sorted candidate set of that round.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// One GPU dies (its down-counter increments).
    GpuFail(usize),
    /// One GPU's failure is repaired (down-counter decrements).
    GpuRecover(usize),
    /// Every GPU on the node dies.
    NodeFail(usize),
    /// The node repair lands.
    NodeRecover(usize),
    /// Evict one running job from the committed plan; it re-enters the
    /// job window and is re-placed by the scheduler next round.
    Preempt { pick: u64 },
    /// Slow one active job's progress rate by `factor` for `rounds`
    /// rounds.
    Straggle { pick: u64, factor: f64, rounds: u64 },
}

/// One scheduled fault: `kind` fires just before round `round` decides.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub round: u64,
    pub kind: FaultKind,
}

/// Rates for [`FaultPlan::generate`]. All rates default to 0 (no
/// events); `mtbf` fields are in rounds (mean time between failures per
/// GPU / per node), `preempts_per_round`/`stragglers_per_round` are
/// expected event counts per round.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Mean rounds between failures of each individual GPU (0 = never).
    pub gpu_mtbf_rounds: f64,
    /// Mean rounds between whole-node failures of each node (0 = never).
    pub node_mtbf_rounds: f64,
    /// Rounds a failed GPU/node stays down before its recovery fires.
    pub repair_rounds: u64,
    /// Expected job preemptions per round.
    pub preempts_per_round: f64,
    /// Expected new stragglers per round.
    pub stragglers_per_round: f64,
    /// Progress-rate multiplier applied to a straggling job (0 < f ≤ 1).
    pub straggler_factor: f64,
    /// Rounds a straggler stays slowed.
    pub straggler_rounds: u64,
    /// Seed for the event draws.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            gpu_mtbf_rounds: 0.0,
            node_mtbf_rounds: 0.0,
            repair_rounds: 10,
            preempts_per_round: 0.0,
            stragglers_per_round: 0.0,
            straggler_factor: 0.5,
            straggler_rounds: 5,
            seed: 1,
        }
    }
}

impl FaultConfig {
    /// Whether this config can ever emit an event.
    pub fn is_zero(&self) -> bool {
        self.gpu_mtbf_rounds <= 0.0
            && self.node_mtbf_rounds <= 0.0
            && self.preempts_per_round <= 0.0
            && self.stragglers_per_round <= 0.0
    }
}

/// A deterministic, round-ordered script of fault events.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// The empty plan: a faultless run, bit-identical to pre-fault code.
    pub fn none() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    /// An explicit script. Events are stably sorted by round, so
    /// within-round order is the order given.
    pub fn from_events(mut events: Vec<FaultEvent>) -> FaultPlan {
        events.sort_by_key(|e| e.round);
        FaultPlan { events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    /// Every event scheduled in `[from, to)`, in firing order. The
    /// half-open range lets the simulator's idle-gap skip apply the
    /// health effects of events inside the skipped window.
    pub fn events_in(&self, from: u64, to: u64) -> &[FaultEvent] {
        let lo = self.events.partition_point(|e| e.round < from);
        let hi = self.events.partition_point(|e| e.round < to);
        &self.events[lo..hi]
    }

    /// Generate a plan from rates: per-GPU and per-node renewal failure
    /// processes (exponential inter-failure gaps, fixed repair time) plus
    /// per-round Poisson-ish preemption/straggler draws. Deterministic in
    /// (`cfg`, `spec`, `horizon_rounds`).
    pub fn generate(cfg: &FaultConfig, spec: &ClusterSpec, horizon_rounds: u64) -> FaultPlan {
        if cfg.is_zero() {
            return FaultPlan::none();
        }
        let mut rng = Pcg64::new(cfg.seed ^ 0xfa_017);
        let mut events = Vec::new();
        let repair = cfg.repair_rounds.max(1);
        let renewal = |mtbf: f64, rng: &mut Pcg64, emit: &mut dyn FnMut(u64, u64)| {
            if mtbf <= 0.0 {
                return;
            }
            let mut t = 0u64;
            loop {
                // Exponential gap, at least one round so fail/recover
                // never collide on the same unit in the same round.
                let gap = (-mtbf * (1.0 - rng.f64()).ln()).ceil().max(1.0);
                if gap >= horizon_rounds as f64 {
                    return; // avoid u64 overflow on tiny rates
                }
                t = t.saturating_add(gap as u64);
                if t >= horizon_rounds {
                    return;
                }
                emit(t, t + repair);
                t += repair;
            }
        };
        for g in 0..spec.total_gpus() {
            renewal(cfg.gpu_mtbf_rounds, &mut rng, &mut |fail, recover| {
                events.push(FaultEvent { round: fail, kind: FaultKind::GpuFail(g) });
                events.push(FaultEvent { round: recover, kind: FaultKind::GpuRecover(g) });
            });
        }
        for n in 0..spec.num_nodes {
            renewal(cfg.node_mtbf_rounds, &mut rng, &mut |fail, recover| {
                events.push(FaultEvent { round: fail, kind: FaultKind::NodeFail(n) });
                events.push(FaultEvent { round: recover, kind: FaultKind::NodeRecover(n) });
            });
        }
        // Per-round expected-count draws: floor(λ) guaranteed events plus
        // one Bernoulli(frac(λ)) extra.
        let per_round = |rate: f64, rng: &mut Pcg64, emit: &mut dyn FnMut(u64, &mut Pcg64)| {
            if rate <= 0.0 {
                return;
            }
            for r in 1..horizon_rounds {
                let mut count = rate.floor() as u64;
                if rng.f64() < rate.fract() {
                    count += 1;
                }
                for _ in 0..count {
                    emit(r, rng);
                }
            }
        };
        per_round(cfg.preempts_per_round, &mut rng, &mut |r, rng| {
            events.push(FaultEvent {
                round: r,
                kind: FaultKind::Preempt { pick: rng.next_u64() },
            });
        });
        per_round(cfg.stragglers_per_round, &mut rng, &mut |r, rng| {
            events.push(FaultEvent {
                round: r,
                kind: FaultKind::Straggle {
                    pick: rng.next_u64(),
                    factor: cfg.straggler_factor.clamp(0.05, 1.0),
                    rounds: cfg.straggler_rounds.max(1),
                },
            });
        });
        FaultPlan::from_events(events)
    }
}

/// Per-GPU health: a down-counter per GPU so overlapping failure domains
/// (node + individual GPU) compose; a GPU is healthy iff its counter is
/// zero.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterHealth {
    down: Vec<u32>,
    num_down: usize,
}

impl ClusterHealth {
    /// All GPUs healthy.
    pub fn new(total_gpus: usize) -> ClusterHealth {
        ClusterHealth { down: vec![0; total_gpus], num_down: 0 }
    }

    pub fn num_gpus(&self) -> usize {
        self.down.len()
    }

    #[inline]
    pub fn is_healthy(&self, gpu: usize) -> bool {
        self.down[gpu] == 0
    }

    pub fn all_healthy(&self) -> bool {
        self.num_down == 0
    }

    pub fn num_healthy(&self) -> usize {
        self.down.len() - self.num_down
    }

    /// GPUs currently down, ascending.
    pub fn dead_gpus(&self) -> Vec<usize> {
        (0..self.down.len()).filter(|&g| self.down[g] > 0).collect()
    }

    /// Increment one GPU's down-counter; returns true if it just died.
    pub fn fail_gpu(&mut self, gpu: usize) -> bool {
        self.down[gpu] += 1;
        if self.down[gpu] == 1 {
            self.num_down += 1;
            true
        } else {
            false
        }
    }

    /// Decrement one GPU's down-counter (saturating: a recovery without
    /// a matching failure is ignored); returns true if it just revived.
    pub fn recover_gpu(&mut self, gpu: usize) -> bool {
        if self.down[gpu] == 0 {
            return false;
        }
        self.down[gpu] -= 1;
        if self.down[gpu] == 0 {
            self.num_down -= 1;
            true
        } else {
            false
        }
    }

    /// Fail every GPU of `node`; returns the GPUs that just died.
    pub fn fail_node(&mut self, spec: &ClusterSpec, node: usize) -> Vec<usize> {
        spec.gpus_of_node(node).filter(|&g| self.fail_gpu(g)).collect()
    }

    /// Recover every GPU of `node`; returns the GPUs that just revived.
    pub fn recover_node(&mut self, spec: &ClusterSpec, node: usize) -> Vec<usize> {
        spec.gpus_of_node(node).filter(|&g| self.recover_gpu(g)).collect()
    }

    /// Apply one event's health effect (preemptions/stragglers are not
    /// health events and are ignored here); returns the GPUs whose state
    /// flipped dead↔alive.
    pub fn apply(&mut self, spec: &ClusterSpec, kind: &FaultKind) -> Vec<usize> {
        match kind {
            FaultKind::GpuFail(g) => {
                if self.fail_gpu(*g) {
                    vec![*g]
                } else {
                    Vec::new()
                }
            }
            FaultKind::GpuRecover(g) => {
                if self.recover_gpu(*g) {
                    vec![*g]
                } else {
                    Vec::new()
                }
            }
            FaultKind::NodeFail(n) => self.fail_node(spec, *n),
            FaultKind::NodeRecover(n) => self.recover_node(spec, *n),
            FaultKind::Preempt { .. } | FaultKind::Straggle { .. } => Vec::new(),
        }
    }

    /// Cross-check a plan against health: no real job may occupy a dead
    /// GPU (blocker pseudo-jobs are the one sanctioned tenant).
    pub fn validate_plan(&self, plan: &PlacementPlan) -> Result<(), String> {
        assert_eq!(plan.num_gpus(), self.down.len(), "health/plan width mismatch");
        for g in 0..plan.num_gpus() {
            if self.down[g] == 0 {
                continue;
            }
            for &j in plan.jobs_on(g) {
                if j < BLOCKER_BASE - plan.num_gpus() as u64 {
                    return Err(format!("job {j} placed on dead GPU {g}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;

    fn spec() -> ClusterSpec {
        ClusterSpec::new(3, 4, GpuType::A100)
    }

    #[test]
    fn zero_rates_generate_no_events() {
        let plan = FaultPlan::generate(&FaultConfig::default(), &spec(), 1000);
        assert!(plan.is_empty());
        assert_eq!(plan, FaultPlan::none());
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let cfg = FaultConfig {
            gpu_mtbf_rounds: 40.0,
            node_mtbf_rounds: 120.0,
            preempts_per_round: 0.3,
            stragglers_per_round: 0.2,
            seed: 9,
            ..Default::default()
        };
        let a = FaultPlan::generate(&cfg, &spec(), 500);
        let b = FaultPlan::generate(&cfg, &spec(), 500);
        assert!(!a.is_empty(), "rates should produce events over 500 rounds");
        assert_eq!(a, b, "same seed must replay the same plan");
        let c = FaultPlan::generate(&FaultConfig { seed: 10, ..cfg }, &spec(), 500);
        assert_ne!(a, c, "different seed should draw a different plan");
    }

    #[test]
    fn generated_events_are_sorted_and_in_horizon() {
        let cfg = FaultConfig {
            gpu_mtbf_rounds: 25.0,
            preempts_per_round: 0.5,
            seed: 4,
            ..Default::default()
        };
        let plan = FaultPlan::generate(&cfg, &spec(), 200);
        let rounds: Vec<u64> = plan.events().iter().map(|e| e.round).collect();
        assert!(rounds.windows(2).all(|w| w[0] <= w[1]), "not sorted: {rounds:?}");
        // Failures land inside the horizon; trailing recoveries may spill
        // past it (the repair of a failure near the horizon edge).
        for e in plan.events() {
            match e.kind {
                FaultKind::GpuRecover(_) | FaultKind::NodeRecover(_) => {}
                _ => assert!(e.round < 200, "event past horizon: {e:?}"),
            }
        }
        // Every failure has its recovery exactly repair_rounds later.
        for e in plan.events() {
            if let FaultKind::GpuFail(g) = e.kind {
                assert!(
                    plan.events().iter().any(|r| r.round == e.round + cfg.repair_rounds
                        && r.kind == FaultKind::GpuRecover(g)),
                    "failure at {} of GPU {g} has no matching recovery",
                    e.round
                );
            }
        }
    }

    #[test]
    fn events_in_returns_half_open_window() {
        let plan = FaultPlan::from_events(vec![
            FaultEvent { round: 2, kind: FaultKind::GpuFail(0) },
            FaultEvent { round: 5, kind: FaultKind::GpuFail(1) },
            FaultEvent { round: 5, kind: FaultKind::Preempt { pick: 3 } },
            FaultEvent { round: 9, kind: FaultKind::GpuRecover(0) },
        ]);
        assert_eq!(plan.events_in(0, 2).len(), 0);
        assert_eq!(plan.events_in(2, 3).len(), 1);
        assert_eq!(plan.events_in(3, 6).len(), 2);
        assert_eq!(plan.events_in(0, 100).len(), 4);
    }

    #[test]
    fn overlapping_failure_domains_compose() {
        let spec = spec();
        let mut h = ClusterHealth::new(spec.total_gpus());
        assert!(h.all_healthy());
        // GPU 5 fails individually, then its whole node (node 1: GPUs
        // 4..8) fails too.
        assert!(h.fail_gpu(5));
        let died = h.fail_node(&spec, 1);
        assert_eq!(died, vec![4, 6, 7], "GPU 5 was already down");
        assert_eq!(h.num_healthy(), spec.total_gpus() - 4);
        // Node recovery alone must NOT revive GPU 5.
        let revived = h.recover_node(&spec, 1);
        assert_eq!(revived, vec![4, 6, 7]);
        assert!(!h.is_healthy(5));
        assert!(h.recover_gpu(5));
        assert!(h.all_healthy());
    }

    #[test]
    fn recover_without_failure_is_ignored() {
        let mut h = ClusterHealth::new(4);
        assert!(!h.recover_gpu(2));
        assert!(h.all_healthy());
    }

    #[test]
    fn validate_plan_rejects_job_on_dead_gpu() {
        let mut h = ClusterHealth::new(4);
        let mut plan = PlacementPlan::new(4);
        plan.place(7, &[1, 2]);
        assert!(h.validate_plan(&plan).is_ok());
        h.fail_gpu(2);
        let err = h.validate_plan(&plan).unwrap_err();
        assert!(err.contains("job 7") && err.contains("GPU 2"), "{err}");
        // Blocker pseudo-jobs are allowed on dead GPUs.
        let mut blocked = PlacementPlan::new(4);
        blocked.place(BLOCKER_BASE - 2, &[2]);
        assert!(h.validate_plan(&blocked).is_ok());
    }
}

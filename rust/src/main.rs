//! `tesserae` — CLI entrypoint for the Tesserae reproduction.
//!
//! Subcommands:
//!   gen-trace   generate a Shockwave/Gavel-style workload trace (JSON)
//!   simulate    run a scheduler over a trace and report JCT/makespan/FTF
//!   figure      regenerate one of the paper's figures/tables
//!   serve       real-execution mode: schedule actual training jobs over
//!               PJRT worker threads and report measured results
//!   engines     compare matching engines (Hungarian / auction / AOT)

use std::process::ExitCode;

use tesserae::cluster::GpuType;
use tesserae::coordinator::{run_cluster, ExecConfig, ExecJob};
use tesserae::experiments::{self, ablations, end_to_end, scalability, Scale, SchedKind};
use tesserae::faults::{FaultConfig, FaultPlan};
use tesserae::trace::{Trace, TraceParams};
use tesserae::util::checkpoint::Checkpoint;
use tesserae::util::cli::Args;

const USAGE: &str = "\
tesserae <command> [options]

commands:
  gen-trace   --out <path> [--jobs N] [--rate JOBS_PER_HOUR] [--seed S] [--gavel]
  simulate    --trace <path> | [--jobs N] ; [--scheduler NAME] [--nodes N]
              [--gpus-per-node G] [--gpu a100|v100] [--seed S] [--noise F]
              scheduler names: tesserae-t tesserae-ftf tiresias tiresias-single
                               gavel gavel-ftf pop sharded
              fault injection (deterministic per --fault-seed):
              [--gpu-mtbf-rounds F] [--node-mtbf-rounds F] [--repair-rounds N]
              [--preempt-rate F] [--straggler-rate F] [--fault-seed S]
              crash recovery (snapshots are generation-numbered JSON,
              written atomically; the last two generations are retained):
              [--state-dir DIR] [--snapshot-every N] [--restore]
              [--stop-after-round R] (stop right after the round-R snapshot
              to emulate a mid-flight kill; restore resumes bit-identically)
  figure      <fig1|fig2|fig3|fig7|fig8|fig9|fig11|fig12|fig13|fig14|fig15|
               fig16|fig17|fig18|table2|faults|scale>
              [--scale quick|standard|paper]
              fig2/fig14/scale also take [--budget-secs N] [--checkpoint PATH]
              (per-cell resume-safe JSON; re-runs skip completed cells)
              scale: sharded-coordinator sweep; [--quick] shrinks the grid,
              [--no-quality] skips the JCT-delta comparison
  serve       [--jobs N] [--nodes N] [--gpus-per-node G] [--round-secs F]
  engines     [--sizes 8,32,64] [--no-aot]

global options:
  --threads N  thread budget for the shared worker pool (matching batches,
               POP partitions, sharded per-job work, scenario sweeps);
               default: TESSERAE_THREADS env var, else all cores
  --trace-out PATH
               enable telemetry and write a Chrome trace-event JSON file
               (open in Perfetto or chrome://tracing) covering every round:
               estimate/schedule/pack/migrate/commit stages, LP solves,
               matching batches, worker-pool leases and chunks
  --stage-deadline-ms N
               soft per-stage watchdog budget, checked cooperatively at
               worker-pool chunk boundaries and LP iteration checkpoints;
               an overrunning stage aborts and the round degrades with
               reason \"deadline\" (0 disables; default: the
               TESSERAE_STAGE_DEADLINE_MS env var, else off)
";

fn parse_scale(args: &Args) -> Scale {
    match args.get_str("scale", "standard").as_str() {
        "quick" => Scale::quick(),
        "paper" => Scale::paper(),
        _ => Scale::standard(),
    }
}

fn parse_kind(name: &str) -> Option<SchedKind> {
    Some(match name {
        "tesserae-t" => SchedKind::TesseraeT,
        "tesserae-ftf" => SchedKind::TesseraeFtf,
        "tiresias" => SchedKind::Tiresias,
        "tiresias-single" => SchedKind::TiresiasSingle,
        "gavel" => SchedKind::Gavel,
        "gavel-ftf" => SchedKind::GavelFtf,
        "pop" => SchedKind::Pop(8),
        "sharded" => SchedKind::Sharded(8),
        _ => return None,
    })
}

fn main() -> ExitCode {
    let args = Args::from_env();
    // One knob for every source of parallelism: install the shared worker
    // pool's thread budget before any work runs.
    let threads = args.get_usize("threads", 0);
    if threads > 0 {
        tesserae::util::pool::WorkerPool::global().install_budget(threads);
    }
    // --stage-deadline-ms: arm the cooperative stage watchdog for the
    // whole process (overrides the TESSERAE_STAGE_DEADLINE_MS env var).
    if let Some(ms) = args.get("stage-deadline-ms").and_then(|s| s.parse().ok()) {
        tesserae::recovery::watchdog::set_stage_deadline_ms(Some(ms));
    }
    // --trace-out: turn telemetry on for the whole run and retain every
    // drained span for Chrome trace export at exit.
    let trace_out = args.get("trace-out").map(str::to_string);
    if trace_out.is_some() {
        tesserae::obs::set_enabled(true);
        tesserae::obs::span::set_retain(true);
    }
    let Some(cmd) = args.subcommand() else {
        eprint!("{USAGE}");
        return ExitCode::FAILURE;
    };
    let result = match cmd {
        "gen-trace" => cmd_gen_trace(&args),
        "simulate" => cmd_simulate(&args),
        "figure" => cmd_figure(&args),
        "serve" => cmd_serve(&args),
        "engines" => cmd_engines(&args),
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            return ExitCode::FAILURE;
        }
    };
    if let Some(path) = &trace_out {
        // Sweep up spans still buffered on this thread or in the sink,
        // then export everything retained over the run.
        tesserae::obs::span::drain_events();
        let events = tesserae::obs::span::take_trace();
        match tesserae::obs::span::write_chrome_trace(path, &events) {
            Ok(()) => eprintln!("wrote {} trace events to {path}", events.len()),
            Err(e) => eprintln!("error: trace export to {path} failed: {e}"),
        }
    }
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn cmd_gen_trace(args: &Args) -> anyhow::Result<()> {
    let params = TraceParams {
        num_jobs: args.get_usize("jobs", 900),
        jobs_per_hour: args.get_f64("rate", 80.0),
        seed: args.get_u64("seed", 1),
    };
    let trace = if args.flag("gavel") {
        Trace::gavel(&params)
    } else {
        Trace::shockwave(&params)
    };
    let out = args.get_str("out", "trace.json");
    trace.save(&out)?;
    println!("wrote {} jobs to {out}", trace.jobs.len());
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let gpu = GpuType::from_name(&args.get_str("gpu", "a100"))
        .ok_or_else(|| anyhow::anyhow!("unknown gpu type"))?;
    let scale = Scale {
        jobs: args.get_usize("jobs", 300),
        nodes: args.get_usize("nodes", 20),
        gpus_per_node: args.get_usize("gpus-per-node", 4),
        jobs_per_hour: args.get_f64("rate", 80.0),
        seed: args.get_u64("seed", 7),
    };
    let trace = match args.get("trace") {
        Some(path) => Trace::load(path)?,
        None => scale.shockwave_trace(),
    };
    let name = args.get_str("scheduler", "tesserae-t");
    let kind =
        parse_kind(&name).ok_or_else(|| anyhow::anyhow!("unknown scheduler '{name}'"))?;
    let noise = args.get_f64("noise", 0.0);
    let fault_cfg = FaultConfig {
        gpu_mtbf_rounds: args.get_f64("gpu-mtbf-rounds", 0.0),
        node_mtbf_rounds: args.get_f64("node-mtbf-rounds", 0.0),
        repair_rounds: args.get_u64("repair-rounds", 10),
        preempts_per_round: args.get_f64("preempt-rate", 0.0),
        stragglers_per_round: args.get_f64("straggler-rate", 0.0),
        seed: args.get_u64("fault-seed", 1),
        ..Default::default()
    };
    let spec = scale.spec(gpu);
    let recovery = tesserae::simulator::RecoveryOptions {
        state_dir: args.get("state-dir").map(std::path::PathBuf::from),
        snapshot_every: args.get_u64("snapshot-every", 5),
        restore: args.flag("restore"),
        stop_after_round: args.get("stop-after-round").and_then(|s| s.parse().ok()),
    };
    if let Some(dir) = &recovery.state_dir {
        eprintln!(
            "recovery: state-dir={} snapshot-every={} restore={}",
            dir.display(),
            recovery.snapshot_every.max(1),
            recovery.restore
        );
    }
    let r = if fault_cfg.is_zero() {
        experiments::run_sim_recoverable(kind, &trace, spec, scale.seed, noise, &recovery)
    } else {
        if noise > 0.0 {
            anyhow::bail!("--noise is not supported together with fault injection");
        }
        let plan = FaultPlan::generate(&fault_cfg, &spec, 1_000_000);
        eprintln!("fault plan: {} events", plan.len());
        experiments::faults::run_sim_faulted_recoverable(
            kind,
            &trace,
            spec,
            scale.seed,
            &plan,
            &recovery,
        )
    };
    println!(
        "{}: jobs={} avg JCT={:.0}s makespan={:.0}s migrations={} worst FTF={:.2} avg decision={:.4}s",
        r.scheduler,
        r.outcomes.len(),
        r.avg_jct,
        r.makespan,
        r.total_migrations,
        r.worst_ftf(),
        r.avg_decision_time()
    );
    if !fault_cfg.is_zero() || r.degraded_rounds > 0 || r.infeasible_pairs > 0 {
        println!(
            "faults: evictions={} preemptions={} replacements={} stragglers={} \
             degraded rounds={} infeasible pairs={} unfinished={}",
            r.evictions,
            r.preemptions,
            r.replacements,
            r.stragglers,
            r.degraded_rounds,
            r.infeasible_pairs,
            r.unfinished
        );
    }
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .ok_or_else(|| anyhow::anyhow!("figure needs an id\n{USAGE}"))?
        .as_str();
    let scale = parse_scale(args);
    let report = match id {
        "fig1" => ablations::fig1_migration_example(),
        "fig2" | "fig14a" => {
            let budget = std::time::Duration::from_secs(args.get_u64("budget-secs", 120));
            let counts = scalability::FIG2_PAPER_JOB_COUNTS;
            match args.get("checkpoint") {
                Some(path) => {
                    let mut ckpt = Checkpoint::load_or_new(path);
                    scalability::fig2_decision_time_checkpointed(
                        &counts,
                        budget,
                        Some(&mut ckpt),
                    )
                }
                None => scalability::fig2_decision_time(&counts, budget),
            }
        }
        "fig3" => end_to_end::fig3_real_migration_overhead(args.get_f64("round-secs", 0.5))?,
        "fig7" => ablations::fig7_packing_example(),
        "fig8" => ablations::fig8_parallelism_packing(),
        "fig9" => end_to_end::fig9_tesserae_vs_tiresias(&scale).0,
        "fig11" => end_to_end::fig11_vs_gavel(&scale),
        "fig12" => end_to_end::fig12_vs_tiresias_single(&scale),
        "fig13" => end_to_end::fig13_ftf(&scale),
        "fig14" | "fig14b" => {
            let counts = [250, 500, 1000, 2048];
            match args.get("checkpoint") {
                Some(path) => {
                    let mut ckpt = Checkpoint::load_or_new(path);
                    scalability::fig14b_breakdown_checkpointed(&counts, Some(&mut ckpt))
                }
                None => scalability::fig14b_breakdown(&counts),
            }
        }
        "scale" => {
            let mut opts = if args.flag("quick") {
                scalability::ScaleSweepOpts::quick()
            } else {
                scalability::ScaleSweepOpts::paper()
            };
            opts.budget =
                std::time::Duration::from_secs(args.get_u64("budget-secs", opts.budget.as_secs()));
            if args.flag("no-quality") {
                opts.quality = false;
            }
            match args.get("checkpoint") {
                Some(path) => {
                    let mut ckpt = Checkpoint::load_or_new(path);
                    scalability::scale_sweep(&opts, Some(&mut ckpt))
                }
                None => scalability::scale_sweep(&opts, None),
            }
        }
        "fig15" => ablations::fig15_strategy_impact(&scale),
        "fig16" => ablations::fig16_noise_sensitivity(&scale, &[0.0, 0.2, 0.4, 0.6, 0.8, 1.0]),
        "fig17" => end_to_end::fig17_gavel_trace(&scale),
        "fig18" => ablations::fig18_estimators(&scale),
        "faults" => experiments::faults::fault_matrix(&scale),
        "table2" => end_to_end::table2_fidelity(
            args.get_usize("reps", 3),
            args.get_f64("round-secs", 0.5),
        )?,
        other => anyhow::bail!("unknown figure '{other}'"),
    };
    println!("{report}");
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("jobs", 6);
    let jobs: Vec<ExecJob> = (0..n as u64)
        .map(|i| ExecJob {
            id: i + 1,
            model: if i % 3 == 0 { "gpt-micro" } else { "gpt-nano" }.into(),
            num_gpus: if i % 4 == 2 { 2 } else { 1 },
            arrival_round: i / 2,
            total_steps: 40 + 15 * i,
        })
        .collect();
    let cfg = ExecConfig {
        num_nodes: args.get_usize("nodes", 2),
        gpus_per_node: args.get_usize("gpus-per-node", 2),
        round_wall_s: args.get_f64("round-secs", 1.0),
        ..Default::default()
    };
    let r = run_cluster(&jobs, &cfg)?;
    println!(
        "rounds={} migrations={} ckpt={}B/{:.3}s wall={:.1}s avg JCT={:.1} rounds",
        r.rounds,
        r.total_migrations,
        r.checkpoint_bytes,
        r.checkpoint_time_s,
        r.wall_s,
        r.avg_jct_rounds
    );
    for (id, j) in &r.jobs {
        println!(
            "  job {id} ({}): steps={} JCT={} rounds, migrations={}, loss {:.3} -> {:.3}",
            j.model, j.steps, j.jct_rounds, j.migrations, j.first_loss, j.last_loss
        );
    }
    Ok(())
}

fn cmd_engines(args: &Args) -> anyhow::Result<()> {
    let sizes: Vec<usize> = args
        .get_str("sizes", "8,16,32,64")
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let report = scalability::matching_engine_comparison(&sizes, !args.flag("no-aot"));
    println!("{report}");
    Ok(())
}

//! Batched matching instances: content signatures, closed-form pruning and
//! the per-round [`Batch`] collector behind [`super::service`].
//!
//! Every Algorithm 3 node-pair instance is identified by the *content* of
//! the (previous, next) node pair it prices: which jobs sit on each GPU
//! slot and each job's amortization divisor. Two pairs with equal content
//! produce bit-identical cost matrices, so content keys are what the
//! service dedups within a round and caches across rounds. Keys compare by
//! full equality (the hash only routes the lookup), so distinct instances
//! can never collide.

use std::collections::HashMap;
use std::sync::Arc;

use crate::cluster::PlacementPlan;
use crate::jobs::JobId;
use crate::linalg::Matrix;

/// Content of one GPU slot: each tenant job with its amortization divisor
/// (the job's cluster-wide GPU count), in slot order.
pub type GpuSig = Vec<(JobId, usize)>;

/// Content of one node: its GPUs' slot signatures in topology order. Equal
/// signatures ⇒ bit-identical Algorithm 3 cost matrices.
pub type NodeSig = Vec<GpuSig>;

/// A matching instance's identity: the solving engine (name *and*
/// configuration fingerprint) plus the (prev, next) node-pair content it
/// was built from. The engine identity is part of the key because engines
/// — and differently-configured instances of the same engine, e.g.
/// auctions at different resolutions — legitimately return *different*
/// optimal permutations; one service must never serve one solver's cached
/// assignment to another. The node signatures are `Arc`-shared (hash/eq
/// delegate to the content) so probing a cache of `n²` pairs costs `n`
/// signature allocations per round, not `n²`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PairKey {
    pub engine: &'static str,
    pub engine_cfg: u64,
    pub prev: Arc<NodeSig>,
    pub next: Arc<NodeSig>,
}

/// Amortization divisor for `job`: its GPU count, read preferentially from
/// the previous round's plan — exactly the `prev_map.or(next_map)` lookup
/// order the pre-service `gpu_pair_cost` used, so signature-built matrices
/// are bit-identical to the ones the old code built in place.
fn job_size(job: JobId, prev: &PlacementPlan, next: &PlacementPlan) -> usize {
    let p = prev.gpus_of(job).len();
    if p > 0 {
        p
    } else {
        next.gpus_of(job).len().max(1)
    }
}

/// Build one node's signature over `gpus` of `plan`, sizing every tenant
/// against both rounds' plans (see [`job_size`]).
pub fn node_sig(
    plan: &PlacementPlan,
    gpus: &[usize],
    prev: &PlacementPlan,
    next: &PlacementPlan,
) -> NodeSig {
    gpus.iter()
        .map(|&g| {
            plan.jobs_on(g)
                .iter()
                .map(|&j| (j, job_size(j, prev, next)))
                .collect()
        })
        .collect()
}

/// Migration cost between two GPU-slot signatures (Algorithm 3 lines 4–7):
/// every job in the symmetric difference contributes `1/(2·num_gpus)`.
/// Same iteration and addition order as the pre-service `gpu_pair_cost`,
/// hence bit-identical entries.
fn sig_pair_cost(u: &GpuSig, v: &GpuSig) -> f64 {
    let mut cost = 0.0;
    for &(j, sz) in u {
        if !v.iter().any(|&(jv, _)| jv == j) {
            cost += 1.0 / (2.0 * sz as f64);
        }
    }
    for &(j, sz) in v {
        if !u.iter().any(|&(ju, _)| ju == j) {
            cost += 1.0 / (2.0 * sz as f64);
        }
    }
    cost
}

/// The full Algorithm 3 cost matrix for a (prev, next) node pair — a pure
/// function of the pair's content signatures.
pub fn pair_cost_matrix(prev: &NodeSig, next: &NodeSig) -> Matrix {
    let mut c = Matrix::zeros(prev.len(), next.len());
    for (a, u) in prev.iter().enumerate() {
        for (b, v) in next.iter().enumerate() {
            c.set(a, b, sig_pair_cost(u, v));
        }
    }
    c
}

/// Whether a node hosts no jobs at all.
pub fn sig_is_empty(sig: &NodeSig) -> bool {
    sig.iter().all(|s| s.is_empty())
}

/// Whether a node's content admits the closed-form one-sided total while
/// preserving bit-parity with an engine solve. Two conditions, both on the
/// divisors `k` (job GPU counts):
///
/// * `k` is a power of two, so every contribution `1/(2k)` is an exact
///   dyadic f64 and sums of them are exact — i.e. independent of the
///   summation order, which is what lets a column-order closed form equal
///   a solver's permutation-order total bit for bit;
/// * `k ≤ 8`, so every matrix entry is a multiple of 1/16 — the native
///   auction engine's default exactness resolution. An exact engine
///   (Hungarian, or the auction on its grid) then returns exactly the
///   optimal total the closed form computes.
pub fn sig_is_exact_prunable(sig: &NodeSig) -> bool {
    sig.iter()
        .flatten()
        .all(|&(_, sz)| sz.is_power_of_two() && sz <= 8)
}

/// Closed-form optimal matching cost of one all-empty node against `sig`
/// (either orientation): the cost matrix is constant along the empty side,
/// so every permutation is optimal and the total is the sum of all of
/// `sig`'s tenant contributions. Caller must have checked
/// [`sig_is_exact_prunable`].
pub fn one_sided_cost(sig: &NodeSig) -> f64 {
    let mut total = 0.0;
    for s in sig {
        for &(_, sz) in s {
            total += 1.0 / (2.0 * sz as f64);
        }
    }
    total
}

/// A round's collected matching instances after prune/cache filtering: the
/// unique cost matrices still needing an engine solve, each with the
/// content key (when known) under which its solution should be cached.
#[derive(Debug, Default)]
pub struct Batch {
    matrices: Vec<Matrix>,
    keys: Vec<Option<PairKey>>,
    index_of: HashMap<PairKey, usize>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.matrices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.matrices.is_empty()
    }

    pub fn matrices(&self) -> &[Matrix] {
        &self.matrices
    }

    pub fn keys(&self) -> &[Option<PairKey>] {
        &self.keys
    }

    /// Add an instance by content key, building its matrix only if the key
    /// is new. Returns `(slot, was_duplicate)`.
    pub fn push_keyed(&mut self, key: PairKey, dedup: bool) -> (usize, bool) {
        if dedup {
            if let Some(&i) = self.index_of.get(&key) {
                return (i, true);
            }
        }
        let i = self.matrices.len();
        self.matrices.push(pair_cost_matrix(&key.prev, &key.next));
        if dedup {
            self.index_of.insert(key.clone(), i);
        }
        self.keys.push(Some(key));
        (i, false)
    }

    /// Add a raw matrix with no content identity (no dedup, no caching).
    pub fn push_matrix(&mut self, matrix: Matrix) -> usize {
        let i = self.matrices.len();
        self.matrices.push(matrix);
        self.keys.push(None);
        i
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::hungarian;

    fn sig(slots: &[&[(JobId, usize)]]) -> NodeSig {
        slots.iter().map(|s| s.to_vec()).collect()
    }

    #[test]
    fn pair_cost_matrix_prices_symmetric_difference() {
        // prev node: job 1 on slot 0, empty slot 1.
        // next node: job 1 on slot 1, job 2 on slot 0.
        let prev = sig(&[&[(1, 1)], &[]]);
        let next = sig(&[&[(2, 1)], &[(1, 1)]]);
        let c = pair_cost_matrix(&prev, &next);
        // (slot0, slot0): job1 leaves (1/2), job2 arrives (1/2) = 1.0
        assert_eq!(c.get(0, 0), 1.0);
        // (slot0, slot1): job1 stays = 0.0
        assert_eq!(c.get(0, 1), 0.0);
        // (slot1, slot0): job2 arrives = 0.5
        assert_eq!(c.get(1, 0), 0.5);
        // (slot1, slot1): job1 arrives = 0.5
        assert_eq!(c.get(1, 1), 0.5);
    }

    #[test]
    fn multi_gpu_divisors_amortize() {
        // A 4-GPU job contributes 1/8 per differing slot.
        let prev = sig(&[&[(7, 4)]]);
        let next = sig(&[&[]]);
        let c = pair_cost_matrix(&prev, &next);
        assert_eq!(c.get(0, 0), 0.125);
    }

    #[test]
    fn emptiness_and_prunability() {
        assert!(sig_is_empty(&sig(&[&[], &[]])));
        assert!(!sig_is_empty(&sig(&[&[], &[(1, 1)]])));
        assert!(sig_is_exact_prunable(&sig(&[&[(1, 1)], &[(2, 8)]])));
        assert!(!sig_is_exact_prunable(&sig(&[&[(1, 3)]])), "1/6 not dyadic");
        assert!(!sig_is_exact_prunable(&sig(&[&[(1, 16)]])), "1/32 off-grid");
    }

    #[test]
    fn one_sided_cost_matches_solver_total() {
        // Empty × nonempty: the closed form must equal the Hungarian total
        // on the actual matrix, bit for bit (dyadic divisors).
        let empty = sig(&[&[], &[], &[], &[]]);
        let busy = sig(&[&[(1, 1), (2, 2)], &[(3, 8)], &[], &[(4, 4), (5, 1)]]);
        assert!(sig_is_exact_prunable(&busy));
        let c = pair_cost_matrix(&empty, &busy);
        let solved = hungarian::solve_min_cost(&c);
        assert_eq!(one_sided_cost(&busy).to_bits(), solved.cost.to_bits());
        // And in the transposed orientation.
        let ct = pair_cost_matrix(&busy, &empty);
        let solved_t = hungarian::solve_min_cost(&ct);
        assert_eq!(one_sided_cost(&busy).to_bits(), solved_t.cost.to_bits());
    }

    fn key(engine: &'static str, prev: NodeSig, next: NodeSig) -> PairKey {
        PairKey {
            engine,
            engine_cfg: 0,
            prev: Arc::new(prev),
            next: Arc::new(next),
        }
    }

    #[test]
    fn batch_dedups_by_content() {
        let a = key("hungarian", sig(&[&[(1, 1)]]), sig(&[&[(2, 1)]]));
        let b = a.clone();
        let c = key("hungarian", sig(&[&[(1, 1)]]), sig(&[&[(3, 1)]]));
        let mut batch = Batch::default();
        let (s0, d0) = batch.push_keyed(a, true);
        let (s1, d1) = batch.push_keyed(b, true);
        let (s2, d2) = batch.push_keyed(c, true);
        assert_eq!((s0, d0), (0, false));
        assert_eq!((s1, d1), (0, true));
        assert_eq!((s2, d2), (1, false));
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn batch_without_dedup_keeps_duplicates() {
        let a = key("hungarian", sig(&[&[(1, 1)]]), sig(&[&[(2, 1)]]));
        let mut batch = Batch::default();
        batch.push_keyed(a.clone(), false);
        batch.push_keyed(a, false);
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn keys_equal_by_content_and_distinguish_engines() {
        let a = key("hungarian", sig(&[&[(1, 1)]]), sig(&[]));
        // Same content behind fresh allocations: equal + same hash bucket.
        let b = key("hungarian", sig(&[&[(1, 1)]]), sig(&[]));
        assert_eq!(a, b);
        // Same content, different engine: distinct (engines may return
        // different optimal permutations on degenerate matrices).
        let c = key("auction", sig(&[&[(1, 1)]]), sig(&[]));
        assert_ne!(a, c);
        // Same engine name, different configuration: also distinct.
        let d = PairKey {
            engine_cfg: 7,
            ..b.clone()
        };
        assert_ne!(b, d);
        let mut m = HashMap::new();
        m.insert(a, 1);
        assert!(m.contains_key(&b));
        assert!(!m.contains_key(&c));
        assert!(!m.contains_key(&d));
    }
}

//! Bertsekas auction algorithm for the assignment problem.
//!
//! The auction algorithm is the data-parallel dual of the Hungarian method:
//! each unassigned "person" (row) bids for its best "object" (column) using
//! only a per-row top-2 scan of the benefit matrix — exactly the shape of
//! the L1 Pallas `top2` kernel. The native Rust implementation here serves
//! as (a) an independent oracle for the AOT JAX/Pallas artifact and (b) a
//! fast approximate engine for very large matching problems.
//!
//! With ε-scaling the final assignment is within `n·ε` of optimal; when all
//! benefits are integer multiples of some resolution `q` and the final
//! ε < q/n, the assignment is exactly optimal (Bertsekas 1988). Migration
//! costs in this codebase are multiples of 1/16, so exactness is achievable.

use crate::linalg::Matrix;

use super::hungarian::AssignmentResult;

/// Configuration for the ε-scaling auction.
#[derive(Debug, Clone)]
pub struct AuctionConfig {
    /// Starting ε as a fraction of the benefit range.
    pub eps_start_frac: f64,
    /// ε divisor between scaling phases.
    pub scale: f64,
    /// Final ε. For exact results on costs with resolution q use q/(n+1).
    pub eps_final: f64,
    /// Safety cap on bidding iterations per phase.
    pub max_rounds: usize,
}

impl Default for AuctionConfig {
    fn default() -> Self {
        AuctionConfig {
            eps_start_frac: 0.25,
            scale: 4.0,
            eps_final: 1e-4,
            max_rounds: 1_000_000,
        }
    }
}

/// Solve max-benefit assignment by forward auction with ε-scaling.
/// Returns row→col assignment and the *benefit* total (not cost).
pub fn solve_max_benefit(benefit: &Matrix, cfg: &AuctionConfig) -> AssignmentResult {
    solve_max_benefit_warm(benefit, cfg, None).0
}

/// [`solve_max_benefit`] with optional warm-start prices (retained duals
/// from a previous similar instance) threaded in, and the final prices
/// returned so the caller can retain them. Forward auction maintains ε-CS
/// from *any* initial prices, so the optimality guarantee is unchanged —
/// but a warm start may select a different, equally-optimal assignment.
/// With `init_prices = None` results are identical to [`solve_max_benefit`].
pub fn solve_max_benefit_warm(
    benefit: &Matrix,
    cfg: &AuctionConfig,
    init_prices: Option<&[f64]>,
) -> (AssignmentResult, Vec<f64>) {
    let n = benefit.rows();
    assert_eq!(n, benefit.cols(), "auction needs a square matrix");
    let mut prices = match init_prices {
        Some(p) if p.len() == n => p.to_vec(),
        _ => vec![0.0f64; n],
    };
    if n == 0 {
        return (
            AssignmentResult {
                row_to_col: vec![],
                cost: 0.0,
            },
            prices,
        );
    }
    if n == 1 {
        return (
            AssignmentResult {
                row_to_col: vec![0],
                cost: benefit.get(0, 0),
            },
            prices,
        );
    }

    let bmax = benefit.data().iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let bmin = benefit.data().iter().cloned().fold(f64::INFINITY, f64::min);
    let range = (bmax - bmin).max(1e-12);

    let mut row_of: Vec<Option<usize>> = vec![None; n]; // object -> person
    let mut col_of: Vec<Option<usize>> = vec![None; n]; // person -> object

    let mut eps = (range * cfg.eps_start_frac).max(cfg.eps_final);
    loop {
        // Each scaling phase restarts the assignment but keeps prices
        // (standard ε-scaling).
        row_of.iter_mut().for_each(|x| *x = None);
        col_of.iter_mut().for_each(|x| *x = None);
        let mut unassigned: Vec<usize> = (0..n).collect();
        let mut rounds = 0usize;
        while let Some(person) = unassigned.pop() {
            rounds += 1;
            assert!(
                rounds <= cfg.max_rounds,
                "auction exceeded {} rounds (eps={eps})",
                cfg.max_rounds
            );
            // Top-2 scan of value = benefit - price (the L1 kernel's job).
            let row = benefit.row(person);
            let mut best_j = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            let mut second_v = f64::NEG_INFINITY;
            for (j, (&b, &p)) in row.iter().zip(&prices).enumerate() {
                let v = b - p;
                if v > best_v {
                    second_v = best_v;
                    best_v = v;
                    best_j = j;
                } else if v > second_v {
                    second_v = v;
                }
            }
            if second_v == f64::NEG_INFINITY {
                second_v = best_v;
            }
            // Bid raises the price by the value margin plus ε.
            prices[best_j] += best_v - second_v + eps;
            if let Some(evicted) = row_of[best_j].replace(person) {
                col_of[evicted] = None;
                unassigned.push(evicted);
            }
            col_of[person] = Some(best_j);
        }
        if eps <= cfg.eps_final {
            break;
        }
        eps = (eps / cfg.scale).max(cfg.eps_final);
    }

    let row_to_col: Vec<usize> = col_of.into_iter().map(|c| c.unwrap()).collect();
    let total = row_to_col
        .iter()
        .enumerate()
        .map(|(r, &c)| benefit.get(r, c))
        .sum();
    (
        AssignmentResult {
            row_to_col,
            cost: total,
        },
        prices,
    )
}

/// Solve min-cost assignment via the auction on negated costs. `resolution`
/// (when known, e.g. 1/16 for migration costs) drives ε_final for exactness;
/// pass `None` for near-optimal on arbitrary float costs.
pub fn solve_min_cost(cost: &Matrix, resolution: Option<f64>) -> AssignmentResult {
    solve_min_cost_warm(cost, resolution, None).0
}

/// [`solve_min_cost`] with warm-start prices threaded through. The prices
/// are duals of the negated-benefit problem — opaque to callers, who only
/// round-trip them between solves of the same recurring instance shape.
pub fn solve_min_cost_warm(
    cost: &Matrix,
    resolution: Option<f64>,
    init_prices: Option<&[f64]>,
) -> (AssignmentResult, Vec<f64>) {
    let n = cost.rows();
    let mut benefit = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            benefit.set(i, j, -cost.get(i, j));
        }
    }
    let mut cfg = AuctionConfig::default();
    if let Some(q) = resolution {
        cfg.eps_final = q / (n as f64 + 1.0);
    }
    let (r, prices) = solve_max_benefit_warm(&benefit, &cfg, init_prices);
    let total = r
        .row_to_col
        .iter()
        .enumerate()
        .map(|(row, &c)| cost.get(row, c))
        .sum();
    (
        AssignmentResult {
            row_to_col: r.row_to_col,
            cost: total,
        },
        prices,
    )
}

/// Reusable buffers for [`solve_min_cost_fill`]: prices, the two
/// assignment maps and the unassigned stack, allocated once per worker
/// arena instead of once per solve.
#[derive(Debug, Default)]
pub struct AuctionScratch {
    prices: Vec<f64>,
    row_of: Vec<usize>,
    col_of: Vec<usize>,
    unassigned: Vec<usize>,
}

/// Sentinel for "no person / no object" in the scratch maps.
const NONE: usize = usize::MAX;

/// Allocation-free [`solve_min_cost`]: the same ε-scaling forward auction
/// with every working vector living in `scratch` and the benefit negation
/// (`b = −c`, exact in floating point) folded into the bidding scan
/// instead of materializing a negated matrix. Results are bit-identical to
/// [`solve_min_cost`]. Writes the assignment (row → col) into `out` and
/// returns the total cost.
pub fn solve_min_cost_fill(
    cost: &Matrix,
    resolution: Option<f64>,
    scratch: &mut AuctionScratch,
    out: &mut Vec<usize>,
) -> f64 {
    let n = cost.rows();
    assert_eq!(n, cost.cols(), "auction needs a square matrix");
    out.clear();
    if n == 0 {
        return 0.0;
    }
    if n == 1 {
        out.push(0);
        return cost.get(0, 0);
    }

    let mut cfg = AuctionConfig::default();
    if let Some(q) = resolution {
        cfg.eps_final = q / (n as f64 + 1.0);
    }

    let AuctionScratch {
        prices,
        row_of,
        col_of,
        unassigned,
    } = scratch;
    prices.clear();
    prices.resize(n, 0.0);
    row_of.clear();
    row_of.resize(n, NONE);
    col_of.clear();
    col_of.resize(n, NONE);

    let bmax = cost.data().iter().map(|&c| -c).fold(f64::NEG_INFINITY, f64::max);
    let bmin = cost.data().iter().map(|&c| -c).fold(f64::INFINITY, f64::min);
    let range = (bmax - bmin).max(1e-12);

    let mut eps = (range * cfg.eps_start_frac).max(cfg.eps_final);
    loop {
        row_of.iter_mut().for_each(|x| *x = NONE);
        col_of.iter_mut().for_each(|x| *x = NONE);
        unassigned.clear();
        unassigned.extend(0..n);
        let mut rounds = 0usize;
        while let Some(person) = unassigned.pop() {
            rounds += 1;
            assert!(
                rounds <= cfg.max_rounds,
                "auction exceeded {} rounds (eps={eps})",
                cfg.max_rounds
            );
            let row = cost.row(person);
            let mut best_j = 0usize;
            let mut best_v = f64::NEG_INFINITY;
            let mut second_v = f64::NEG_INFINITY;
            for (j, (&c, &p)) in row.iter().zip(prices.iter()).enumerate() {
                let v = -c - p;
                if v > best_v {
                    second_v = best_v;
                    best_v = v;
                    best_j = j;
                } else if v > second_v {
                    second_v = v;
                }
            }
            if second_v == f64::NEG_INFINITY {
                second_v = best_v;
            }
            prices[best_j] += best_v - second_v + eps;
            let evicted = row_of[best_j];
            row_of[best_j] = person;
            if evicted != NONE {
                col_of[evicted] = NONE;
                unassigned.push(evicted);
            }
            col_of[person] = best_j;
        }
        if eps <= cfg.eps_final {
            break;
        }
        eps = (eps / cfg.scale).max(cfg.eps_final);
    }

    out.extend(col_of.iter().copied());
    out.iter().enumerate().map(|(r, &c)| cost.get(r, c)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::hungarian;
    use crate::util::prop::{approx_eq, forall};

    #[test]
    fn matches_hungarian_on_integer_costs() {
        forall(
            "auction == hungarian (integer costs)",
            41,
            100,
            |r| {
                let n = 1 + r.below(10) as usize;
                let mut m = Matrix::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        m.set(i, j, r.below(20) as f64);
                    }
                }
                m
            },
            |cost| {
                let exact = hungarian::solve_min_cost(cost);
                let auc = solve_min_cost(cost, Some(1.0));
                approx_eq(auc.cost, exact.cost, 1e-9)
            },
        );
    }

    #[test]
    fn matches_hungarian_on_migration_resolution() {
        // Costs are multiples of 1/16 like Algorithm 3's outputs.
        forall(
            "auction exact at 1/16 resolution",
            43,
            60,
            |r| {
                let n = 2 + r.below(8) as usize;
                let mut m = Matrix::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        m.set(i, j, r.below(33) as f64 / 16.0);
                    }
                }
                m
            },
            |cost| {
                let exact = hungarian::solve_min_cost(cost);
                let auc = solve_min_cost(cost, Some(1.0 / 16.0));
                approx_eq(auc.cost, exact.cost, 1e-9)
            },
        );
    }

    #[test]
    fn near_optimal_on_float_costs() {
        forall(
            "auction near-optimal (floats)",
            47,
            50,
            |r| {
                let n = 2 + r.below(10) as usize;
                let mut m = Matrix::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        m.set(i, j, r.range_f64(0.0, 10.0));
                    }
                }
                m
            },
            |cost| {
                let exact = hungarian::solve_min_cost(cost);
                let auc = solve_min_cost(cost, None);
                let slack = (cost.rows() as f64 + 1.0) * 1e-4;
                if auc.cost <= exact.cost + slack {
                    Ok(())
                } else {
                    Err(format!("auction {} vs exact {}", auc.cost, exact.cost))
                }
            },
        );
    }

    #[test]
    fn assignment_is_permutation() {
        let mut rng = crate::util::rng::Pcg64::new(8);
        let n = 64;
        let m = Matrix::random(n, n, &mut rng);
        let r = solve_max_benefit(&m, &AuctionConfig::default());
        let mut seen = vec![false; n];
        for &c in &r.row_to_col {
            assert!(!seen[c]);
            seen[c] = true;
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(solve_min_cost(&Matrix::zeros(0, 0), None).cost, 0.0);
        let one = Matrix::from_rows(&[&[2.0]]);
        assert_eq!(solve_min_cost(&one, None).row_to_col, vec![0]);
    }

    #[test]
    fn warm_start_none_is_bit_identical_to_cold() {
        let mut rng = crate::util::rng::Pcg64::new(91);
        for _ in 0..20 {
            let n = 2 + rng.below(8) as usize;
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m.set(i, j, rng.below(33) as f64 / 16.0);
                }
            }
            let cold = solve_min_cost(&m, Some(1.0 / 16.0));
            let (warm, _) = solve_min_cost_warm(&m, Some(1.0 / 16.0), None);
            assert_eq!(cold.row_to_col, warm.row_to_col);
            assert_eq!(cold.cost.to_bits(), warm.cost.to_bits());
        }
    }

    #[test]
    fn scratch_fill_is_bit_identical_to_cold() {
        // The arena path folds the cost negation into the scan; every
        // float op matches the materialized-matrix path, so the outputs
        // must agree bit for bit — including across arena reuse.
        let mut rng = crate::util::rng::Pcg64::new(17);
        let mut scratch = AuctionScratch::default();
        let mut out = Vec::new();
        for _ in 0..30 {
            let n = 1 + rng.below(10) as usize;
            let mut m = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    m.set(i, j, rng.below(33) as f64 / 16.0);
                }
            }
            let cold = solve_min_cost(&m, Some(1.0 / 16.0));
            let total = solve_min_cost_fill(&m, Some(1.0 / 16.0), &mut scratch, &mut out);
            assert_eq!(cold.row_to_col, out);
            assert_eq!(cold.cost.to_bits(), total.to_bits());
        }
    }

    #[test]
    fn warm_started_solve_stays_optimal() {
        // ε-CS holds from any initial prices, so a solve warm-started with
        // the duals of a *different* instance must still be exactly optimal
        // on quantized costs (though possibly via a different argmin).
        forall(
            "warm-started auction optimal",
            49,
            40,
            |r| {
                let n = 2 + r.below(8) as usize;
                let mut a = Matrix::zeros(n, n);
                let mut b = Matrix::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        a.set(i, j, r.below(33) as f64 / 16.0);
                        // b perturbs a on a few entries — the cross-round
                        // cost-matrix drift the service's warm starts see.
                        let drift = if r.below(4) == 0 {
                            r.below(8) as f64 / 16.0
                        } else {
                            0.0
                        };
                        b.set(i, j, a.get(i, j) + drift);
                    }
                }
                (a, b)
            },
            |(a, b)| {
                let (_, prices) = solve_min_cost_warm(a, Some(1.0 / 16.0), None);
                let (warm, _) = solve_min_cost_warm(b, Some(1.0 / 16.0), Some(&prices));
                let exact = hungarian::solve_min_cost(b);
                approx_eq(warm.cost, exact.cost, 1e-9)
            },
        );
    }
}

//! Graph-matching engines — the paper's core insight is that placement
//! constraints reduce to weighted bipartite matching (§4). This module
//! exposes:
//!
//! * [`hungarian`] — exact O(n³) min-cost assignment (default engine),
//! * [`auction`] — Bertsekas auction (the algorithm the AOT JAX/Pallas
//!   artifact implements; also available natively),
//! * [`max_weight_matching`] — the partial max-weight bipartite matching
//!   shape of the packing policy (Algorithm 4),
//! * [`MatchingEngine`] — a pluggable solver trait so the scheduler can run
//!   on the native solvers or the PJRT-loaded artifact interchangeably.

pub mod auction;
pub mod hungarian;

pub use hungarian::{AssignmentResult, FORBIDDEN};

use crate::linalg::Matrix;

/// A pluggable assignment solver. Implemented by the native Hungarian and
/// auction engines here and by `runtime::AotAssignmentEngine` (the
/// JAX/Pallas artifact executed via PJRT).
pub trait MatchingEngine: Send + Sync {
    /// Solve square min-cost assignment.
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult;

    /// Solve rectangular min-cost assignment (rows ≤ cols; every row gets a
    /// distinct column). Default: pad to square with zero-cost dummy rows —
    /// engines with a native rectangular path (Hungarian) override this.
    fn solve_min_cost_rect(&self, cost: &Matrix) -> AssignmentResult {
        let (n, m) = (cost.rows(), cost.cols());
        assert!(n <= m, "rect assignment needs rows <= cols");
        if n == m {
            return self.solve_min_cost(cost);
        }
        let mut sq = Matrix::zeros(m, m);
        for r in 0..n {
            for c in 0..m {
                sq.set(r, c, cost.get(r, c));
            }
        }
        let sol = self.solve_min_cost(&sq);
        let row_to_col = sol.row_to_col[..n].to_vec();
        let total = row_to_col
            .iter()
            .enumerate()
            .map(|(r, &c)| cost.get(r, c))
            .sum();
        AssignmentResult {
            row_to_col,
            cost: total,
        }
    }

    fn name(&self) -> &'static str;
}

/// Exact Hungarian engine (default).
#[derive(Debug, Default, Clone)]
pub struct HungarianEngine;

impl MatchingEngine for HungarianEngine {
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult {
        hungarian::solve_min_cost(cost)
    }

    fn solve_min_cost_rect(&self, cost: &Matrix) -> AssignmentResult {
        hungarian::solve_min_cost_rect(cost)
    }

    fn name(&self) -> &'static str {
        "hungarian"
    }
}

/// Native auction engine. `resolution` enables exactness on quantized costs
/// (e.g. `Some(1/16)` for Algorithm 3 migration costs).
#[derive(Debug, Clone)]
pub struct AuctionEngine {
    pub resolution: Option<f64>,
}

impl Default for AuctionEngine {
    fn default() -> Self {
        AuctionEngine {
            resolution: Some(1.0 / 16.0),
        }
    }
}

impl MatchingEngine for AuctionEngine {
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult {
        auction::solve_min_cost(cost, self.resolution)
    }

    fn name(&self) -> &'static str {
        "auction"
    }
}

/// An edge in a bipartite packing graph: (left index, right index, weight).
pub type Edge = (usize, usize, f64);

/// A matched pair from [`max_weight_matching`].
#[derive(Debug, Clone, PartialEq)]
pub struct MatchedPair {
    pub left: usize,
    pub right: usize,
    pub weight: f64,
}

/// Maximum-weight bipartite matching where leaving a node unmatched is
/// allowed and only listed edges may be used (the Algorithm 4 problem):
/// choose a subset of `edges` forming a matching that maximizes total
/// weight. Weights must be finite; non-positive-weight edges are never
/// chosen (an unmatched pair is always at least as good).
///
/// Reduction: orient the graph so the smaller side is the rows, then solve
/// a rows × (cols + rows) *rectangular* min-cost assignment — real edges
/// cost −w, non-edges a problem-scaled forbidden cost, and `rows` dummy
/// columns at 0 allow any row to stay unmatched. O(rows²·cols) instead of
/// the O((rows+cols)³) square padding.
pub fn max_weight_matching(
    n_left: usize,
    n_right: usize,
    edges: &[Edge],
    engine: &dyn MatchingEngine,
) -> Vec<MatchedPair> {
    if n_left == 0 || n_right == 0 || edges.is_empty() {
        return vec![];
    }
    // Orient: rows = smaller side.
    let transpose = n_left > n_right;
    let (rows, cols) = if transpose {
        (n_right, n_left)
    } else {
        (n_left, n_right)
    };
    // Problem-scaled forbidden cost: large enough that no optimal solution
    // uses a non-edge, small enough to stay in f32 range for the AOT
    // auction engine (FORBIDDEN=1e12 would destroy its ε-scaling).
    let max_w = edges
        .iter()
        .map(|&(_, _, w)| w.abs())
        .fold(0.0f64, f64::max);
    let forbidden = (max_w + 1.0) * ((rows + cols) as f64 + 1.0);

    let width = cols + rows; // real columns + one dummy column per row
    let mut cost = Matrix::zeros(rows, width);
    for r in 0..rows {
        for c in 0..cols {
            cost.set(r, c, forbidden);
        }
    }
    for &(u, v, w) in edges {
        assert!(u < n_left && v < n_right, "edge ({u},{v}) out of range");
        assert!(w.is_finite(), "edge weight must be finite");
        let (r, c) = if transpose { (v, u) } else { (u, v) };
        // Keep the best weight on parallel edges.
        if -w < cost.get(r, c) {
            cost.set(r, c, -w);
        }
    }
    let solution = engine.solve_min_cost_rect(&cost);
    let mut out = Vec::new();
    for (r, &c) in solution.row_to_col.iter().enumerate() {
        if c < cols {
            let cell = cost.get(r, c);
            if cell < 0.0 {
                let (left, right) = if transpose { (c, r) } else { (r, c) };
                out.push(MatchedPair {
                    left,
                    right,
                    weight: -cell,
                });
            }
        }
    }
    out.sort_by_key(|p| (p.left, p.right));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{approx_eq, forall};

    fn total(pairs: &[MatchedPair]) -> f64 {
        pairs.iter().map(|p| p.weight).sum()
    }

    /// Exhaustive max-weight matching by subset enumeration (tests only).
    fn brute_force(n_left: usize, n_right: usize, edges: &[Edge]) -> f64 {
        let m = edges.len();
        assert!(m <= 16);
        let mut best = 0.0f64;
        'mask: for mask in 0u32..(1 << m) {
            let mut used_l = vec![false; n_left];
            let mut used_r = vec![false; n_right];
            let mut w = 0.0;
            for (k, &(u, v, ew)) in edges.iter().enumerate() {
                if mask & (1 << k) != 0 {
                    if used_l[u] || used_r[v] {
                        continue 'mask;
                    }
                    used_l[u] = true;
                    used_r[v] = true;
                    w += ew;
                }
            }
            best = best.max(w);
        }
        best
    }

    #[test]
    fn paper_figure7_example() {
        // Fig. 7(a): placed jobs {1,2,3} × pending jobs {4,5,6} with combined
        // normalized throughputs as edge weights; the matching picks the
        // maximum-total set.
        let edges = vec![
            (0, 0, 0.8), // job1-job4
            (0, 1, 1.2), // job1-job5
            (1, 1, 0.9), // job2-job5
            (1, 2, 1.1), // job2-job6
            (2, 2, 1.3), // job3-job6
        ];
        let m = max_weight_matching(3, 3, &edges, &HungarianEngine);
        let got = total(&m);
        assert!((got - brute_force(3, 3, &edges)).abs() < 1e-9);
        // job1-job4 (0.8) + job2-job5 (0.9) + job3-job6 (1.3) = 3.0 beats the
        // greedy pick of the single heaviest edges (1.2 + 1.3 = 2.5).
        assert!((got - 3.0).abs() < 1e-9, "total {got}");
    }

    #[test]
    fn parallelism_strategy_changes_matching() {
        // Fig. 7(b): boosting edge (job1, job5) from 1.2 to 1.5 by picking a
        // better parallelism strategy must keep/strengthen that edge.
        let edges = vec![(0, 1, 1.5), (1, 1, 0.9), (1, 2, 1.1), (2, 2, 1.3)];
        let m = max_weight_matching(3, 3, &edges, &HungarianEngine);
        assert!(m.iter().any(|p| p.left == 0 && p.right == 1 && p.weight == 1.5));
    }

    #[test]
    fn unmatched_better_than_negative_weight() {
        let edges = vec![(0, 0, -1.0)];
        let m = max_weight_matching(1, 1, &edges, &HungarianEngine);
        assert!(m.is_empty());
    }

    #[test]
    fn empty_inputs() {
        assert!(max_weight_matching(0, 5, &[], &HungarianEngine).is_empty());
        assert!(max_weight_matching(3, 3, &[], &HungarianEngine).is_empty());
    }

    #[test]
    fn matches_brute_force_property() {
        forall(
            "max-weight matching == brute force",
            53,
            120,
            |r| {
                let n_left = 1 + r.below(4) as usize;
                let n_right = 1 + r.below(4) as usize;
                let max_edges = (n_left * n_right).min(10);
                let m = 1 + r.below(max_edges as u64) as usize;
                let edges: Vec<Edge> = (0..m)
                    .map(|_| {
                        (
                            r.below(n_left as u64) as usize,
                            r.below(n_right as u64) as usize,
                            r.range_f64(0.1, 2.0),
                        )
                    })
                    .collect();
                (n_left, n_right, edges)
            },
            |(nl, nr, edges)| {
                let fast = total(&max_weight_matching(*nl, *nr, edges, &HungarianEngine));
                let slow = brute_force(*nl, *nr, edges);
                approx_eq(fast, slow, 1e-9)
            },
        );
    }

    #[test]
    fn engines_agree_on_packing_graphs() {
        forall(
            "hungarian vs auction on packing graphs",
            59,
            40,
            |r| {
                let n = 2 + r.below(6) as usize;
                let m = 1 + r.below((n * n).min(12) as u64) as usize;
                let edges: Vec<Edge> = (0..m)
                    .map(|_| {
                        (
                            r.below(n as u64) as usize,
                            r.below(n as u64) as usize,
                            // Quantized weights so the auction is exact.
                            r.below(32) as f64 / 16.0,
                        )
                    })
                    .collect();
                (n, edges)
            },
            |(n, edges)| {
                let h = total(&max_weight_matching(*n, *n, edges, &HungarianEngine));
                let a = total(&max_weight_matching(
                    *n,
                    *n,
                    edges,
                    &AuctionEngine {
                        resolution: Some(1.0 / 16.0),
                    },
                ));
                approx_eq(h, a, 1e-6)
            },
        );
    }

    #[test]
    fn result_is_a_matching() {
        forall(
            "output is a valid matching",
            61,
            60,
            |r| {
                let nl = 1 + r.below(8) as usize;
                let nr = 1 + r.below(8) as usize;
                let m = 1 + r.below(16) as usize;
                let edges: Vec<Edge> = (0..m)
                    .map(|_| {
                        (
                            r.below(nl as u64) as usize,
                            r.below(nr as u64) as usize,
                            r.range_f64(0.0, 3.0),
                        )
                    })
                    .collect();
                (nl, nr, edges)
            },
            |(nl, nr, edges)| {
                let pairs = max_weight_matching(*nl, *nr, edges, &HungarianEngine);
                let mut seen_l = vec![false; *nl];
                let mut seen_r = vec![false; *nr];
                for p in &pairs {
                    if seen_l[p.left] || seen_r[p.right] {
                        return Err("node matched twice".into());
                    }
                    seen_l[p.left] = true;
                    seen_r[p.right] = true;
                    if !edges
                        .iter()
                        .any(|&(u, v, w)| u == p.left && v == p.right && (w - p.weight).abs() < 1e-12)
                    {
                        return Err("pair not an input edge".into());
                    }
                }
                Ok(())
            },
        );
    }
}

//! Graph-matching engines — the paper's core insight is that placement
//! constraints reduce to weighted bipartite matching (§4). This module
//! exposes:
//!
//! * [`hungarian`] — exact O(n³) min-cost assignment (default engine),
//! * [`auction`] — Bertsekas auction (the algorithm the AOT JAX/Pallas
//!   artifact implements; also available natively),
//! * [`max_weight_matching`] — the partial max-weight bipartite matching
//!   shape of the packing policy (Algorithm 4),
//! * [`MatchingEngine`] — a pluggable solver trait so the scheduler can run
//!   on the native solvers or the PJRT-loaded artifact interchangeably,
//! * [`batch`] / [`service`] — the batched matching service: content-keyed
//!   pruning, dedup and cross-round caching plus parallel batch solving
//!   for every matching instance a scheduling round generates.

pub mod auction;
pub mod batch;
pub mod hungarian;
pub mod service;

pub use batch::{node_sig, pair_cost_matrix, GpuSig, NodeSig, PairKey};
pub use hungarian::{AssignmentResult, SolveScratch, FORBIDDEN};
pub use service::{MatchingService, MatchingServiceStats, NodePairRound, ServiceConfig};

use crate::linalg::Matrix;

/// A pluggable assignment solver. Implemented by the native Hungarian and
/// auction engines here and by `runtime::AotAssignmentEngine` (the
/// JAX/Pallas artifact executed via PJRT).
pub trait MatchingEngine: Send + Sync {
    /// Solve square min-cost assignment.
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult;

    /// Solve rectangular min-cost assignment (rows ≤ cols; every row gets a
    /// distinct column). Default: pad to square with zero-cost dummy rows —
    /// engines with a native rectangular path (Hungarian) override this.
    fn solve_min_cost_rect(&self, cost: &Matrix) -> AssignmentResult {
        let (n, m) = (cost.rows(), cost.cols());
        assert!(n <= m, "rect assignment needs rows <= cols");
        if n == m {
            return self.solve_min_cost(cost);
        }
        let mut sq = Matrix::zeros(m, m);
        for r in 0..n {
            for c in 0..m {
                sq.set(r, c, cost.get(r, c));
            }
        }
        let sol = self.solve_min_cost(&sq);
        let row_to_col = sol.row_to_col[..n].to_vec();
        let total = row_to_col
            .iter()
            .enumerate()
            .map(|(r, &c)| cost.get(r, c))
            .sum();
        AssignmentResult {
            row_to_col,
            cost: total,
        }
    }

    /// Like [`Self::solve_min_cost_rect`] but reusing caller-owned scratch
    /// buffers across solves (the batch hot path). Engines without a
    /// scratch-aware native path ignore the arena; results are identical
    /// either way.
    fn solve_min_cost_rect_scratch(
        &self,
        cost: &Matrix,
        _scratch: &mut SolveScratch,
    ) -> AssignmentResult {
        self.solve_min_cost_rect(cost)
    }

    /// Like [`Self::solve_min_cost_rect_scratch`] but writing the assignment
    /// into `scratch.assignment` instead of allocating an
    /// [`AssignmentResult`] — the allocation-free batch hot path. Engines
    /// with arena-native kernels (Hungarian, auction) override this to do
    /// zero heap allocations in steady state; the default delegates to the
    /// allocating path and copies. Results are bit-identical either way.
    fn solve_min_cost_rect_into(&self, cost: &Matrix, scratch: &mut SolveScratch) -> f64 {
        let sol = self.solve_min_cost_rect_scratch(cost, scratch);
        scratch.assignment.clear();
        scratch.assignment.extend_from_slice(&sol.row_to_col);
        sol.cost
    }

    /// Solve a batch of independent (square or rectangular) instances.
    /// Default: a sequential loop over [`Self::solve_min_cost_rect_scratch`]
    /// with one shared scratch arena. Engines with a real batched path —
    /// e.g. the PJRT/AOT auction artifact padding many instances through
    /// one device dispatch — override this (and [`Self::has_native_batch`]).
    /// Implementations must be positional (`out[i]` solves `costs[i]`) and
    /// bit-identical to the sequential loop.
    fn solve_batch(&self, costs: &[Matrix]) -> Vec<AssignmentResult> {
        let mut scratch = SolveScratch::default();
        costs
            .iter()
            .map(|c| self.solve_min_cost_rect_scratch(c, &mut scratch))
            .collect()
    }

    /// Whether [`Self::solve_batch`] is a true batched implementation; the
    /// matching service then prefers it over its own thread fan-out.
    fn has_native_batch(&self) -> bool {
        false
    }

    /// Whether this engine's solves are *exactly* optimal on the
    /// migration-cost grid (matrices whose entries are multiples of 1/16).
    /// The matching service's one-sided closed-form pruning is
    /// bit-identical to an engine solve only under this guarantee, so it
    /// is applied only for engines that opt in. Conservative default:
    /// `false` — an engine that does not declare exactness (e.g. an f32
    /// device artifact, or the auction with `resolution: None`) keeps its
    /// every instance solved rather than priced in closed form.
    fn exact_on_migration_costs(&self) -> bool {
        false
    }

    /// Whether [`Self::solve_min_cost_warm`] actually consumes warm-start
    /// hints. The matching service only takes its sequential warm-start
    /// path for engines that do; everyone else keeps the batched path.
    fn supports_warm_start(&self) -> bool {
        false
    }

    /// Fingerprint of the engine *configuration* (not just its kind), so
    /// cached solutions from differently-configured engines sharing a
    /// [`Self::name`] never serve each other. Engines with tunable
    /// parameters that change solutions (e.g. the auction's resolution)
    /// must fold them in; parameterless engines keep the default.
    fn config_fingerprint(&self) -> u64 {
        0
    }

    /// Square solve with an optional engine-specific warm-start hint, also
    /// returning the hint to retain for the next similar instance (the
    /// auction's dual prices). Engines without warm starts ignore the hint
    /// and return `None`.
    fn solve_min_cost_warm(
        &self,
        cost: &Matrix,
        _warm: Option<&[f64]>,
    ) -> (AssignmentResult, Option<Vec<f64>>) {
        (self.solve_min_cost(cost), None)
    }

    fn name(&self) -> &'static str;
}

/// Exact Hungarian engine (default).
#[derive(Debug, Default, Clone)]
pub struct HungarianEngine;

impl MatchingEngine for HungarianEngine {
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult {
        hungarian::solve_min_cost(cost)
    }

    fn solve_min_cost_rect(&self, cost: &Matrix) -> AssignmentResult {
        hungarian::solve_min_cost_rect(cost)
    }

    fn solve_min_cost_rect_scratch(
        &self,
        cost: &Matrix,
        scratch: &mut SolveScratch,
    ) -> AssignmentResult {
        hungarian::solve_min_cost_rect_in(cost, scratch)
    }

    fn solve_min_cost_rect_into(&self, cost: &Matrix, scratch: &mut SolveScratch) -> f64 {
        hungarian::solve_min_cost_rect_fill(cost, scratch).1
    }

    /// Exact everywhere, hence exact on the migration grid.
    fn exact_on_migration_costs(&self) -> bool {
        true
    }

    fn name(&self) -> &'static str {
        "hungarian"
    }
}

/// Native auction engine. `resolution` enables exactness on quantized costs
/// (e.g. `Some(1/16)` for Algorithm 3 migration costs).
#[derive(Debug, Clone)]
pub struct AuctionEngine {
    pub resolution: Option<f64>,
}

impl Default for AuctionEngine {
    fn default() -> Self {
        AuctionEngine {
            resolution: Some(1.0 / 16.0),
        }
    }
}

impl MatchingEngine for AuctionEngine {
    fn solve_min_cost(&self, cost: &Matrix) -> AssignmentResult {
        auction::solve_min_cost(cost, self.resolution)
    }

    fn solve_min_cost_warm(
        &self,
        cost: &Matrix,
        warm: Option<&[f64]>,
    ) -> (AssignmentResult, Option<Vec<f64>>) {
        let (sol, prices) = auction::solve_min_cost_warm(cost, self.resolution, warm);
        (sol, Some(prices))
    }

    /// Square instances run the arena-native auction kernel; rectangular
    /// ones keep the padded (allocating) path, as in
    /// [`MatchingEngine::solve_min_cost_rect`].
    fn solve_min_cost_rect_into(&self, cost: &Matrix, scratch: &mut SolveScratch) -> f64 {
        if cost.rows() == cost.cols() {
            let SolveScratch {
                assignment, auction, ..
            } = scratch;
            auction::solve_min_cost_fill(cost, self.resolution, auction, assignment)
        } else {
            let sol = self.solve_min_cost_rect_scratch(cost, scratch);
            scratch.assignment.clear();
            scratch.assignment.extend_from_slice(&sol.row_to_col);
            sol.cost
        }
    }

    /// Exact on the 1/16 grid only when every grid entry is a multiple of
    /// the configured resolution (ε-scaling then terminates below the
    /// grid spacing, which makes the assignment exactly optimal).
    fn exact_on_migration_costs(&self) -> bool {
        matches!(self.resolution, Some(q) if q > 0.0 && ((1.0 / 16.0) / q).fract() == 0.0)
    }

    fn supports_warm_start(&self) -> bool {
        true
    }

    /// The resolution changes both exactness and the returned argmin, so
    /// it is part of the cache identity.
    fn config_fingerprint(&self) -> u64 {
        self.resolution.map(f64::to_bits).unwrap_or(u64::MAX)
    }

    fn name(&self) -> &'static str {
        "auction"
    }
}

/// An edge in a bipartite packing graph: (left index, right index, weight).
pub type Edge = (usize, usize, f64);

/// A matched pair from [`max_weight_matching`].
#[derive(Debug, Clone, PartialEq)]
pub struct MatchedPair {
    pub left: usize,
    pub right: usize,
    pub weight: f64,
}

/// Maximum-weight bipartite matching where leaving a node unmatched is
/// allowed and only listed edges may be used (the Algorithm 4 problem):
/// choose a subset of `edges` forming a matching that maximizes total
/// weight. Weights must be finite; non-positive-weight edges are never
/// chosen (an unmatched pair is always at least as good).
///
/// Reduction: orient the graph so the smaller side is the rows, then solve
/// a rows × (cols + rows) *rectangular* min-cost assignment — real edges
/// cost −w, non-edges a problem-scaled forbidden cost, and `rows` dummy
/// columns at 0 allow any row to stay unmatched. O(rows²·cols) instead of
/// the O((rows+cols)³) square padding.
pub fn max_weight_matching(
    n_left: usize,
    n_right: usize,
    edges: &[Edge],
    engine: &dyn MatchingEngine,
) -> Vec<MatchedPair> {
    if n_left == 0 || n_right == 0 || edges.is_empty() {
        return vec![];
    }
    // Orient: rows = smaller side.
    let transpose = n_left > n_right;
    let (rows, cols) = if transpose {
        (n_right, n_left)
    } else {
        (n_left, n_right)
    };
    // Problem-scaled forbidden cost: large enough that no optimal solution
    // uses a non-edge, small enough to stay in f32 range for the AOT
    // auction engine (FORBIDDEN=1e12 would destroy its ε-scaling).
    let max_w = edges
        .iter()
        .map(|&(_, _, w)| w.abs())
        .fold(0.0f64, f64::max);
    let forbidden = (max_w + 1.0) * ((rows + cols) as f64 + 1.0);

    let width = cols + rows; // real columns + one dummy column per row
    let mut cost = Matrix::zeros(rows, width);
    for r in 0..rows {
        for c in 0..cols {
            cost.set(r, c, forbidden);
        }
    }
    for &(u, v, w) in edges {
        assert!(u < n_left && v < n_right, "edge ({u},{v}) out of range");
        assert!(w.is_finite(), "edge weight must be finite");
        let (r, c) = if transpose { (v, u) } else { (u, v) };
        // Keep the best weight on parallel edges.
        if -w < cost.get(r, c) {
            cost.set(r, c, -w);
        }
    }
    let solution = engine.solve_min_cost_rect(&cost);
    let mut out = Vec::new();
    for (r, &c) in solution.row_to_col.iter().enumerate() {
        if c < cols {
            let cell = cost.get(r, c);
            if cell < 0.0 {
                let (left, right) = if transpose { (c, r) } else { (r, c) };
                out.push(MatchedPair {
                    left,
                    right,
                    weight: -cell,
                });
            }
        }
    }
    out.sort_by_key(|p| (p.left, p.right));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{approx_eq, forall};

    fn total(pairs: &[MatchedPair]) -> f64 {
        pairs.iter().map(|p| p.weight).sum()
    }

    /// Exhaustive max-weight matching by subset enumeration (tests only).
    fn brute_force(n_left: usize, n_right: usize, edges: &[Edge]) -> f64 {
        let m = edges.len();
        assert!(m <= 16);
        let mut best = 0.0f64;
        'mask: for mask in 0u32..(1 << m) {
            let mut used_l = vec![false; n_left];
            let mut used_r = vec![false; n_right];
            let mut w = 0.0;
            for (k, &(u, v, ew)) in edges.iter().enumerate() {
                if mask & (1 << k) != 0 {
                    if used_l[u] || used_r[v] {
                        continue 'mask;
                    }
                    used_l[u] = true;
                    used_r[v] = true;
                    w += ew;
                }
            }
            best = best.max(w);
        }
        best
    }

    #[test]
    fn paper_figure7_example() {
        // Fig. 7(a): placed jobs {1,2,3} × pending jobs {4,5,6} with combined
        // normalized throughputs as edge weights; the matching picks the
        // maximum-total set.
        let edges = vec![
            (0, 0, 0.8), // job1-job4
            (0, 1, 1.2), // job1-job5
            (1, 1, 0.9), // job2-job5
            (1, 2, 1.1), // job2-job6
            (2, 2, 1.3), // job3-job6
        ];
        let m = max_weight_matching(3, 3, &edges, &HungarianEngine);
        let got = total(&m);
        assert!((got - brute_force(3, 3, &edges)).abs() < 1e-9);
        // job1-job4 (0.8) + job2-job5 (0.9) + job3-job6 (1.3) = 3.0 beats the
        // greedy pick of the single heaviest edges (1.2 + 1.3 = 2.5).
        assert!((got - 3.0).abs() < 1e-9, "total {got}");
    }

    #[test]
    fn parallelism_strategy_changes_matching() {
        // Fig. 7(b): boosting edge (job1, job5) from 1.2 to 1.5 by picking a
        // better parallelism strategy must keep/strengthen that edge.
        let edges = vec![(0, 1, 1.5), (1, 1, 0.9), (1, 2, 1.1), (2, 2, 1.3)];
        let m = max_weight_matching(3, 3, &edges, &HungarianEngine);
        assert!(m.iter().any(|p| p.left == 0 && p.right == 1 && p.weight == 1.5));
    }

    #[test]
    fn unmatched_better_than_negative_weight() {
        let edges = vec![(0, 0, -1.0)];
        let m = max_weight_matching(1, 1, &edges, &HungarianEngine);
        assert!(m.is_empty());
    }

    #[test]
    fn empty_inputs() {
        assert!(max_weight_matching(0, 5, &[], &HungarianEngine).is_empty());
        assert!(max_weight_matching(3, 3, &[], &HungarianEngine).is_empty());
    }

    #[test]
    fn matches_brute_force_property() {
        forall(
            "max-weight matching == brute force",
            53,
            120,
            |r| {
                let n_left = 1 + r.below(4) as usize;
                let n_right = 1 + r.below(4) as usize;
                let max_edges = (n_left * n_right).min(10);
                let m = 1 + r.below(max_edges as u64) as usize;
                let edges: Vec<Edge> = (0..m)
                    .map(|_| {
                        (
                            r.below(n_left as u64) as usize,
                            r.below(n_right as u64) as usize,
                            r.range_f64(0.1, 2.0),
                        )
                    })
                    .collect();
                (n_left, n_right, edges)
            },
            |(nl, nr, edges)| {
                let fast = total(&max_weight_matching(*nl, *nr, edges, &HungarianEngine));
                let slow = brute_force(*nl, *nr, edges);
                approx_eq(fast, slow, 1e-9)
            },
        );
    }

    #[test]
    fn engines_agree_on_packing_graphs() {
        forall(
            "hungarian vs auction on packing graphs",
            59,
            40,
            |r| {
                let n = 2 + r.below(6) as usize;
                let m = 1 + r.below((n * n).min(12) as u64) as usize;
                let edges: Vec<Edge> = (0..m)
                    .map(|_| {
                        (
                            r.below(n as u64) as usize,
                            r.below(n as u64) as usize,
                            // Quantized weights so the auction is exact.
                            r.below(32) as f64 / 16.0,
                        )
                    })
                    .collect();
                (n, edges)
            },
            |(n, edges)| {
                let h = total(&max_weight_matching(*n, *n, edges, &HungarianEngine));
                let a = total(&max_weight_matching(
                    *n,
                    *n,
                    edges,
                    &AuctionEngine {
                        resolution: Some(1.0 / 16.0),
                    },
                ));
                approx_eq(h, a, 1e-6)
            },
        );
    }

    #[test]
    fn default_solve_batch_matches_per_instance_solves() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(73);
        let matrices: Vec<Matrix> = (0..12)
            .map(|_| {
                let n = 1 + rng.below(6) as usize;
                let m = n + rng.below(3) as usize;
                let mut c = Matrix::zeros(n, m);
                for i in 0..n {
                    for j in 0..m {
                        c.set(i, j, rng.below(64) as f64 / 16.0);
                    }
                }
                c
            })
            .collect();
        for engine in [
            &HungarianEngine as &dyn MatchingEngine,
            &AuctionEngine::default(),
        ] {
            // The auction's default rect path pads; only feed it squares.
            let usable: Vec<Matrix> = matrices
                .iter()
                .filter(|c| engine.name() != "auction" || c.rows() == c.cols())
                .cloned()
                .collect();
            let batched = engine.solve_batch(&usable);
            assert_eq!(batched.len(), usable.len());
            for (c, sol) in usable.iter().zip(&batched) {
                let single = engine.solve_min_cost_rect(c);
                assert_eq!(single.row_to_col, sol.row_to_col);
                assert_eq!(single.cost.to_bits(), sol.cost.to_bits());
            }
            assert!(!engine.has_native_batch());
        }
    }

    #[test]
    fn rect_into_matches_allocating_path_for_all_engines() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(167);
        let matrices: Vec<Matrix> = (0..20)
            .map(|_| {
                let n = 1 + rng.below(7) as usize;
                let m = n + rng.below(4) as usize;
                let mut c = Matrix::zeros(n, m);
                for i in 0..n {
                    for j in 0..m {
                        c.set(i, j, rng.below(64) as f64 / 16.0);
                    }
                }
                c
            })
            .collect();
        for engine in [
            &HungarianEngine as &dyn MatchingEngine,
            &AuctionEngine::default(),
        ] {
            let mut scratch = SolveScratch::default();
            for c in &matrices {
                let want = engine.solve_min_cost_rect(c);
                let got_cost = engine.solve_min_cost_rect_into(c, &mut scratch);
                assert_eq!(scratch.assignment(), &want.row_to_col[..], "{}", engine.name());
                assert_eq!(got_cost.to_bits(), want.cost.to_bits(), "{}", engine.name());
            }
        }
    }

    #[test]
    fn result_is_a_matching() {
        forall(
            "output is a valid matching",
            61,
            60,
            |r| {
                let nl = 1 + r.below(8) as usize;
                let nr = 1 + r.below(8) as usize;
                let m = 1 + r.below(16) as usize;
                let edges: Vec<Edge> = (0..m)
                    .map(|_| {
                        (
                            r.below(nl as u64) as usize,
                            r.below(nr as u64) as usize,
                            r.range_f64(0.0, 3.0),
                        )
                    })
                    .collect();
                (nl, nr, edges)
            },
            |(nl, nr, edges)| {
                let pairs = max_weight_matching(*nl, *nr, edges, &HungarianEngine);
                let mut seen_l = vec![false; *nl];
                let mut seen_r = vec![false; *nr];
                for p in &pairs {
                    if seen_l[p.left] || seen_r[p.right] {
                        return Err("node matched twice".into());
                    }
                    seen_l[p.left] = true;
                    seen_r[p.right] = true;
                    if !edges
                        .iter()
                        .any(|&(u, v, w)| u == p.left && v == p.right && (w - p.weight).abs() < 1e-12)
                    {
                        return Err("pair not an input edge".into());
                    }
                }
                Ok(())
            },
        );
    }
}

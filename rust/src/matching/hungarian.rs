//! Exact minimum-cost assignment: the Hungarian method in its O(n³)
//! shortest-augmenting-path (Jonker–Volgenant style) formulation.
//!
//! This is the solver the paper invokes for both placement policies:
//! node-level GPU matching (Algorithm 3), cluster-level node matching
//! (Algorithm 2), the flat non-packing variant (Algorithm 5) and the
//! max-weight packing matching (Algorithm 4, via cost negation).

use crate::linalg::Matrix;

/// Cost treated as "forbidden edge". Large but safe against overflow when
/// accumulated across n ≤ 10⁴ rows.
pub const FORBIDDEN: f64 = 1e12;

/// An assignment of rows to columns.
#[derive(Debug, Clone, PartialEq)]
pub struct AssignmentResult {
    /// `row_to_col[i] = j` means row i is assigned to column j.
    pub row_to_col: Vec<usize>,
    /// Total cost of the assignment.
    pub cost: f64,
}

impl AssignmentResult {
    /// Inverse mapping col -> row.
    pub fn col_to_row(&self) -> Vec<usize> {
        let n = self.row_to_col.len();
        let mut inv = vec![usize::MAX; n];
        for (r, &c) in self.row_to_col.iter().enumerate() {
            inv[c] = r;
        }
        inv
    }
}

/// Solve the square min-cost assignment problem exactly.
///
/// `cost` must be square; entries ≥ `FORBIDDEN` mark edges that should not
/// be used (they will only appear in the solution if no feasible assignment
/// avoids them).
pub fn solve_min_cost(cost: &Matrix) -> AssignmentResult {
    assert_eq!(cost.rows(), cost.cols(), "hungarian needs a square matrix");
    solve_min_cost_rect(cost)
}

/// Reusable working buffers for [`solve_min_cost_rect_in`] /
/// [`solve_min_cost_rect_fill`]. Batch solvers keep one arena per worker
/// thread so the per-solve vectors are allocated once per worker instead
/// of once per instance. The potentials / scratch vectors are plain SoA
/// arrays and the per-augmentation column mask is a `u64` bitset, so the
/// inner loops touch dense cache lines and skip visited columns a word at
/// a time.
#[derive(Debug, Default)]
pub struct SolveScratch {
    pub(crate) u: Vec<f64>,
    pub(crate) v: Vec<f64>,
    pub(crate) p: Vec<usize>,
    pub(crate) way: Vec<usize>,
    pub(crate) minv: Vec<f64>,
    /// Bitset over columns `0..=m` (bit 0 is the sentinel column).
    pub(crate) used: Vec<u64>,
    /// Output slot of the allocation-free fill path (row → column).
    pub(crate) assignment: Vec<usize>,
    /// Sub-arena for the auction engine's in-place solves.
    pub(crate) auction: super::auction::AuctionScratch,
}

impl SolveScratch {
    /// The assignment written by the last fill-style solve (row → column).
    pub fn assignment(&self) -> &[usize] {
        &self.assignment
    }
}

/// Rectangular min-cost assignment: every *row* gets a distinct column
/// (requires `rows ≤ cols`). O(rows² · cols) — much cheaper than padding
/// to square when the sides are unbalanced (the packing-policy shape).
pub fn solve_min_cost_rect(cost: &Matrix) -> AssignmentResult {
    solve_min_cost_rect_in(cost, &mut SolveScratch::default())
}

/// [`solve_min_cost_rect`] with caller-owned scratch buffers (the batch
/// hot path). Identical algorithm; results are bit-identical regardless of
/// what previous solves used the arena.
pub fn solve_min_cost_rect_in(cost: &Matrix, scratch: &mut SolveScratch) -> AssignmentResult {
    let (_, total) = solve_min_cost_rect_fill(cost, scratch);
    AssignmentResult {
        row_to_col: scratch.assignment.clone(),
        cost: total,
    }
}

/// Allocation-free core of the rectangular Hungarian solve: identical
/// pivots and bit-identical outputs to [`solve_min_cost_rect`], but the
/// assignment lands in `scratch.assignment` instead of a fresh `Vec` — in
/// steady state (warm arena) the call performs zero heap allocations,
/// which is what the counting-allocator audit in `bench_round_pipeline`
/// asserts. Returns the assignment slice and the total cost.
pub fn solve_min_cost_rect_fill<'a>(
    cost: &Matrix,
    scratch: &'a mut SolveScratch,
) -> (&'a [usize], f64) {
    let n = cost.rows();
    let m = cost.cols();
    assert!(n <= m, "rectangular hungarian needs rows <= cols");
    let SolveScratch {
        u,
        v,
        p,
        way,
        minv,
        used,
        assignment,
        ..
    } = scratch;
    assignment.clear();
    if n == 0 {
        return (assignment.as_slice(), 0.0);
    }

    const INF: f64 = f64::INFINITY;
    // 1-indexed arrays with column 0 as sentinel (e-maxx formulation);
    // p[j] = row matched to column j (0 = none); p[0] = row being inserted.
    // `used` packs columns 0..=m into u64 words; bit 0 (the sentinel) is
    // set by the first inner iteration, so scans over `!word` naturally
    // cover exactly the unvisited real columns, 64 at a time.
    let words = m / 64 + 1;
    u.clear();
    u.resize(n + 1, 0.0);
    v.clear();
    v.resize(m + 1, 0.0);
    p.clear();
    p.resize(m + 1, 0);
    way.clear();
    way.resize(m + 1, 0);
    minv.clear();
    minv.resize(m + 1, INF);
    used.clear();
    used.resize(words, 0);
    // Valid-bit mask of the last word (bits representing j > m are never
    // scanned).
    let top = (m + 1) % 64;
    let last_mask: u64 = if top == 0 { !0 } else { (1u64 << top) - 1 };

    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        minv.iter_mut().for_each(|x| *x = INF);
        used.iter_mut().for_each(|x| *x = 0);
        loop {
            used[j0 / 64] |= 1u64 << (j0 % 64);
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            let row = cost.row(i0 - 1);
            for (k, &word) in used.iter().enumerate() {
                let mut free = !word;
                if k == words - 1 {
                    free &= last_mask;
                }
                // Ascending trailing_zeros preserves the scalar loop's
                // lowest-j-wins tie-breaks exactly.
                while free != 0 {
                    let j = k * 64 + free.trailing_zeros() as usize;
                    free &= free - 1;
                    let cur = row[j - 1] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            // Potential update. The branchless sweep also shifts minv of
            // *used* columns — harmless, those slots are never read again
            // before the per-row reset — and the used bits then move the
            // potentials exactly as the scalar loop did.
            for x in minv.iter_mut() {
                *x -= delta;
            }
            for (k, &word) in used.iter().enumerate() {
                let mut bits = word;
                while bits != 0 {
                    let j = k * 64 + bits.trailing_zeros() as usize;
                    bits &= bits - 1;
                    u[p[j]] += delta;
                    v[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        // Augment along the alternating path.
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }

    assignment.resize(n, usize::MAX);
    for j in 1..=m {
        if p[j] != 0 {
            assignment[p[j] - 1] = j - 1;
        }
    }
    let total = assignment
        .iter()
        .enumerate()
        .map(|(r, &c)| cost.get(r, c))
        .sum();
    (assignment.as_slice(), total)
}

/// Exhaustive minimum-cost assignment (n! — tests only, n ≤ 8).
pub fn brute_force_min_cost(cost: &Matrix) -> AssignmentResult {
    let n = cost.rows();
    assert!(n <= 8, "brute force limited to n<=8");
    let mut perm: Vec<usize> = (0..n).collect();
    let mut best = AssignmentResult {
        row_to_col: perm.clone(),
        cost: f64::INFINITY,
    };
    permute(&mut perm, 0, &mut |p| {
        let c: f64 = p.iter().enumerate().map(|(r, &col)| cost.get(r, col)).sum();
        if c < best.cost {
            best = AssignmentResult {
                row_to_col: p.to_vec(),
                cost: c,
            };
        }
    });
    best
}

fn permute(xs: &mut Vec<usize>, k: usize, visit: &mut impl FnMut(&[usize])) {
    if k == xs.len() {
        visit(xs);
        return;
    }
    for i in k..xs.len() {
        xs.swap(k, i);
        permute(xs, k + 1, visit);
        xs.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{approx_eq, forall};
    use crate::util::rng::Pcg64;

    #[test]
    fn trivial_cases() {
        assert_eq!(solve_min_cost(&Matrix::zeros(0, 0)).cost, 0.0);
        let one = Matrix::from_rows(&[&[3.5]]);
        let r = solve_min_cost(&one);
        assert_eq!(r.row_to_col, vec![0]);
        assert_eq!(r.cost, 3.5);
    }

    #[test]
    fn known_3x3() {
        // Classic example: optimal = 5 (0->1, 1->0, 2->2).
        let c = Matrix::from_rows(&[&[4.0, 1.0, 3.0], &[2.0, 0.0, 5.0], &[3.0, 2.0, 2.0]]);
        let r = solve_min_cost(&c);
        assert_eq!(r.cost, 5.0);
        assert_eq!(r.row_to_col, vec![1, 0, 2]);
    }

    #[test]
    fn paper_example2_identity_remap_costs_zero() {
        // §A Example 2: plans {(0,1),(1,2),(2,3),(3,4)} vs
        // {(0,4),(1,1),(2,2),(3,3)} — remapping makes migrations 0.
        let c = Matrix::from_rows(&[
            &[1.0, 0.0, 1.0, 1.0],
            &[1.0, 1.0, 0.0, 1.0],
            &[1.0, 1.0, 1.0, 0.0],
            &[0.0, 1.0, 1.0, 1.0],
        ]);
        let r = solve_min_cost(&c);
        assert_eq!(r.cost, 0.0);
        assert_eq!(r.row_to_col, vec![1, 2, 3, 0]);
    }

    #[test]
    fn paper_example3_one_migration() {
        // §A Example 3 cost matrix; optimal total = 1.0.
        let c = Matrix::from_rows(&[
            &[1.0, 0.5, 1.5, 1.5],
            &[1.5, 1.0, 0.0, 1.0],
            &[1.5, 1.0, 1.0, 0.0],
            &[0.5, 1.0, 1.0, 1.0],
        ]);
        let r = solve_min_cost(&c);
        assert!((r.cost - 1.0).abs() < 1e-12, "cost {}", r.cost);
    }

    #[test]
    fn matches_brute_force_property() {
        forall(
            "hungarian == brute force",
            31,
            200,
            |r| {
                let n = 1 + r.below(6) as usize;
                let mut m = Matrix::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        m.set(i, j, r.range_f64(0.0, 10.0));
                    }
                }
                m
            },
            |cost| {
                let fast = solve_min_cost(cost);
                let slow = brute_force_min_cost(cost);
                approx_eq(fast.cost, slow.cost, 1e-9)?;
                // Assignment must be a permutation.
                let mut seen = vec![false; cost.rows()];
                for &c in &fast.row_to_col {
                    if seen[c] {
                        return Err("duplicate column".into());
                    }
                    seen[c] = true;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn respects_forbidden_edges_when_possible() {
        let big = FORBIDDEN;
        let c = Matrix::from_rows(&[&[big, 1.0], &[1.0, big]]);
        let r = solve_min_cost(&c);
        assert_eq!(r.row_to_col, vec![1, 0]);
        assert_eq!(r.cost, 2.0);
    }

    #[test]
    fn permutation_cost_shift_invariance() {
        // Adding a constant to a full row shifts every assignment equally:
        // the argmin permutation stays optimal.
        forall(
            "row-shift invariance",
            37,
            50,
            |r| {
                let n = 2 + r.below(5) as usize;
                let mut m = Matrix::zeros(n, n);
                for i in 0..n {
                    for j in 0..n {
                        m.set(i, j, r.range_f64(0.0, 5.0));
                    }
                }
                let row = r.below(n as u64) as usize;
                let shift = r.range_f64(0.5, 3.0);
                (m, row, shift)
            },
            |(m, row, shift)| {
                let base = solve_min_cost(m);
                let mut shifted = m.clone();
                for j in 0..m.cols() {
                    shifted.set(*row, j, m.get(*row, j) + shift);
                }
                let after = solve_min_cost(&shifted);
                approx_eq(after.cost, base.cost + shift, 1e-9)
            },
        );
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // One arena reused across differently-sized solves must reproduce
        // the fresh-allocation results exactly (the batch-solver contract).
        let mut rng = Pcg64::new(77);
        let mut scratch = SolveScratch::default();
        for _ in 0..50 {
            let n = 1 + rng.below(8) as usize;
            let m = n + rng.below(5) as usize;
            let mut c = Matrix::zeros(n, m);
            for i in 0..n {
                for j in 0..m {
                    c.set(i, j, rng.range_f64(0.0, 10.0));
                }
            }
            let fresh = solve_min_cost_rect(&c);
            let reused = solve_min_cost_rect_in(&c, &mut scratch);
            assert_eq!(fresh.row_to_col, reused.row_to_col);
            assert_eq!(fresh.cost.to_bits(), reused.cost.to_bits());
        }
    }

    #[test]
    fn fill_variant_matches_allocating_path() {
        let mut rng = Pcg64::new(123);
        let mut scratch = SolveScratch::default();
        for _ in 0..30 {
            let n = 1 + rng.below(9) as usize;
            let m = n + rng.below(6) as usize;
            let mut c = Matrix::zeros(n, m);
            for i in 0..n {
                for j in 0..m {
                    c.set(i, j, rng.range_f64(0.0, 10.0));
                }
            }
            let fresh = solve_min_cost_rect(&c);
            let (assignment, total) = solve_min_cost_rect_fill(&c, &mut scratch);
            assert_eq!(fresh.row_to_col, assignment);
            assert_eq!(fresh.cost.to_bits(), total.to_bits());
        }
    }

    #[test]
    fn bitset_skips_word_boundaries_correctly() {
        // Sizes straddling the 63/64/65 and 127/128/129 column boundaries
        // exercise the last-word mask and multi-word scans.
        let mut rng = Pcg64::new(321);
        let mut scratch = SolveScratch::default();
        for &m in &[63usize, 64, 65, 127, 128, 129] {
            let n = m.min(40);
            let mut c = Matrix::zeros(n, m);
            for i in 0..n {
                for j in 0..m {
                    c.set(i, j, rng.range_f64(0.0, 100.0));
                }
            }
            let got = solve_min_cost_rect_in(&c, &mut scratch);
            // Assignment must be a valid partial permutation into 0..m.
            let mut seen = vec![false; m];
            for &col in &got.row_to_col {
                assert!(col < m && !seen[col]);
                seen[col] = true;
            }
            // And the dual objective must certify optimality: for an
            // optimal (u, v), u_i + v_j <= c_ij with equality on matches.
            let brute_n = 6.min(n);
            let mut small = Matrix::zeros(brute_n, brute_n);
            for i in 0..brute_n {
                for j in 0..brute_n {
                    small.set(i, j, c.get(i, j));
                }
            }
            assert!(
                (solve_min_cost_rect_in(&small, &mut scratch).cost
                    - brute_force_min_cost(&small).cost)
                    .abs()
                    < 1e-9
            );
        }
    }

    #[test]
    fn large_instance_smoke() {
        let mut r = Pcg64::new(5);
        let n = 256;
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                m.set(i, j, r.range_f64(0.0, 100.0));
            }
        }
        let res = solve_min_cost(&m);
        assert_eq!(res.row_to_col.len(), n);
        // Optimal cost for random uniform costs is far below the diagonal sum.
        let diag: f64 = (0..n).map(|i| m.get(i, i)).sum();
        assert!(res.cost < diag);
    }
}

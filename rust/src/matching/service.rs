//! The batched matching service: every graph-matching instance a
//! scheduling round generates — the `num_nodes²` Algorithm 3 node-pair
//! matchings, the Algorithm 2 node matching, Algorithm 5's flat
//! cluster-wide matching and Algorithm 4's packing matching — flows
//! through one [`MatchingService`] that
//!
//! 1. **prunes** trivial node pairs before solving: empty×empty pairs
//!    resolve to cost 0 with no matrix, empty×nonempty pairs get the
//!    closed-form one-sided total (gated on [`sig_is_exact_prunable`] so
//!    the closed form is bit-identical to what a solve would return);
//! 2. **dedups** identical cost matrices by content key within a round
//!    (symmetric clusters solve each unique instance once) and **caches**
//!    solved instances by content across rounds — a node pair whose job
//!    sets did not change since the previous round is a lookup, not a
//!    rebuild-and-solve;
//! 3. **solves the surviving unique instances as one batch**, either via
//!    the engine's native [`MatchingEngine::solve_batch`] (the AOT auction
//!    artifact's hook) or across the process-wide shared
//!    [`WorkerPool`] (deterministic chunked map, one [`SolveScratch`]
//!    arena per chunk). Results are positionally deterministic and
//!    bit-identical to sequential per-instance solves for any thread
//!    budget.
//!
//! Parity contract: with [`ServiceConfig::default`] every consumer's
//! output (plans, migration counts, costs, packing matchings) is
//! bit-identical to [`ServiceConfig::sequential_reference`], which
//! reproduces the pre-service sequential path — property-tested in
//! `tests/properties.rs` and end-to-end in `tests/integration_sim.rs`.
//! The one deliberate exception is [`ServiceConfig::warm_start`] (default
//! off): auction dual prices retained per node-pair position warm-start
//! the next round's solve, which preserves *optimality* on quantized
//! costs but may pick a different equally-optimal assignment.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::linalg::Matrix;
use crate::util::alloc;
use crate::util::pool::WorkerPool;

use super::batch::{
    one_sided_cost, pair_cost_matrix, sig_is_empty, sig_is_exact_prunable, Batch, NodeSig,
    PairKey,
};
use super::hungarian::SolveScratch;
use super::{AssignmentResult, MatchingEngine};

/// Optimization toggles for [`MatchingService`]. Each flag is independent
/// so parity tests can bisect a divergence to one optimization.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Closed-form costs for empty×empty and (exact-prunable)
    /// empty×nonempty node pairs: no matrix is built, no solve runs.
    pub prune: bool,
    /// Within-round content dedup: identical cost matrices solve once.
    pub dedup: bool,
    /// Cross-round content cache: a pair whose node contents did not
    /// change since a previous solve is a lookup.
    pub cache: bool,
    /// Solve the unique batch across the shared worker pool.
    pub parallel: bool,
    /// Minimum unique instances before the pool is engaged — below this,
    /// thread spawn costs more than the solves themselves.
    pub parallel_threshold: usize,
    /// Worker cap; 0 = the shared pool's thread budget
    /// (`--threads` / `TESSERAE_THREADS`, defaulting to
    /// `std::thread::available_parallelism()`).
    pub workers: usize,
    /// Retain auction dual prices per node-pair position and warm-start
    /// that position's next solve. Off by default: warm starts preserve
    /// optimality but may return a different equally-optimal assignment,
    /// which breaks bit-parity with the cold path. Note the interaction
    /// with `cache`: a pair whose content is *unchanged* is a cache hit
    /// and never re-solves, so with both enabled warm starts only fire on
    /// positions whose cost matrix actually changed (the intended case —
    /// a changed matrix close to last round's is where retained prices
    /// help); with `cache` off every recurring solve warm-starts.
    pub warm_start: bool,
    /// Cross-round cache entry cap; the cache is epoch-cleared when it
    /// would exceed this (bounds memory on month-long simulations).
    pub max_cache_entries: usize,
    /// Cross-round cache *weight* cap, in signature GPU slots summed over
    /// both sides of every entry. Entry counts alone do not bound bytes —
    /// Algorithm 5's whole-cluster instances carry O(total GPUs) slots
    /// each — so the cache also epoch-clears when its total slot weight
    /// would exceed this.
    pub max_cache_slots: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            prune: true,
            dedup: true,
            cache: true,
            parallel: true,
            parallel_threshold: 64,
            workers: 0,
            warm_start: false,
            max_cache_entries: 65_536,
            max_cache_slots: 262_144,
        }
    }
}

impl ServiceConfig {
    /// Everything off: the service degenerates to the pre-service
    /// build-all, solve-sequentially path. This is the reference side of
    /// every parity test.
    pub fn sequential_reference() -> ServiceConfig {
        ServiceConfig {
            prune: false,
            dedup: false,
            cache: false,
            parallel: false,
            parallel_threshold: usize::MAX,
            workers: 1,
            warm_start: false,
            max_cache_entries: 0,
            max_cache_slots: 0,
        }
    }
}

/// Per-round service counters, drained by
/// [`MatchingService::take_round_stats`] into `MigrationOutcome` and the
/// Fig. 14(b) decision-time breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MatchingServiceStats {
    /// Matching instances the round generated (before any filtering).
    pub instances: usize,
    /// Instances resolved by closed-form pruning (no matrix, no solve).
    pub pruned: usize,
    /// Instances that shared an identical in-round instance's solve.
    pub deduped: usize,
    /// Instances resolved from the cross-round content cache.
    pub cache_hits: usize,
    /// Cost matrices actually constructed.
    pub built: usize,
    /// Engine solves actually performed.
    pub solved: usize,
    /// Solves that received a warm-start price hint.
    pub warm_starts: usize,
    /// Wall time spent inside engine solves.
    pub solve_wall_s: f64,
    /// Heap allocations made *inside* batch solve kernels, measured with
    /// per-thread counters from the counting allocator. Always 0 unless
    /// the crate is built with `--features alloc_audit`; with the audit on,
    /// a steady-state round (arena buffers grown to size) must report 0 —
    /// asserted by `bench_round_pipeline`.
    pub kernel_allocs: usize,
}

impl MatchingServiceStats {
    /// Fold a concurrently-produced stats block into this one: counts add,
    /// solve wall time takes the max (the POP partition-stitch rule, where
    /// partitions run on parallel threads).
    pub fn absorb_parallel(&mut self, o: &MatchingServiceStats) {
        self.instances += o.instances;
        self.pruned += o.pruned;
        self.deduped += o.deduped;
        self.cache_hits += o.cache_hits;
        self.built += o.built;
        self.solved += o.solved;
        self.warm_starts += o.warm_starts;
        self.solve_wall_s = self.solve_wall_s.max(o.solve_wall_s);
        self.kernel_allocs += o.kernel_allocs;
    }
}

/// One round's node-pair phase output: the Algorithm 2 node cost matrix
/// plus the per-pair GPU assignments that were solved along the way.
/// Pruned pairs have no eager assignment — the migration policy resolves
/// the few it actually matches via [`MatchingService::pair_assignment`].
pub struct NodePairRound {
    pub node_cost: Matrix,
    assignments: Vec<Option<Arc<AssignmentResult>>>,
    cols: usize,
}

impl NodePairRound {
    pub fn assignment(&self, k: usize, l: usize) -> Option<&Arc<AssignmentResult>> {
        self.assignments[k * self.cols + l].as_ref()
    }
}

/// The service: per-round prune/dedup/batch orchestration plus the
/// cross-round content cache and warm-start price store. Engines are
/// passed per call (the scheduler owns its `Arc<dyn MatchingEngine>`), so
/// one service composes with any engine, including the PJRT-loaded AOT
/// auction artifact — cached solutions and retained prices are keyed by
/// `engine.name()` alongside the pair content, so mixing engines through
/// one service can never serve one engine's assignment to another.
pub struct MatchingService {
    pub cfg: ServiceConfig,
    cache: HashMap<PairKey, Arc<AssignmentResult>>,
    /// Total signature slots held by `cache` (the byte-ish weight the
    /// `max_cache_slots` budget bounds).
    cache_slots: usize,
    warm_prices: HashMap<(&'static str, u64, usize, usize), Vec<f64>>,
    stats: MatchingServiceStats,
    /// Solve arenas reused across rounds. Workers check one out per chunk
    /// and return it grown; after the first round every buffer has reached
    /// its steady-state capacity and solve kernels stop allocating.
    scratch_pool: Mutex<Vec<SolveScratch>>,
}

impl MatchingService {
    pub fn new(cfg: ServiceConfig) -> MatchingService {
        MatchingService {
            cfg,
            cache: HashMap::new(),
            cache_slots: 0,
            warm_prices: HashMap::new(),
            stats: MatchingServiceStats::default(),
            scratch_pool: Mutex::new(Vec::new()),
        }
    }

    pub fn with_defaults() -> MatchingService {
        MatchingService::new(ServiceConfig::default())
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    pub fn clear_cache(&mut self) {
        self.cache.clear();
        self.cache_slots = 0;
        self.warm_prices.clear();
    }

    /// Drain the counters accumulated since the last drain (one scheduling
    /// round's worth when drained at the end of the migration stage, the
    /// round's last matching consumer).
    pub fn take_round_stats(&mut self) -> MatchingServiceStats {
        std::mem::take(&mut self.stats)
    }

    pub fn peek_round_stats(&self) -> MatchingServiceStats {
        self.stats
    }

    /// The tentpole entry point: price every (prev, next) node pair of a
    /// round (Algorithm 2 lines 3–5) as one pruned, deduped, cached,
    /// batch-solved unit. Entry `(k, l)` of the returned matrix is the
    /// optimal Algorithm 3 matching cost of previous node `k` against next
    /// node `l`, bit-identical to solving each pair individually.
    pub fn node_pair_round(
        &mut self,
        engine: &dyn MatchingEngine,
        prev_sigs: &[Arc<NodeSig>],
        next_sigs: &[Arc<NodeSig>],
    ) -> NodePairRound {
        let n = prev_sigs.len();
        let m = next_sigs.len();
        crate::obs_span!("matching.node_pair_round", { prev_nodes: n, next_nodes: m });
        // Algorithm 3 matches equally-sized GPU lists; a silent mismatch
        // would mis-size every cost matrix.
        let width = prev_sigs.first().map(|s| s.len()).unwrap_or(0);
        assert!(
            prev_sigs.iter().chain(next_sigs.iter()).all(|s| s.len() == width),
            "node GPU lists must all have the same length"
        );

        let mut node_cost = Matrix::zeros(n, m);
        let mut assignments: Vec<Option<Arc<AssignmentResult>>> = vec![None; n * m];
        let prev_empty: Vec<bool> = prev_sigs.iter().map(|s| sig_is_empty(s)).collect();
        let next_empty: Vec<bool> = next_sigs.iter().map(|s| sig_is_empty(s)).collect();
        // One-sided closed forms additionally need the engine to be exact
        // on the 1/16 migration-cost grid — an approximate engine could
        // return a (worse) near-optimal total where the closed form is the
        // true optimum, breaking bit-parity with the reference path.
        let engine_exact = engine.exact_on_migration_costs();
        let prev_prunable: Vec<bool> = prev_sigs
            .iter()
            .map(|s| engine_exact && sig_is_exact_prunable(s))
            .collect();
        let next_prunable: Vec<bool> = next_sigs
            .iter()
            .map(|s| engine_exact && sig_is_exact_prunable(s))
            .collect();
        let engine_name = engine.name();
        let engine_cfg = engine.config_fingerprint();

        let mut batch = Batch::default();
        // (pair index, batch slot) links, filled in after the batch solve.
        let mut links: Vec<(usize, usize)> = Vec::new();
        self.stats.instances += n * m;
        for k in 0..n {
            for l in 0..m {
                let idx = k * m + l;
                if self.cfg.prune {
                    if prev_empty[k] && next_empty[l] {
                        // All-zero matrix: every engine's total is exactly
                        // 0 regardless of the permutation it picks, so this
                        // prune needs no exactness gate (entry stays 0.0).
                        self.stats.pruned += 1;
                        continue;
                    }
                    if prev_empty[k] && next_prunable[l] {
                        node_cost.set(k, l, one_sided_cost(&next_sigs[l]));
                        self.stats.pruned += 1;
                        continue;
                    }
                    if next_empty[l] && prev_prunable[k] {
                        node_cost.set(k, l, one_sided_cost(&prev_sigs[k]));
                        self.stats.pruned += 1;
                        continue;
                    }
                }
                if self.cfg.cache || self.cfg.dedup {
                    let key = PairKey {
                        engine: engine_name,
                        engine_cfg,
                        prev: Arc::clone(&prev_sigs[k]),
                        next: Arc::clone(&next_sigs[l]),
                    };
                    if self.cfg.cache {
                        if let Some(sol) = self.cache.get(&key) {
                            self.stats.cache_hits += 1;
                            node_cost.set(k, l, sol.cost);
                            assignments[idx] = Some(Arc::clone(sol));
                            continue;
                        }
                    }
                    let (slot, dup) = batch.push_keyed(key, self.cfg.dedup);
                    if dup {
                        self.stats.deduped += 1;
                    } else {
                        self.stats.built += 1;
                    }
                    links.push((idx, slot));
                } else {
                    let slot =
                        batch.push_matrix(pair_cost_matrix(&prev_sigs[k], &next_sigs[l]));
                    self.stats.built += 1;
                    links.push((idx, slot));
                }
            }
        }

        // The sequential warm path only pays off for engines that actually
        // consume price hints; everyone else keeps the batched path.
        let solved = if self.cfg.warm_start && engine.supports_warm_start() {
            self.solve_batch_warm(engine, &batch, &links, m)
        } else {
            self.solve_batch_now(engine, batch.matrices())
        };
        debug_assert_eq!(solved.len(), batch.len());
        if self.cfg.cache {
            for (key, sol) in batch.keys().iter().zip(&solved) {
                if let Some(key) = key {
                    self.cache_insert(key.clone(), Arc::clone(sol));
                }
            }
        }
        for &(idx, slot) in &links {
            let sol = &solved[slot];
            node_cost.set(idx / m, idx % m, sol.cost);
            assignments[idx] = Some(Arc::clone(sol));
        }
        NodePairRound {
            node_cost,
            assignments,
            cols: m,
        }
    }

    /// GPU assignment for one (prev, next) node-pair content — the lazy
    /// path for pairs whose *cost* was pruned but which the node matching
    /// then selected. Content-cached, so e.g. the all-empty pair's zero
    /// matrix is solved once ever per engine behaviour.
    pub fn pair_assignment(
        &mut self,
        engine: &dyn MatchingEngine,
        prev: &Arc<NodeSig>,
        next: &Arc<NodeSig>,
    ) -> Arc<AssignmentResult> {
        let key = PairKey {
            engine: engine.name(),
            engine_cfg: engine.config_fingerprint(),
            prev: Arc::clone(prev),
            next: Arc::clone(next),
        };
        if self.cfg.cache {
            if let Some(sol) = self.cache.get(&key) {
                self.stats.cache_hits += 1;
                return Arc::clone(sol);
            }
        }
        let matrix = pair_cost_matrix(prev, next);
        self.stats.built += 1;
        let t0 = Instant::now();
        let sol = Arc::new(engine.solve_min_cost(&matrix));
        self.stats.solved += 1;
        self.stats.solve_wall_s += t0.elapsed().as_secs_f64();
        if self.cfg.cache {
            self.cache_insert(key, Arc::clone(&sol));
        }
        sol
    }

    /// One standalone pair instance (Algorithm 5's whole-cluster matching
    /// is a single "node pair" spanning every GPU): counted, cached,
    /// solved.
    pub fn solve_pair(
        &mut self,
        engine: &dyn MatchingEngine,
        prev: &Arc<NodeSig>,
        next: &Arc<NodeSig>,
    ) -> Arc<AssignmentResult> {
        self.stats.instances += 1;
        self.pair_assignment(engine, prev, next)
    }

    /// Solve one square instance directly (the Algorithm 2 node matrix —
    /// fresh floats every round, so content caching would never hit).
    pub fn solve_square(
        &mut self,
        engine: &dyn MatchingEngine,
        cost: &Matrix,
    ) -> AssignmentResult {
        self.stats.instances += 1;
        self.stats.built += 1;
        let t0 = Instant::now();
        let sol = engine.solve_min_cost(cost);
        self.stats.solved += 1;
        self.stats.solve_wall_s += t0.elapsed().as_secs_f64();
        sol
    }

    /// Algorithm 4's max-weight packing matching, routed through the
    /// service so packing solves land in the same per-round stats (the
    /// reduction itself lives in [`super::max_weight_matching`]).
    pub fn max_weight(
        &mut self,
        engine: &dyn MatchingEngine,
        n_left: usize,
        n_right: usize,
        edges: &[super::Edge],
    ) -> Vec<super::MatchedPair> {
        self.stats.instances += 1;
        self.stats.built += 1;
        let t0 = Instant::now();
        let out = super::max_weight_matching(n_left, n_right, edges, engine);
        self.stats.solved += 1;
        self.stats.solve_wall_s += t0.elapsed().as_secs_f64();
        out
    }

    /// Solve `matrices` positionally. Three interchangeable paths — the
    /// engine's native batch, the shared worker pool's chunked map, or a
    /// sequential loop — all bit-identical because every instance is
    /// solved by the same deterministic per-instance entry point. The
    /// sequential and pooled paths run the allocation-free
    /// [`MatchingEngine::solve_min_cost_rect_into`] kernels against arenas
    /// checked out of [`Self::scratch_pool`], with each kernel's heap
    /// allocations measured per thread (see
    /// [`MatchingServiceStats::kernel_allocs`]); result materialization
    /// happens outside the measured window.
    fn solve_batch_now(
        &mut self,
        engine: &dyn MatchingEngine,
        matrices: &[Matrix],
    ) -> Vec<Arc<AssignmentResult>> {
        if matrices.is_empty() {
            return Vec::new();
        }
        crate::obs_span!("matching.batch", { instances: matrices.len() });
        let t0 = Instant::now();
        let solved: Vec<AssignmentResult> = if engine.has_native_batch() {
            engine.solve_batch(matrices)
        } else if !self.cfg.parallel || matrices.len() < self.cfg.parallel_threshold {
            let mut scratch = self.take_scratch();
            let mut kernel_allocs = 0usize;
            let out = matrices
                .iter()
                .map(|c| Self::solve_one_into(engine, c, &mut scratch, &mut kernel_allocs))
                .collect();
            self.scratch_pool.lock().unwrap().push(scratch);
            self.stats.kernel_allocs += kernel_allocs;
            out
        } else {
            // `cfg.workers` caps the worker count (0 = the pool's budget);
            // a budget of 1, or a pool already fully leased by an outer
            // caller (scenario sweeps), degrades to the same sequential
            // loop as above. Arenas are checked out per chunk and returned
            // grown, so steady-state rounds reuse warm buffers.
            let pool = &self.scratch_pool;
            let kernel_allocs = AtomicUsize::new(0);
            let out = WorkerPool::global().run_chunks(matrices, self.cfg.workers, 8, |_, part| {
                let mut scratch = pool.lock().unwrap().pop().unwrap_or_default();
                let mut chunk_allocs = 0usize;
                let solved = part
                    .iter()
                    .map(|c| Self::solve_one_into(engine, c, &mut scratch, &mut chunk_allocs))
                    .collect::<Vec<_>>();
                pool.lock().unwrap().push(scratch);
                kernel_allocs.fetch_add(chunk_allocs, Ordering::Relaxed);
                solved
            });
            self.stats.kernel_allocs += kernel_allocs.load(Ordering::Relaxed);
            out
        };
        self.stats.solved += matrices.len();
        self.stats.solve_wall_s += t0.elapsed().as_secs_f64();
        solved.into_iter().map(Arc::new).collect()
    }

    /// One arena-kernel solve with its heap allocations measured via the
    /// current thread's allocator counter (0 unless `alloc_audit` is on).
    /// The `AssignmentResult` copy is deliberately outside the window —
    /// handing results back inherently allocates; the claim under audit is
    /// that the *solve kernels* do not.
    fn solve_one_into(
        engine: &dyn MatchingEngine,
        cost: &Matrix,
        scratch: &mut SolveScratch,
        kernel_allocs: &mut usize,
    ) -> AssignmentResult {
        let before = alloc::thread_allocs();
        let total = engine.solve_min_cost_rect_into(cost, scratch);
        *kernel_allocs += alloc::thread_allocs() - before;
        AssignmentResult {
            row_to_col: scratch.assignment().to_vec(),
            cost: total,
        }
    }

    fn take_scratch(&self) -> SolveScratch {
        self.scratch_pool.lock().unwrap().pop().unwrap_or_default()
    }

    /// Warm-start path: sequential by design (prices are retained per
    /// node-pair position, so each solve feeds the next round's hint).
    fn solve_batch_warm(
        &mut self,
        engine: &dyn MatchingEngine,
        batch: &Batch,
        links: &[(usize, usize)],
        cols: usize,
    ) -> Vec<Arc<AssignmentResult>> {
        // Each slot's first consuming position owns the retained prices
        // (per engine identity — prices from one solver configuration
        // mean nothing to another).
        crate::obs_span!("matching.batch_warm", { instances: batch.len() });
        let engine_name = engine.name();
        let engine_cfg = engine.config_fingerprint();
        let mut first_pos: Vec<Option<(usize, usize)>> = vec![None; batch.len()];
        for &(idx, slot) in links {
            if first_pos[slot].is_none() {
                first_pos[slot] = Some((idx / cols, idx % cols));
            }
        }
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(batch.len());
        for (slot, matrix) in batch.matrices().iter().enumerate() {
            let pos = first_pos[slot].expect("every batch slot has a consumer");
            let price_key = (engine_name, engine_cfg, pos.0, pos.1);
            let warm = self
                .warm_prices
                .get(&price_key)
                .filter(|p| p.len() == matrix.cols())
                .map(|p| p.as_slice());
            if warm.is_some() {
                self.stats.warm_starts += 1;
            }
            let (sol, prices) = engine.solve_min_cost_warm(matrix, warm);
            if let Some(prices) = prices {
                self.warm_prices.insert(price_key, prices);
            }
            out.push(Arc::new(sol));
        }
        self.stats.solved += batch.len();
        self.stats.solve_wall_s += t0.elapsed().as_secs_f64();
        out
    }

    fn cache_insert(&mut self, key: PairKey, sol: Arc<AssignmentResult>) {
        let weight = key.prev.len() + key.next.len();
        if self.cache.len() >= self.cfg.max_cache_entries
            || self.cache_slots + weight > self.cfg.max_cache_slots
        {
            // Epoch reset: simpler than LRU bookkeeping and bounds memory;
            // a steady-state round refills its working set in one pass.
            self.cache.clear();
            self.cache_slots = 0;
        }
        if self.cache.insert(key, sol).is_none() {
            self.cache_slots += weight;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matching::{AuctionEngine, HungarianEngine};

    fn sig(slots: &[&[(u64, usize)]]) -> Arc<NodeSig> {
        Arc::new(slots.iter().map(|s| s.to_vec()).collect())
    }

    /// 1 busy node (jobs 1, 2) + `empties` empty nodes, 2 GPUs per node.
    fn sigs_sparse(empties: usize) -> Vec<Arc<NodeSig>> {
        let mut v = vec![sig(&[&[(1, 1)], &[(2, 1)]])];
        for _ in 0..empties {
            v.push(sig(&[&[], &[]]));
        }
        v
    }

    fn reference_round(prev: &[Arc<NodeSig>], next: &[Arc<NodeSig>]) -> NodePairRound {
        let mut svc = MatchingService::new(ServiceConfig::sequential_reference());
        svc.node_pair_round(&HungarianEngine, prev, next)
    }

    fn assert_rounds_match(a: &NodePairRound, b: &NodePairRound, n: usize, m: usize) {
        for k in 0..n {
            for l in 0..m {
                assert_eq!(
                    a.node_cost.get(k, l).to_bits(),
                    b.node_cost.get(k, l).to_bits(),
                    "cost diverged at pair ({k},{l})"
                );
            }
        }
    }

    #[test]
    fn pruning_skips_empty_pairs_with_exact_costs() {
        let prev = sigs_sparse(3);
        let next = sigs_sparse(3);
        let mut svc = MatchingService::with_defaults();
        let round = svc.node_pair_round(&HungarianEngine, &prev, &next);
        let stats = svc.take_round_stats();
        assert_eq!(stats.instances, 16);
        // 3×3 empty×empty + 3+3 empty×busy pairs prune; busy×busy solves.
        assert_eq!(stats.pruned, 15);
        assert_eq!(stats.solved, 1);
        let reference = reference_round(&prev, &next);
        assert_rounds_match(&round, &reference, 4, 4);
    }

    #[test]
    fn dedup_collapses_identical_instances() {
        // Two identical busy prev nodes against two identical busy next
        // nodes: 4 instances, 1 unique solve.
        let busy = sig(&[&[(1, 1)], &[(2, 2)]]);
        let prev = vec![busy.clone(), busy.clone()];
        let next = vec![busy.clone(), busy.clone()];
        let mut svc = MatchingService::with_defaults();
        let round = svc.node_pair_round(&HungarianEngine, &prev, &next);
        let stats = svc.take_round_stats();
        assert_eq!(stats.instances, 4);
        assert_eq!(stats.built, 1);
        assert_eq!(stats.deduped, 3);
        assert_eq!(stats.solved, 1);
        let reference = reference_round(&prev, &next);
        assert_rounds_match(&round, &reference, 2, 2);
        // Deduped pairs share the identical assignment object.
        for k in 0..2 {
            for l in 0..2 {
                assert!(Arc::ptr_eq(
                    round.assignment(0, 0).unwrap(),
                    round.assignment(k, l).unwrap()
                ));
            }
        }
    }

    #[test]
    fn cache_hits_across_rounds_and_invalidates_on_change() {
        let prev = vec![sig(&[&[(1, 1)], &[(2, 1)]]), sig(&[&[(3, 1)], &[]])];
        let next = prev.clone();
        let mut svc = MatchingService::with_defaults();
        svc.node_pair_round(&HungarianEngine, &prev, &next);
        let first = svc.take_round_stats();
        assert!(first.solved > 0);
        // Same contents again: all non-pruned pairs are cache hits.
        let round2 = svc.node_pair_round(&HungarianEngine, &prev, &next);
        let second = svc.take_round_stats();
        assert_eq!(second.solved, 0);
        assert_eq!(second.cache_hits + second.pruned + second.deduped, 4);
        let reference = reference_round(&prev, &next);
        assert_rounds_match(&round2, &reference, 2, 2);
        // Changed content must not hit the stale entry.
        let changed = vec![sig(&[&[(1, 1)], &[(9, 1)]]), sig(&[&[(3, 1)], &[]])];
        let round3 = svc.node_pair_round(&HungarianEngine, &prev, &changed);
        let reference3 = reference_round(&prev, &changed);
        assert_rounds_match(&round3, &reference3, 2, 2);
    }

    #[test]
    fn parallel_pool_matches_sequential_batch() {
        // Many distinct busy pairs with the pool forced on.
        let prev: Vec<Arc<NodeSig>> =
            (0..6).map(|i| sig(&[&[(i, 1)], &[(100 + i, 2)]])).collect();
        let next: Vec<Arc<NodeSig>> =
            (0..6).map(|i| sig(&[&[(200 + i, 1)], &[(i, 1)]])).collect();
        let mut par = MatchingService::new(ServiceConfig {
            parallel_threshold: 1,
            ..Default::default()
        });
        let a = par.node_pair_round(&HungarianEngine, &prev, &next);
        let b = reference_round(&prev, &next);
        assert_rounds_match(&a, &b, 6, 6);
    }

    #[test]
    fn arena_pool_is_reused_across_rounds() {
        let prev: Vec<Arc<NodeSig>> =
            (0..5).map(|i| sig(&[&[(i, 1)], &[(300 + i, 1)]])).collect();
        let next: Vec<Arc<NodeSig>> =
            (0..5).map(|i| sig(&[&[(400 + i, 1)], &[(i, 2)]])).collect();
        let mut svc = MatchingService::new(ServiceConfig {
            cache: false, // force re-solves so the arenas are exercised
            ..Default::default()
        });
        let a = svc.node_pair_round(&HungarianEngine, &prev, &next);
        assert!(
            !svc.scratch_pool.lock().unwrap().is_empty(),
            "solve arenas must be returned to the pool"
        );
        let b = svc.node_pair_round(&HungarianEngine, &prev, &next);
        assert_rounds_match(&a, &b, 5, 5);
        let reference = reference_round(&prev, &next);
        assert_rounds_match(&a, &reference, 5, 5);
        // Without the alloc_audit feature the kernel counter stays zero.
        if !crate::util::alloc::audit_enabled() {
            assert_eq!(svc.take_round_stats().kernel_allocs, 0);
        }
    }

    #[test]
    fn auction_engine_parity_on_node_pairs() {
        let prev = sigs_sparse(2);
        let next = vec![
            sig(&[&[(2, 1)], &[(9, 1)]]),
            sig(&[&[], &[]]),
            sig(&[&[(1, 1)], &[]]),
        ];
        let engine = AuctionEngine::default();
        let mut svc = MatchingService::with_defaults();
        let a = svc.node_pair_round(&engine, &prev, &next);
        let mut seq = MatchingService::new(ServiceConfig::sequential_reference());
        let b = seq.node_pair_round(&engine, &prev, &next);
        assert_rounds_match(&a, &b, 3, 3);
    }

    #[test]
    fn warm_start_preserves_costs() {
        // Warm-started auction solves must price every pair identically to
        // the cold run (assignments may legitimately differ).
        let prev: Vec<Arc<NodeSig>> =
            (0..3).map(|i| sig(&[&[(i, 1)], &[(50 + i, 1)]])).collect();
        let next: Vec<Arc<NodeSig>> =
            (0..3).map(|i| sig(&[&[(50 + i, 1)], &[(i, 1)]])).collect();
        let engine = AuctionEngine::default();
        let mut warm = MatchingService::new(ServiceConfig {
            warm_start: true,
            cache: false, // force re-solves so warm starts actually fire
            ..Default::default()
        });
        let w1 = warm.node_pair_round(&engine, &prev, &next);
        let s1 = warm.take_round_stats();
        assert_eq!(s1.warm_starts, 0, "no prices retained yet");
        let w2 = warm.node_pair_round(&engine, &prev, &next);
        let s2 = warm.take_round_stats();
        assert!(s2.warm_starts > 0, "second round should warm-start");
        let cold = reference_round(&prev, &next);
        assert_rounds_match(&w1, &cold, 3, 3);
        assert_rounds_match(&w2, &cold, 3, 3);
    }

    #[test]
    fn cache_eviction_bounds_memory() {
        let mut svc = MatchingService::new(ServiceConfig {
            max_cache_entries: 4,
            ..Default::default()
        });
        for i in 0..20u64 {
            let prev = vec![sig(&[&[(i, 1)], &[]])];
            let next = vec![sig(&[&[(1000 + i, 1)], &[]])];
            svc.node_pair_round(&HungarianEngine, &prev, &next);
        }
        assert!(svc.cache_len() <= 4);
    }

    #[test]
    fn cache_slot_budget_bounds_wide_entries() {
        // Whole-cluster (Algorithm 5) signatures are O(total GPUs) wide;
        // the slot budget must bound the cache even when the entry count
        // stays tiny.
        let mut svc = MatchingService::new(ServiceConfig {
            max_cache_slots: 10,
            ..Default::default()
        });
        for i in 0..10u64 {
            let wide = sig(&[&[(i, 1)], &[], &[], &[]]); // weight 4 + 4
            let other = sig(&[&[(100 + i, 1)], &[], &[], &[]]);
            svc.pair_assignment(&HungarianEngine, &wide, &other);
            assert_eq!(svc.cache_len(), 1, "slot budget must epoch-clear");
        }
    }

    #[test]
    fn cache_is_engine_keyed() {
        // Zero matrices are exactly where engines return different optimal
        // permutations (our Hungarian: identity; the auction: reversed).
        // One service used with both engines must keep their cached
        // assignments apart — each engine gets its own solve back.
        use crate::matching::pair_cost_matrix;
        let empty = sig(&[&[], &[], &[]]);
        let auction = AuctionEngine::default();
        let mut svc = MatchingService::with_defaults();
        let h = svc.pair_assignment(&HungarianEngine, &empty, &empty);
        let a = svc.pair_assignment(&auction, &empty, &empty);
        let h2 = svc.pair_assignment(&HungarianEngine, &empty, &empty);
        assert_eq!(h.row_to_col, h2.row_to_col, "hungarian entry stable");
        let matrix = pair_cost_matrix(&empty, &empty);
        assert_eq!(h.row_to_col, HungarianEngine.solve_min_cost(&matrix).row_to_col);
        assert_eq!(a.row_to_col, auction.solve_min_cost(&matrix).row_to_col);
    }

    #[test]
    fn pair_assignment_caches_pruned_pairs() {
        let empty = sig(&[&[], &[]]);
        let mut svc = MatchingService::with_defaults();
        let a = svc.pair_assignment(&HungarianEngine, &empty, &empty);
        let stats1 = svc.take_round_stats();
        assert_eq!(stats1.solved, 1);
        let b = svc.pair_assignment(&HungarianEngine, &empty, &empty);
        let stats2 = svc.take_round_stats();
        assert_eq!(stats2.solved, 0);
        assert_eq!(stats2.cache_hits, 1);
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    #[should_panic(expected = "same length")]
    fn unequal_gpu_lists_rejected() {
        let mut svc = MatchingService::with_defaults();
        let prev = vec![sig(&[&[], &[]])];
        let next = vec![sig(&[&[]])]; // one-slot node vs two-slot node
        svc.node_pair_round(&HungarianEngine, &prev, &next);
    }

    #[test]
    fn approximate_engine_disables_one_sided_pruning() {
        // The auction with `resolution: None` is only near-optimal, so the
        // exact one-sided closed forms must not be used for it — only the
        // engine-independent empty×empty prune may fire, and the serviced
        // result must still match the engine's own sequential solves.
        let prev = vec![sig(&[&[], &[]]), sig(&[&[(1, 1)], &[(2, 1)]])];
        let next = vec![sig(&[&[(1, 1)], &[(2, 1)]]), sig(&[&[], &[]])];
        let engine = AuctionEngine { resolution: None };
        assert!(!engine.exact_on_migration_costs());
        let mut svc = MatchingService::with_defaults();
        let a = svc.node_pair_round(&engine, &prev, &next);
        let stats = svc.take_round_stats();
        assert_eq!(stats.pruned, 1, "only empty×empty may prune: {stats:?}");
        let mut seq = MatchingService::new(ServiceConfig::sequential_reference());
        let b = seq.node_pair_round(&engine, &prev, &next);
        assert_rounds_match(&a, &b, 2, 2);
    }
}

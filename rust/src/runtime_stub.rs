//! Std-only stand-in for the PJRT runtime (`src/runtime/`), compiled when
//! the `pjrt` feature is off — i.e. when the `xla` crate from the
//! rust_pallas image is not available as a dependency.
//!
//! The stub mirrors the real module's public surface exactly, so every
//! consumer (coordinator, benches, integration tests, the matching-engine
//! comparison) compiles unchanged. Entry points that would touch PJRT —
//! [`Manifest::discover`], [`Manifest::load`], [`AotAssignmentEngine`]'s
//! constructors — return an error explaining that the runtime is not built,
//! which is the same signal the real module emits when `make artifacts` has
//! not run; all callers already handle it by skipping. Pure-CPU pieces with
//! no PJRT dependency ([`train::ParamState`], [`ModelSpec::checkpoint_bytes`])
//! keep their real implementations.

use anyhow::{anyhow, Result};

pub use assignment::AotAssignmentEngine;
pub use gp_artifact::GpArtifact;
pub use train::{ModelSpec, TrainSession};

use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Default artifacts directory relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";

fn unavailable<T>() -> Result<T> {
    Err(anyhow!(
        "PJRT runtime not built: this binary was compiled without the \
         `pjrt` feature (the `xla` crate is only available in the \
         rust_pallas image)"
    ))
}

/// Parsed `manifest.json` plus the artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    #[allow(dead_code)]
    root: Json,
}

impl Manifest {
    pub fn load(_dir: &Path) -> Result<Manifest> {
        unavailable()
    }

    /// Always errors in the stub: without PJRT there is nothing to execute
    /// the artifacts with, even if a manifest file exists on disk.
    pub fn discover() -> Result<Manifest> {
        unavailable()
    }

    pub fn artifact(&self, name: &str) -> Result<&Json> {
        Err(anyhow!("artifact '{name}' unavailable: PJRT runtime not built"))
    }

    pub fn artifact_names(&self) -> Vec<String> {
        Vec::new()
    }

    pub fn file_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

/// A thread-local PJRT CPU runtime (stub: cannot be constructed).
pub struct Runtime {
    pub manifest: Manifest,
}

impl Runtime {
    pub fn new(_manifest: Manifest) -> Result<Runtime> {
        unavailable()
    }

    pub fn discover() -> Result<Runtime> {
        unavailable()
    }
}

pub mod assignment {
    use std::sync::Mutex;

    use anyhow::Result;

    use crate::linalg::Matrix;
    use crate::matching::{AssignmentResult, MatchingEngine};

    use super::{unavailable, Manifest};

    /// Sizes the AOT artifacts were exported at (must match `aot.py`).
    pub const BUCKETS: [usize; 6] = [8, 16, 32, 64, 128, 256];

    /// `Send + Sync` handle to the solver thread (stub: unconstructible).
    pub struct AotAssignmentEngine {
        /// ε target resolution for exactness on quantized costs.
        pub resolution: f64,
        _solver: Mutex<()>,
    }

    impl AotAssignmentEngine {
        /// Spawn the solver thread and compile every bucket.
        pub fn start(_manifest: Manifest) -> Result<AotAssignmentEngine> {
            unavailable()
        }

        /// Convenience: discover artifacts and start.
        pub fn discover() -> Result<AotAssignmentEngine> {
            unavailable()
        }
    }

    impl MatchingEngine for AotAssignmentEngine {
        fn solve_min_cost(&self, _cost: &Matrix) -> AssignmentResult {
            unreachable!("AotAssignmentEngine cannot be constructed without the `pjrt` feature")
        }

        fn name(&self) -> &'static str {
            "aot-auction"
        }
    }
}

pub mod gp_artifact {
    use anyhow::Result;

    use super::{unavailable, Runtime};

    /// Handle to the compiled GP artifact (stub: unconstructible).
    pub struct GpArtifact {
        pub n_max: usize,
        pub dim: usize,
        pub num_queries: usize,
    }

    impl GpArtifact {
        pub fn load(_rt: &Runtime) -> Result<GpArtifact> {
            unavailable()
        }

        /// Posterior mean/variance at `queries` given `observations`.
        pub fn posterior(
            &self,
            _observations: &[(Vec<f64>, f64)],
            _queries: &[Vec<f64>],
        ) -> Result<Vec<(f64, f64)>> {
            unavailable()
        }
    }
}

pub mod train {
    use anyhow::Result;

    use crate::util::rng::Pcg64;

    use super::{unavailable, Runtime};

    /// Static description of one exported model size (from the manifest).
    #[derive(Debug, Clone)]
    pub struct ModelSpec {
        pub name: String,
        pub vocab: usize,
        pub seq_len: usize,
        pub batch: usize,
        pub num_params: usize,
        /// Per-tensor shapes, in ABI order.
        pub param_shapes: Vec<Vec<usize>>,
        pub init_file: String,
        pub train_step_file: String,
    }

    impl ModelSpec {
        /// Total checkpoint size in bytes (f32 params).
        pub fn checkpoint_bytes(&self) -> usize {
            self.num_params * 4
        }
    }

    /// A job's portable parameter state. Pure CPU data — the stub keeps the
    /// real implementation (the coordinator's checkpoint accounting and the
    /// `param_average_is_elementwise_mean` test use it).
    #[derive(Debug, Clone)]
    pub struct ParamState {
        /// One flat f32 buffer per parameter tensor, ABI order.
        pub tensors: Vec<Vec<f32>>,
    }

    impl ParamState {
        /// Element-wise average of replica states (the coordinator's
        /// round-granular data-parallel reduction).
        pub fn average(replicas: &[ParamState]) -> ParamState {
            assert!(!replicas.is_empty());
            let mut out = replicas[0].clone();
            for r in &replicas[1..] {
                for (o, t) in out.tensors.iter_mut().zip(&r.tensors) {
                    for (a, b) in o.iter_mut().zip(t) {
                        *a += *b;
                    }
                }
            }
            let k = replicas.len() as f32;
            for t in &mut out.tensors {
                for a in t {
                    *a /= k;
                }
            }
            out
        }
    }

    /// Compiled executables + helpers for one model size (stub:
    /// unconstructible — `load` always errors).
    pub struct TrainSession {
        pub spec: ModelSpec,
    }

    impl TrainSession {
        pub fn load(_rt: &Runtime, _model_name: &str) -> Result<TrainSession> {
            unavailable()
        }

        /// Run the AOT `init` computation.
        pub fn init_params(&self, _seed: i32) -> Result<ParamState> {
            unavailable()
        }

        /// One SGD step on a token batch; returns the loss.
        pub fn step(&self, _params: &mut ParamState, _tokens: &[i32]) -> Result<f32> {
            unavailable()
        }

        /// Synthetic learnable batch matching `model.synthetic_batch`.
        pub fn synthetic_batch(&self, _rng: &mut Pcg64) -> Vec<i32> {
            Vec::new()
        }
    }
}

//! Composed schedulers: the round-based decision interface plus the
//! schedulers evaluated in §6 — Tesserae-T / Tesserae-FTF, Tiresias,
//! Tiresias (Single), Gavel, Gavel-FTF and POP.

pub mod gavel;
pub mod pipeline;
pub mod pop;
pub mod tesserae;

pub use gavel::{GavelObjective, GavelScheduler};
pub use pipeline::{run_round, RoundContext, Stage, StageProvider};
pub use pop::PopScheduler;
pub use tesserae::TesseraeScheduler;

use std::collections::BTreeMap;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::jobs::{JobId, ParallelismStrategy};
use crate::matching::MatchingServiceStats;
use crate::policies::JobInfo;

/// Everything a scheduler sees at the start of a round.
pub struct RoundInput<'a> {
    pub now: f64,
    pub round: u64,
    pub active: &'a [JobInfo],
    /// Previous round's *physical* plan (for migration minimization).
    pub prev_plan: &'a PlacementPlan,
    pub spec: &'a ClusterSpec,
    /// Per-GPU health when at least one GPU is down; `None` on a fully
    /// healthy cluster keeps every scheduler on the pre-fault code path
    /// (the fault-rate-0 bit-parity contract).
    pub health: Option<&'a crate::faults::ClusterHealth>,
}

/// Decision-time breakdown (Fig. 14(b)).
#[derive(Debug, Clone, Copy, Default)]
pub struct DecisionTimings {
    /// Per-pipeline-stage wall clock (Estimate / Schedule / Pack /
    /// Migrate / Commit), written by the pipeline driver; sums to
    /// `total_s` within driver-overhead tolerance (debug-asserted in
    /// [`pipeline::run_round`]).
    pub stage_s: [f64; Stage::COUNT],
    /// Legacy Fig. 14(b) bucket: estimation + scheduling (priority order /
    /// LP solve and the allocation walk — the Estimate and Schedule
    /// stages together).
    pub scheduling_s: f64,
    pub packing_s: f64,
    pub migration_s: f64,
    pub total_s: f64,
    /// The round's matching-service counters: instances generated, how
    /// many were pruned/deduped/cache-hit instead of solved, and the wall
    /// time inside engine solves.
    pub matching: MatchingServiceStats,
}

impl DecisionTimings {
    /// Wall clock of one pipeline stage.
    pub fn stage(&self, stage: Stage) -> f64 {
        self.stage_s[stage.index()]
    }
}

/// A scheduler's output for one round.
#[derive(Debug, Clone)]
pub struct RoundDecision {
    /// Physical placement for the next round (post migration remap).
    pub plan: PlacementPlan,
    /// Parallelism strategy per placed job.
    pub strategies: BTreeMap<JobId, ParallelismStrategy>,
    /// (placed, pending) pairs sharing GPUs this round.
    pub packed_pairs: Vec<(JobId, JobId)>,
    /// Jobs migrated relative to the previous round (Definition 1).
    pub migrations: usize,
    /// True when a pipeline stage failed and the driver substituted the
    /// degraded-mode fallback (previous plan minus finished jobs and dead
    /// GPUs) instead of a freshly computed decision.
    pub degraded: bool,
    pub timings: DecisionTimings,
}

/// A round-based cluster scheduler (§3.2).
pub trait Scheduler: Send {
    fn name(&self) -> String;
    fn decide(&mut self, input: &RoundInput) -> RoundDecision;

    /// Hard cross-round state worth persisting in a crash snapshot
    /// (shard routing stickiness, breaker state, …). `None` — the
    /// default — means the scheduler is decision-equivalent from a cold
    /// start: soft caches (`LpCache`, matching caches) are deliberately
    /// *not* snapshotted and rebuild cold on restore, which the
    /// warm-vs-cold parity property tests keep bit-identical.
    fn snapshot_state(&self) -> Option<crate::util::json::Json> {
        None
    }

    /// Restore state produced by [`Scheduler::snapshot_state`]. The
    /// default ignores it (nothing was snapshotted).
    fn restore_state(&mut self, _state: &crate::util::json::Json) {}
}

/// Shared helper: assign each job its best isolated strategy according to
/// `source` (packed jobs are overridden by the packing policy). The
/// per-job candidate enumeration is independent work, so it shards across
/// the process-wide worker pool; results are keyed by job id, making the
/// map identical for any thread budget.
pub(crate) fn best_isolated_strategies(
    infos: &[&JobInfo],
    source: &dyn crate::estimator::ThroughputSource,
) -> BTreeMap<JobId, ParallelismStrategy> {
    crate::util::pool::WorkerPool::global()
        .map(infos, 0, 64, |_, j| {
            let best = ParallelismStrategy::candidates(j.model, j.num_gpus)
                .into_iter()
                .max_by(|a, b| {
                    source
                        .isolated_tput(j.model, a, j.num_gpus)
                        .partial_cmp(&source.isolated_tput(j.model, b, j.num_gpus))
                        .unwrap()
                })
                .unwrap_or(ParallelismStrategy::DataParallel);
            (j.id, best)
        })
        .into_iter()
        .collect()
}

//! The staged round pipeline: one scheduling round decomposed into typed
//! stages — `Estimate → Schedule → Pack → Migrate → Commit` — driven by
//! [`run_round`] over a [`StageProvider`]. Every scheduler
//! (`TesseraeScheduler`, `GavelScheduler`, `PopScheduler`) and the
//! real-execution coordinator runs through this driver; `decide()` is a
//! thin wrapper.
//!
//! Stage semantics (providers may leave stages empty, never reorder them):
//!
//! * **Estimate** — per-job inputs for the round: the scheduling policy's
//!   priority order, LP objective weights, POP's partition split. Sharded
//!   per-job work (via [`crate::util::pool::WorkerPool`]) lives here and
//!   in Schedule.
//! * **Schedule** — turn estimates into a logical allocation: the
//!   no-packing allocation walk + per-placed-job strategy selection, the
//!   Gavel LP solve + realization, POP's partition solves + stitch.
//! * **Pack** — GPU sharing: Algorithm 4's matching (Tesserae) or the LP's
//!   chosen pair variables (Gavel).
//! * **Migrate** — physical realization against the previous round's plan
//!   (Algorithms 2+3 / 5 / the Gavel baseline), producing the
//!   [`MigrationOutcome`].
//! * **Commit** — assemble the [`RoundDecision`], including the legacy
//!   `scheduling_s`/`packing_s`/`migration_s` timing fields.
//!
//! The [`RoundContext`] carries the artifacts between stages: the ordered
//! job window, the allocation (placed/pending + evolving plan), the packed
//! pairs and the migration outcome. Scheduler-specific scratch (LP scores,
//! partition groups) stays inside the provider.
//!
//! The driver measures per-stage wall clock into
//! `DecisionTimings::stage_s` (one entry per stage, Fig. 14(b)'s new
//! columns) and debug-asserts the stage times account for `total_s`.
//! Determinism contract: a provider's stages must produce bit-identical
//! artifacts for any worker-pool budget — the pipeline introduces *where*
//! work happens, never *what* is computed. This staging is also the seam
//! for overlapping round `r+1`'s Estimate with round `r`'s Migrate tail.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

use crate::cluster::PlacementPlan;
use crate::jobs::{JobId, ParallelismStrategy};
use crate::obs;
use crate::obs::{metrics, recorder, span};
use crate::policies::placement::MigrationOutcome;
use crate::policies::JobInfo;
use crate::recovery::watchdog;

use super::{DecisionTimings, RoundDecision, RoundInput};

/// Env var for deterministic stage-failure injection: a comma-separated
/// list of `"<stage>@<round>"` entries (e.g. `pack@3` or
/// `pack@3,migrate@5`) panics those stages of those rounds, and the
/// every-round form `"<stage>@*"` (e.g. `pack@*`) panics the stage of
/// *every* round — the knob that drives circuit-breaker
/// trip/cooldown/half-open tests deterministically. Exercises the
/// degraded-mode fallback end to end without patching any provider.
pub const FAULT_INJECT_ENV: &str = "TESSERAE_FAULT_INJECT_STAGE";

/// The pipeline's typed stages, in execution order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Estimate,
    Schedule,
    Pack,
    Migrate,
    Commit,
}

impl Stage {
    /// Number of stages (the width of `DecisionTimings::stage_s`).
    pub const COUNT: usize = 5;

    /// All stages in execution order.
    pub const ALL: [Stage; Stage::COUNT] = [
        Stage::Estimate,
        Stage::Schedule,
        Stage::Pack,
        Stage::Migrate,
        Stage::Commit,
    ];

    /// Index into `DecisionTimings::stage_s`.
    pub fn index(self) -> usize {
        match self {
            Stage::Estimate => 0,
            Stage::Schedule => 1,
            Stage::Pack => 2,
            Stage::Migrate => 3,
            Stage::Commit => 4,
        }
    }

    /// Column/report label.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Estimate => "estimate",
            Stage::Schedule => "schedule",
            Stage::Pack => "pack",
            Stage::Migrate => "migrate",
            Stage::Commit => "commit",
        }
    }
}

/// Artifacts carried between stages of one round. Providers fill the
/// fields their stages produce and read what earlier stages left.
pub struct RoundContext<'a> {
    pub input: &'a RoundInput<'a>,
    /// Estimate: priority order as indices into `input.active`.
    pub order: Vec<usize>,
    /// Schedule: id → info for the round's job window, built once and
    /// shared with later stages (Pack resolves placed/pending infos
    /// through it instead of rebuilding the map).
    pub by_id: BTreeMap<JobId, &'a JobInfo>,
    /// Schedule: jobs placed / left pending, in priority order.
    pub placed: Vec<JobId>,
    pub pending: Vec<JobId>,
    /// Schedule → Pack: the evolving *logical* plan.
    pub plan: PlacementPlan,
    /// Final per-job strategies for the decision.
    pub strategies: BTreeMap<JobId, ParallelismStrategy>,
    /// Pack: (placed, pending) pairs sharing GPUs this round.
    pub packed_pairs: Vec<(JobId, JobId)>,
    /// Migrate: the physical realization (`None` for providers that remap
    /// inline, e.g. POP's pre-stitched partition plans).
    pub outcome: Option<MigrationOutcome>,
    /// Migrate: Definition-1 migration count when `outcome` is `None`.
    pub migrations: usize,
    /// Per-stage wall clock, written by the driver as stages complete —
    /// `commit` can already read the first four entries.
    pub stage_s: [f64; Stage::COUNT],
}

impl<'a> RoundContext<'a> {
    pub fn new(input: &'a RoundInput<'a>) -> RoundContext<'a> {
        RoundContext {
            input,
            order: Vec::new(),
            by_id: BTreeMap::new(),
            placed: Vec::new(),
            pending: Vec::new(),
            plan: PlacementPlan::new(input.spec.total_gpus()),
            strategies: BTreeMap::new(),
            packed_pairs: Vec::new(),
            outcome: None,
            migrations: 0,
            stage_s: [0.0; Stage::COUNT],
        }
    }
}

/// A scheduler expressed as pipeline stages. `decide()` becomes
/// `pipeline::run_round(self, input)`.
pub trait StageProvider {
    fn estimate(&mut self, cx: &mut RoundContext);
    fn schedule(&mut self, cx: &mut RoundContext);
    fn pack(&mut self, cx: &mut RoundContext);
    fn migrate(&mut self, cx: &mut RoundContext);
    /// Assemble the decision. The driver overwrites `stage_s` and
    /// `total_s` on the returned timings; the provider is responsible for
    /// the legacy breakdown fields and the matching-service stats.
    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision;
    /// Called by the driver after a stage panicked, before the
    /// degraded-mode fallback is returned: discard any scratch the aborted
    /// round may have left half-updated (e.g. a warm LP cache) so the next
    /// round starts from a consistent state. Default: nothing to discard.
    fn reset_after_failure(&mut self) {}
}

/// Rounds currently in flight, process-wide. POP's sub-schedulers drive
/// nested `run_round` calls on worker-pool threads; only the *outermost*
/// round drains the span sink and records into the flight recorder, so a
/// round capture always covers the whole decision (sub-round spans land
/// inside it). Only touched when telemetry is enabled.
static ROUND_DEPTH: AtomicUsize = AtomicUsize::new(0);

/// Registry names for the per-stage wall-clock histograms.
const STAGE_METRIC: [&str; Stage::COUNT] = [
    "round.estimate_s",
    "round.schedule_s",
    "round.pack_s",
    "round.migrate_s",
    "round.commit_s",
];

/// Fold one finished round into the metrics registry: per-stage and total
/// wall clocks plus the round's matching-service counters (the scattered
/// `MatchingServiceStats` fields, absorbed behind the one snapshot).
/// Gated on [`obs::enabled`] inside every registry call.
fn publish_round_metrics(decision: &RoundDecision) {
    metrics::counter_add("rounds", 1);
    metrics::observe("round.total_s", decision.timings.total_s);
    for stage in Stage::ALL {
        metrics::observe(STAGE_METRIC[stage.index()], decision.timings.stage_s[stage.index()]);
    }
    let m = &decision.timings.matching;
    metrics::counter_add("matching.instances", m.instances as u64);
    metrics::counter_add("matching.pruned", m.pruned as u64);
    metrics::counter_add("matching.deduped", m.deduped as u64);
    metrics::counter_add("matching.cache_hits", m.cache_hits as u64);
    metrics::counter_add("matching.built", m.built as u64);
    metrics::counter_add("matching.solved", m.solved as u64);
    metrics::counter_add("matching.warm_starts", m.warm_starts as u64);
    metrics::counter_add("matching.kernel_allocs", m.kernel_allocs as u64);
    if m.solved > 0 {
        metrics::observe("matching.solve_wall_s", m.solve_wall_s);
    }
    metrics::counter_add("round.migrations", decision.migrations as u64);
}

/// RAII balance for [`ROUND_DEPTH`]: the decrement must run even when a
/// stage panics and the round unwinds into the degraded fallback —
/// otherwise every later round on this process would look nested and the
/// flight recorder would go silent.
struct DepthGuard {
    outermost: bool,
}

impl DepthGuard {
    fn acquire() -> DepthGuard {
        let depth = ROUND_DEPTH.fetch_add(1, Ordering::AcqRel);
        DepthGuard { outermost: depth == 0 }
    }
}

impl Drop for DepthGuard {
    fn drop(&mut self) {
        ROUND_DEPTH.fetch_sub(1, Ordering::AcqRel);
    }
}

/// True when any [`FAULT_INJECT_ENV`] entry names this `(stage, round)` —
/// or the stage with the every-round wildcard `@*`. Read per call (not
/// cached): the var costs ~100ns against stage bodies measured in
/// microseconds, and tests flip it at runtime.
fn injected_failure(stage: Stage, round: u64) -> bool {
    match std::env::var(FAULT_INJECT_ENV) {
        Ok(v) => injection_spec_hits(&v, stage, round),
        Err(_) => false,
    }
}

/// One env value against one `(stage, round)` — split out so the
/// list/wildcard grammar is testable without mutating the process
/// environment (a wildcard entry would degrade every concurrent test's
/// rounds for as long as it was set).
fn injection_spec_hits(spec: &str, stage: Stage, round: u64) -> bool {
    spec.split(',').any(|entry| match entry.trim().split_once('@') {
        Some((s, "*")) => s == stage.name(),
        Some((s, r)) => s == stage.name() && r.parse() == Ok(round),
        None => false,
    })
}

/// Run every stage plus commit, timing each against one clock. Split out
/// of [`run_round`] so the driver can `catch_unwind` the whole computed
/// path as a unit.
fn drive_stages<P: StageProvider + ?Sized>(
    provider: &mut P,
    input: &RoundInput,
    t_total: Instant,
) -> RoundDecision {
    // Stage times are differences of boundary timestamps on one clock, so
    // they sum to the measured total by construction — OS preemption
    // anywhere lands inside some stage instead of an unattributed gap
    // (the context setup before the first boundary is attributed to
    // Estimate).
    let mut cx = RoundContext::new(input);
    let mut last_s = 0.0f64;
    for stage in [Stage::Estimate, Stage::Schedule, Stage::Pack, Stage::Migrate] {
        crate::obs_span!(stage.name(), { round: input.round });
        // Arm this thread's watchdog deadline for the stage (a no-op when
        // no budget is configured); overruns trip a `DeadlineExceeded`
        // panic at the next cooperative checkpoint, which the caller's
        // catch-unwind turns into a `deadline` degraded round.
        let _deadline = watchdog::arm_stage(stage.name());
        if injected_failure(stage, input.round) {
            panic!("injected failure: stage {} round {}", stage.name(), input.round);
        }
        match stage {
            Stage::Estimate => provider.estimate(&mut cx),
            Stage::Schedule => provider.schedule(&mut cx),
            Stage::Pack => provider.pack(&mut cx),
            Stage::Migrate => provider.migrate(&mut cx),
            Stage::Commit => unreachable!("commit is driven separately"),
        }
        // Guaranteed per-stage check even when the stage body never
        // reached a pool or LP checkpoint.
        watchdog::checkpoint();
        let boundary_s = t_total.elapsed().as_secs_f64();
        cx.stage_s[stage.index()] = boundary_s - last_s;
        last_s = boundary_s;
    }
    let mut decision = {
        crate::obs_span!(Stage::Commit.name(), { round: input.round });
        let _deadline = watchdog::arm_stage(Stage::Commit.name());
        if injected_failure(Stage::Commit, input.round) {
            panic!("injected failure: stage commit round {}", input.round);
        }
        let decision = provider.commit(&mut cx);
        watchdog::checkpoint();
        decision
    };
    cx.stage_s[Stage::Commit.index()] = t_total.elapsed().as_secs_f64() - last_s;
    decision.timings.stage_s = cx.stage_s;
    decision.timings.total_s = t_total.elapsed().as_secs_f64();
    // The five stages are the whole round; only the final total_s read
    // sits outside the last boundary, so the sum is exact up to that one
    // instant (plus float rounding).
    let staged: f64 = cx.stage_s.iter().sum();
    debug_assert!(
        decision.timings.total_s - staged <= 1e-3 + 0.01 * decision.timings.total_s,
        "stage times must sum to the round total: {staged}s of {}s",
        decision.timings.total_s
    );
    decision
}

/// Degraded mode (the fault-tolerance contract): when a stage fails, the
/// round still returns a *valid* decision — the previous committed plan
/// minus jobs that left the window and minus anything touching a dead
/// GPU. Surviving jobs keep their GPUs (zero migrations by construction),
/// strategies fall back to the simulator's data-parallel default, and the
/// decision is flagged `degraded` so callers can count and re-plan next
/// round.
fn degraded_decision(
    input: &RoundInput,
    payload: &(dyn std::any::Any + Send),
    t_total: Instant,
) -> RoundDecision {
    // A watchdog trip carries a typed payload; everything else is an
    // ordinary stage panic. The distinction is observable (counter +
    // flight-dump context) because a hung stage and a crashing stage call
    // for different operator responses.
    let (reason, msg) = match payload.downcast_ref::<watchdog::DeadlineExceeded>() {
        Some(d) => (
            "deadline",
            format!("stage {} exceeded its {}ms budget", d.stage, d.budget_ms),
        ),
        None => (
            "panic",
            payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string()),
        ),
    };
    metrics::counter_add("round.degraded", 1);
    if reason == "deadline" {
        metrics::counter_add("round.degraded_deadline", 1);
    }
    crate::obs_log!(
        warn,
        "round {}: stage failure ({reason}), falling back to previous plan: {msg}",
        input.round
    );
    recorder::dump_on_failure(&format!("degraded round {} ({reason}): {msg}", input.round));

    let mut plan = input.prev_plan.clone();
    let active: BTreeSet<JobId> = input.active.iter().map(|j| j.id).collect();
    let stale: BTreeSet<JobId> = plan
        .jobs()
        .into_iter()
        .filter(|j| !active.contains(j))
        .collect();
    if !stale.is_empty() {
        plan.remove_jobs(&stale);
    }
    if let Some(h) = input.health {
        let mut on_dead = BTreeSet::new();
        for g in h.dead_gpus() {
            on_dead.extend(plan.jobs_on(g).iter().copied());
        }
        if !on_dead.is_empty() {
            plan.remove_jobs(&on_dead);
        }
    }
    debug_assert!(plan.validate().is_ok());
    // Survivors sit exactly where they were, so this is zero — computed
    // (not hardcoded) to keep the simulator's plan-diff cross-check honest.
    let migrations = plan.migrations_from(input.prev_plan);
    RoundDecision {
        plan,
        strategies: BTreeMap::new(),
        packed_pairs: Vec::new(),
        migrations,
        degraded: true,
        timings: DecisionTimings {
            total_s: t_total.elapsed().as_secs_f64(),
            ..DecisionTimings::default()
        },
    }
}

/// Drive one round through the staged pipeline, timing each stage. A
/// panic in any stage (or commit) is caught and answered with the
/// degraded-mode fallback from [`degraded_decision`] — a round never
/// takes the process down with it.
pub fn run_round<P: StageProvider + ?Sized>(
    provider: &mut P,
    input: &RoundInput,
) -> RoundDecision {
    // Telemetry state is sampled once per round: the enabled flag cannot
    // flip mid-round for this call, and when off the only cost below is
    // this one relaxed load per gate.
    let telemetry = obs::enabled();
    // Metric deltas are only meaningful for the outermost round.
    let depth = telemetry.then(DepthGuard::acquire);
    let base = match &depth {
        Some(g) if g.outermost => Some(metrics::snapshot()),
        _ => None,
    };
    let round_span = telemetry.then(|| {
        span::SpanGuard::begin(
            "round",
            vec![
                ("round", span::ArgValue::from(input.round)),
                ("jobs", span::ArgValue::from(input.active.len())),
            ],
        )
    });
    let t_total = Instant::now();
    // `AssertUnwindSafe`: on the Err path the provider is only touched
    // through `reset_after_failure`, whose contract is exactly "make any
    // broken invariants whole"; everything else borrowed here is read-only.
    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        drive_stages(provider, input, t_total)
    }));
    let decision = match attempt {
        Ok(decision) => decision,
        Err(payload) => {
            provider.reset_after_failure();
            degraded_decision(input, payload.as_ref(), t_total)
        }
    };
    // Close the round span *before* draining so it lands in this round's
    // capture, then record the round into the flight recorder.
    drop(round_span);
    if let Some(base) = base {
        publish_round_metrics(&decision);
        let metrics_delta = metrics::snapshot().delta_since(&base);
        let spans = span::drain_events();
        recorder::record_round(recorder::RoundRecord {
            round: input.round,
            label: short_type_name::<P>().to_string(),
            total_s: decision.timings.total_s,
            spans,
            metrics_delta,
        });
    }
    decision
}

/// "tesserae::schedulers::pop::PopScheduler" → "PopScheduler" (the flight
/// recorder's round label).
fn short_type_name<P: ?Sized>() -> &'static str {
    let full = std::any::type_name::<P>();
    full.rsplit("::").next().unwrap_or(full)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuType};
    use crate::schedulers::DecisionTimings;

    /// Minimal provider: no-op stages, empty decision.
    struct Noop;

    impl StageProvider for Noop {
        fn estimate(&mut self, _cx: &mut RoundContext) {}
        fn schedule(&mut self, cx: &mut RoundContext) {
            cx.placed.clear();
        }
        fn pack(&mut self, _cx: &mut RoundContext) {}
        fn migrate(&mut self, _cx: &mut RoundContext) {}
        fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
            RoundDecision {
                plan: cx.plan.clone(),
                strategies: cx.strategies.clone(),
                packed_pairs: cx.packed_pairs.clone(),
                migrations: cx.migrations,
                degraded: false,
                timings: DecisionTimings::default(),
            }
        }
    }

    #[test]
    fn driver_times_every_stage_and_total() {
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let prev = crate::cluster::PlacementPlan::new(2);
        let input = RoundInput {
            now: 0.0,
            round: 0,
            active: &[],
            prev_plan: &prev,
            spec: &spec,
            health: None,
        };
        let d = run_round(&mut Noop, &input);
        assert!(d.timings.total_s > 0.0);
        assert!(d.timings.stage_s.iter().all(|&s| s >= 0.0));
        let staged: f64 = d.timings.stage_s.iter().sum();
        assert!(staged <= d.timings.total_s);
        assert!(d.plan.jobs().is_empty());
    }

    #[test]
    fn telemetry_round_capture_has_all_stage_spans() {
        let _guard = crate::obs::enabled_guard(true);
        crate::obs::span::drain_events();
        crate::obs::recorder::clear();
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let prev = crate::cluster::PlacementPlan::new(2);
        let input = RoundInput {
            now: 0.0,
            round: 7,
            active: &[],
            prev_plan: &prev,
            spec: &spec,
            health: None,
        };
        let _ = run_round(&mut Noop, &input);
        // Other tests' rounds may interleave while telemetry is on; find
        // ours rather than assuming it is the latest.
        let rec = crate::obs::recorder::rounds()
            .into_iter()
            .rev()
            .find(|r| r.label == "Noop" && r.round == 7)
            .expect("round recorded");
        let names: Vec<&str> = rec.spans.iter().map(|e| e.name).collect();
        for want in ["round", "estimate", "schedule", "pack", "migrate", "commit"] {
            assert!(names.contains(&want), "missing span {want} in {names:?}");
        }
        // Published metrics surfaced in the round's delta (≥, not ==:
        // concurrent rounds can publish inside our window).
        assert!(rec.metrics_delta.counters.get("rounds").copied().unwrap_or(0) >= 1);
        assert!(rec.metrics_delta.histograms.contains_key("round.total_s"));
        crate::obs::recorder::clear();
    }

    /// Panics in `pack`; records whether the driver asked for a reset.
    struct Exploding {
        resets: usize,
    }

    impl StageProvider for Exploding {
        fn estimate(&mut self, _cx: &mut RoundContext) {}
        fn schedule(&mut self, _cx: &mut RoundContext) {}
        fn pack(&mut self, _cx: &mut RoundContext) {
            panic!("pack stage exploded");
        }
        fn migrate(&mut self, _cx: &mut RoundContext) {}
        fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
            RoundDecision {
                plan: cx.plan.clone(),
                strategies: cx.strategies.clone(),
                packed_pairs: cx.packed_pairs.clone(),
                migrations: cx.migrations,
                degraded: false,
                timings: DecisionTimings::default(),
            }
        }
        fn reset_after_failure(&mut self) {
            self.resets += 1;
        }
    }

    fn job_info(id: u64) -> crate::policies::JobInfo {
        crate::policies::JobInfo {
            id,
            model: crate::jobs::ModelKind::ResNet50,
            num_gpus: 1,
            arrival_time: 0.0,
            attained_service: 0.0,
            total_iters: 100.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 0.0,
            iso_tput: 10.0,
        }
    }

    #[test]
    fn stage_panic_falls_back_to_previous_plan() {
        let spec = ClusterSpec::new(1, 4, GpuType::A100);
        let mut prev = crate::cluster::PlacementPlan::new(4);
        prev.place(1, &[0]);
        prev.place(2, &[1]); // finished: not in the active window
        prev.place(3, &[2]); // on the GPU that just died
        let mut health = crate::faults::ClusterHealth::new(4);
        health.fail_gpu(2);
        let active = vec![job_info(1), job_info(3)];
        let input = RoundInput {
            now: 0.0,
            round: 5,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: Some(&health),
        };
        let mut provider = Exploding { resets: 0 };
        let d = run_round(&mut provider, &input);
        assert!(d.degraded, "stage panic must yield the degraded fallback");
        assert_eq!(provider.resets, 1, "driver must reset the provider");
        d.plan.validate().unwrap();
        health.validate_plan(&d.plan).unwrap();
        // Job 1 holds its slot; the finished job and the dead GPU's job
        // are gone; nothing migrated.
        assert_eq!(d.plan.gpus_of(1), vec![0]);
        assert!(!d.plan.jobs().contains(&2));
        assert!(!d.plan.jobs().contains(&3));
        assert_eq!(d.migrations, 0);
        assert!(d.timings.total_s > 0.0);
    }

    #[test]
    fn injected_failure_env_hits_only_the_named_round() {
        // Unique round number so parallel tests can't collide with the
        // brief window this env var is set.
        std::env::set_var(FAULT_INJECT_ENV, "schedule@424242");
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let prev = crate::cluster::PlacementPlan::new(2);
        let mut input = RoundInput {
            now: 0.0,
            round: 424242,
            active: &[],
            prev_plan: &prev,
            spec: &spec,
            health: None,
        };
        let hit = run_round(&mut Noop, &input);
        input.round = 424243;
        let miss = run_round(&mut Noop, &input);
        std::env::remove_var(FAULT_INJECT_ENV);
        assert!(hit.degraded, "named round must take the injected failure");
        assert!(!miss.degraded, "other rounds must run clean");
    }

    #[test]
    fn injection_spec_grammar_accepts_lists_and_wildcards() {
        // List form: either named (stage, round) hits, nothing else.
        let list = "pack@3,migrate@5";
        assert!(injection_spec_hits(list, Stage::Pack, 3));
        assert!(injection_spec_hits(list, Stage::Migrate, 5));
        assert!(!injection_spec_hits(list, Stage::Pack, 5));
        assert!(!injection_spec_hits(list, Stage::Migrate, 3));
        assert!(!injection_spec_hits(list, Stage::Schedule, 3));
        // Wildcard form: the stage fails every round; other stages don't.
        assert!(injection_spec_hits("pack@*", Stage::Pack, 0));
        assert!(injection_spec_hits("pack@*", Stage::Pack, 999_999));
        assert!(!injection_spec_hits("pack@*", Stage::Migrate, 0));
        // Mixed list with a wildcard entry, spaces tolerated.
        let mixed = "estimate@7, pack@*";
        assert!(injection_spec_hits(mixed, Stage::Estimate, 7));
        assert!(injection_spec_hits(mixed, Stage::Pack, 12));
        assert!(!injection_spec_hits(mixed, Stage::Estimate, 8));
        // Malformed entries are inert.
        assert!(!injection_spec_hits("pack", Stage::Pack, 3));
        assert!(!injection_spec_hits("", Stage::Pack, 3));
    }

    /// Panics in `pack` with the watchdog's typed payload, as a tripped
    /// deadline checkpoint would.
    struct HungPack;

    impl StageProvider for HungPack {
        fn estimate(&mut self, _cx: &mut RoundContext) {}
        fn schedule(&mut self, _cx: &mut RoundContext) {}
        fn pack(&mut self, _cx: &mut RoundContext) {
            std::panic::panic_any(crate::recovery::watchdog::DeadlineExceeded {
                stage: "pack",
                budget_ms: 7,
            });
        }
        fn migrate(&mut self, _cx: &mut RoundContext) {}
        fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
            RoundDecision {
                plan: cx.plan.clone(),
                strategies: cx.strategies.clone(),
                packed_pairs: cx.packed_pairs.clone(),
                migrations: cx.migrations,
                degraded: false,
                timings: DecisionTimings::default(),
            }
        }
    }

    #[test]
    fn deadline_payload_degrades_with_deadline_reason() {
        let _guard = crate::obs::enabled_guard(true);
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let prev = crate::cluster::PlacementPlan::new(2);
        let input = RoundInput {
            now: 0.0,
            round: 3,
            active: &[],
            prev_plan: &prev,
            spec: &spec,
            health: None,
        };
        let base = metrics::snapshot();
        let d = run_round(&mut HungPack, &input);
        assert!(d.degraded, "deadline trip must yield the degraded fallback");
        let delta = metrics::snapshot().delta_since(&base);
        assert!(
            delta.counters.get("round.degraded_deadline").copied().unwrap_or(0) >= 1,
            "deadline-degraded rounds must be counted separately"
        );
    }

    #[test]
    fn stage_indices_are_dense_and_ordered() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(s.index(), i);
        }
        let names: Vec<&str> = Stage::ALL.iter().map(|s| s.name()).collect();
        assert_eq!(names, ["estimate", "schedule", "pack", "migrate", "commit"]);
    }
}

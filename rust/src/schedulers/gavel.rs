//! The Gavel baseline (§2, Narayanan et al. OSDI'20): scheduling + packing
//! formulated as one linear program. Variables are per-job allocation
//! fractions `x_j ∈ [0,1]` plus, when GPU sharing is enabled, per-pair
//! variables `y_p` for candidate packings. The LP maximizes
//! priority-weighted throughput-normalized allocation subject to cluster
//! capacity. The variable count grows with active jobs (and pairs), which
//! is exactly the scalability wall Fig. 2 / Fig. 14 measure.
//!
//! Divergence from Gavel's cvxpy implementation (documented in DESIGN.md):
//! candidate pairs are limited to equal-GPU jobs adjacent in the priority
//! order (O(n) pairs rather than O(n²)) so the dense-simplex substrate
//! stays within memory; the scaling *shape* (LP superlinear vs matching) is
//! preserved.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::estimator::ThroughputSource;
use crate::jobs::ParallelismStrategy;
use crate::linalg::{solve_lp, Lp, Matrix};
use crate::matching::{MatchingEngine, MatchingService};
use crate::policies::placement::{allocate_without_packing, migrate_with, MigrationMode};
use crate::policies::JobInfo;

use super::{best_isolated_strategies, DecisionTimings, RoundDecision, RoundInput, Scheduler};

/// Objective flavors: LAS-weighted (default Gavel) or finish-time fairness
/// (Gavel-FTF, Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GavelObjective {
    Las,
    Ftf,
}

/// The Gavel LP scheduler.
pub struct GavelScheduler {
    pub objective: GavelObjective,
    /// Enable packing-pair variables.
    pub packing: bool,
    source: Arc<dyn ThroughputSource>,
    engine: Arc<dyn MatchingEngine>,
    /// Persistent matching service for the migration stage (only exercised
    /// when `migration` is a real matching mode, e.g. Fig. 11's "w/" arm).
    service: MatchingService,
    /// Migration realization (Gavel's own policy is the identity baseline;
    /// Fig. 11's "w/" arm swaps in Tesserae's algorithm).
    pub migration: MigrationMode,
    /// Candidate-pair window: each job pairs with up to this many
    /// equal-GPU neighbours. Gavel's cvxpy formulation is all-pairs
    /// (O(n²)); the window keeps the dense-simplex tableau in memory while
    /// preserving the superlinear variable growth of Fig. 2.
    pub pair_window: usize,
}

impl GavelScheduler {
    pub fn new(
        objective: GavelObjective,
        packing: bool,
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> GavelScheduler {
        GavelScheduler {
            objective,
            packing,
            source,
            engine,
            service: MatchingService::with_defaults(),
            migration: MigrationMode::GavelBaseline,
            pair_window: 6,
        }
    }

    fn weight(&self, j: &JobInfo) -> f64 {
        match self.objective {
            // LAS: favour low attained service.
            GavelObjective::Las => 1.0 / (1.0 + j.attained_service / 3600.0),
            // FTF: favour high (bad) fairness ratio.
            GavelObjective::Ftf => j.ftf_rho(1.0),
        }
    }

    /// Build and solve the allocation LP; returns per-job scores and chosen
    /// pair allocations.
    fn solve_allocation(
        &self,
        input: &RoundInput,
    ) -> (Vec<f64>, Vec<(usize, usize, f64)>, usize) {
        let jobs = input.active;
        let n = jobs.len();
        if n == 0 {
            return (vec![], vec![], 0);
        }
        // Candidate pairs: equal GPU count, adjacent in arrival order.
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        if self.packing {
            let mut by_gpus: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
            for (i, j) in jobs.iter().enumerate() {
                by_gpus.entry(j.num_gpus).or_default().push(i);
            }
            for group in by_gpus.values() {
                for (i, &a) in group.iter().enumerate() {
                    for &b in group.iter().skip(i + 1).take(self.pair_window) {
                        pairs.push((a, b));
                    }
                }
            }
        }
        let nv = n + pairs.len();

        // Objective: w_j · x_j + (w_a·na + w_b·nb) · y_p.
        let dp = ParallelismStrategy::DataParallel;
        let mut c = vec![0.0; nv];
        for (i, j) in jobs.iter().enumerate() {
            c[i] = self.weight(j);
        }
        for (p, &(a, b)) in pairs.iter().enumerate() {
            let ja = &jobs[a];
            let jb = &jobs[b];
            let w = self
                .source
                .normalized_pair((ja.model, &dp), (jb.model, &dp), ja.num_gpus)
                .map(|(na, nb)| self.weight(ja) * na + self.weight(jb) * nb)
                .unwrap_or(0.0);
            c[n + p] = w;
        }

        // Constraints: capacity row + per-job rows (x_j + Σ_p∋j y_p ≤ 1).
        let m = 1 + n;
        let mut a = Matrix::zeros(m, nv);
        let mut rhs = vec![0.0; m];
        for (i, j) in jobs.iter().enumerate() {
            a.set(0, i, j.num_gpus as f64);
            a.set(1 + i, i, 1.0);
        }
        for (p, &(i1, i2)) in pairs.iter().enumerate() {
            a.set(0, n + p, jobs[i1].num_gpus as f64);
            a.set(1 + i1, n + p, 1.0);
            a.set(1 + i2, n + p, 1.0);
        }
        rhs[0] = input.spec.total_gpus() as f64;
        for r in rhs.iter_mut().skip(1) {
            *r = 1.0;
        }

        let lp = Lp {
            objective: c,
            constraints: a,
            rhs,
        };
        match solve_lp(&lp) {
            Ok(sol) => {
                let scores = sol.x[..n].to_vec();
                let chosen: Vec<(usize, usize, f64)> = pairs
                    .iter()
                    .enumerate()
                    .filter(|(p, _)| sol.x[n + *p] > 0.25)
                    .map(|(p, &(a, b))| (a, b, sol.x[n + p]))
                    .collect();
                (scores, chosen, nv)
            }
            Err(_) => ((0..n).map(|i| lp.objective[i]).collect(), vec![], nv),
        }
    }
}

impl Scheduler for GavelScheduler {
    fn name(&self) -> String {
        match (self.objective, self.packing) {
            (GavelObjective::Las, true) => "gavel".into(),
            (GavelObjective::Las, false) => "gavel-nopack".into(),
            (GavelObjective::Ftf, _) => "gavel-ftf".into(),
        }
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        let t_total = Instant::now();
        let t0 = Instant::now();
        let (scores, pair_allocs, _nv) = self.solve_allocation(input);
        let scheduling_s = t0.elapsed().as_secs_f64();

        // Realize the fractional allocation: priority score = LP allocation
        // corrected by rounds already received (Gavel's round-robin rule).
        let mut order: Vec<usize> = (0..input.active.len()).collect();
        order.sort_by(|&a, &b| {
            let sa = scores.get(a).copied().unwrap_or(0.0)
                / (1.0 + input.active[a].rounds_received as f64);
            let sb = scores.get(b).copied().unwrap_or(0.0)
                / (1.0 + input.active[b].rounds_received as f64);
            sb.partial_cmp(&sa)
                .unwrap()
                .then(input.active[a].id.cmp(&input.active[b].id))
        });
        let ordered: Vec<&JobInfo> = order.iter().map(|&i| &input.active[i]).collect();
        let alloc = allocate_without_packing(input.spec, &ordered);
        let mut plan = alloc.plan;
        let by_id: BTreeMap<_, _> = input.active.iter().map(|j| (j.id, j)).collect();
        let placed_infos: Vec<&JobInfo> = alloc.placed.iter().map(|id| by_id[id]).collect();
        let mut strategies = best_isolated_strategies(&placed_infos, self.source.as_ref());

        // Apply LP-chosen packings where one side is placed and the other
        // pending.
        let t1 = Instant::now();
        let mut packed_pairs = Vec::new();
        let placed_set: std::collections::BTreeSet<_> = alloc.placed.iter().copied().collect();
        let pending_set: std::collections::BTreeSet<_> = alloc.pending.iter().copied().collect();
        let mut by_alloc = pair_allocs;
        by_alloc.sort_by(|x, y| y.2.partial_cmp(&x.2).unwrap());
        for (a, b, _) in by_alloc {
            let (ja, jb) = (&input.active[a], &input.active[b]);
            let (host, guest) = if placed_set.contains(&ja.id) && pending_set.contains(&jb.id) {
                (ja, jb)
            } else if placed_set.contains(&jb.id) && pending_set.contains(&ja.id) {
                (jb, ja)
            } else {
                continue;
            };
            let gpus = plan.gpus_of(host.id).to_vec();
            if gpus.is_empty() || !plan.gpus_of(guest.id).is_empty() {
                continue;
            }
            if gpus.iter().any(|&g| plan.free_capacity(g) == 0) {
                continue;
            }
            plan.place(guest.id, &gpus);
            strategies.insert(guest.id, ParallelismStrategy::DataParallel);
            packed_pairs.push((host.id, guest.id));
        }
        let packing_s = t1.elapsed().as_secs_f64();

        let outcome = migrate_with(
            input.spec,
            input.prev_plan,
            &plan,
            self.migration,
            self.engine.as_ref(),
            &mut self.service,
        );

        RoundDecision {
            plan: outcome.plan,
            strategies,
            packed_pairs,
            migrations: outcome.migrations,
            timings: DecisionTimings {
                scheduling_s,
                packing_s,
                migration_s: outcome.decide_time_s,
                total_s: t_total.elapsed().as_secs_f64(),
                matching: outcome.service,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuType, PlacementPlan};
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind;
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, model: ModelKind, gpus: u32, attained: f64) -> JobInfo {
        JobInfo {
            id,
            model,
            num_gpus: gpus,
            arrival_time: id as f64,
            attained_service: attained,
            total_iters: 10_000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 1000.0,
            iso_tput: 10.0,
        }
    }

    fn gavel(objective: GavelObjective, packing: bool) -> GavelScheduler {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        GavelScheduler::new(objective, packing, source, Arc::new(HungarianEngine))
    }

    #[test]
    fn allocates_within_capacity() {
        let spec = ClusterSpec::new(1, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..6)
            .map(|i| info(i, ModelKind::ResNet50, 1 + (i % 2) as u32, i as f64 * 100.0))
            .collect();
        let prev = PlacementPlan::new(4);
        let mut s = gavel(GavelObjective::Las, true);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        d.plan.validate().unwrap();
        let used: usize = (0..4).filter(|&g| !d.plan.jobs_on(g).is_empty()).count();
        assert!(used > 0);
    }

    #[test]
    fn las_weighting_prefers_unserved_jobs() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        let active = vec![
            info(1, ModelKind::ResNet50, 1, 1_000_000.0),
            info(2, ModelKind::ResNet50, 1, 0.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = gavel(GavelObjective::Las, false);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        assert!(d.plan.jobs().contains(&2));
    }

    #[test]
    fn packing_variables_enable_sharing() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        let active = vec![
            info(1, ModelKind::PointNet, 1, 0.0),
            info(2, ModelKind::Dcgan, 1, 0.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = gavel(GavelObjective::Las, true);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        // One GPU, two beneficial-to-pack jobs: LP should share.
        assert_eq!(d.plan.jobs().len(), 2, "{:?}", d.plan);
        assert_eq!(d.packed_pairs.len(), 1);
    }

    #[test]
    fn nopack_never_shares() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        let active = vec![
            info(1, ModelKind::PointNet, 1, 0.0),
            info(2, ModelKind::Dcgan, 1, 0.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = gavel(GavelObjective::Las, false);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        assert_eq!(d.plan.jobs().len(), 1);
    }

    #[test]
    fn decision_time_grows_with_jobs() {
        // The Fig. 2 effect in miniature: more active jobs => larger LP =>
        // superlinear decision time.
        let spec = ClusterSpec::new(8, 4, GpuType::A100);
        let prev = PlacementPlan::new(32);
        let time_for = |n: u64| {
            let active: Vec<JobInfo> = (0..n)
                .map(|i| info(i, ModelKind::ResNet50, 1, i as f64))
                .collect();
            let mut s = gavel(GavelObjective::Las, true);
            let d = s.decide(&RoundInput {
                now: 0.0,
                round: 0,
                active: &active,
                prev_plan: &prev,
                spec: &spec,
            });
            d.timings.scheduling_s
        };
        let t_small = time_for(20);
        let t_large = time_for(160);
        assert!(
            t_large > 3.0 * t_small,
            "LP time should blow up: {t_small} vs {t_large}"
        );
    }
}

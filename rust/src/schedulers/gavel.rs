//! The Gavel baseline (§2, Narayanan et al. OSDI'20): scheduling + packing
//! formulated as one linear program. Variables are per-job allocation
//! fractions `x_j ∈ [0,1]` plus, when GPU sharing is enabled, per-pair
//! variables `y_p` for candidate packings. The LP maximizes
//! priority-weighted throughput-normalized allocation subject to cluster
//! capacity. The variable count grows with active jobs (and pairs), which
//! is exactly the scalability wall Fig. 2 / Fig. 14 measure.
//!
//! The LP is solved by the sparse revised simplex
//! (`crate::linalg::revised`): the capacity row plus per-job coupling rows
//! are stored in CSC form, `x ≤ 1` box constraints are native variable
//! bounds (not rows), and jobs that appear in no candidate pair need no
//! row at all. Across rounds the scheduler caches the built instance —
//! when the active job window is unchanged only the objective (the drifted
//! priority weights) is patched in place, and the previous round's optimal
//! basis warm-starts the re-solve. When the window *changes* (arrival /
//! departure) under the same config, the instance is rebuilt in place and
//! the basis is carried across by an id-based remap plus a bounded
//! dual-simplex repair (`linalg::revised::repair_warm_start`) — a handful
//! of pivots instead of a cold solve. The dense tableau solver is retained
//! in `linalg::lp` purely as the parity oracle for tests and `bench_lp`.
//!
//! Divergence from Gavel's cvxpy implementation (documented in DESIGN.md):
//! candidate pairs are limited to equal-GPU jobs adjacent in the priority
//! order (O(n) pairs rather than O(n²)); the scaling *shape* (LP
//! superlinear vs matching) is preserved.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::estimator::ThroughputSource;
use crate::jobs::ParallelismStrategy;
use crate::linalg::{repair_warm_start, solve_sparse_lp, CscMatrix, SparseLp, WarmStart};
use crate::matching::{MatchingEngine, MatchingService};
use crate::obs::metrics;
use crate::policies::placement::{allocate_masked, migrate_masked, MigrationMode};
use crate::policies::JobInfo;
use crate::util::pool::WorkerPool;

use super::pipeline::{self, RoundContext, Stage, StageProvider};
use super::{best_isolated_strategies, DecisionTimings, RoundDecision, RoundInput, Scheduler};

/// Objective flavors: LAS-weighted (default Gavel) or finish-time fairness
/// (Gavel-FTF, Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GavelObjective {
    Las,
    Ftf,
}

/// Gavel's per-job priority weight under `objective`.
pub fn job_weight(objective: GavelObjective, j: &JobInfo) -> f64 {
    match objective {
        // LAS: favour low attained service.
        GavelObjective::Las => 1.0 / (1.0 + j.attained_service / 3600.0),
        // FTF: favour high (bad) fairness ratio.
        GavelObjective::Ftf => j.ftf_rho(1.0),
    }
}

/// Candidate packing pairs over `jobs`: equal GPU count, each job paired
/// with up to `pair_window` later neighbours of its GPU class. Empty when
/// `packing` is off. Deterministic in the job order.
pub fn candidate_pairs(
    jobs: &[JobInfo],
    packing: bool,
    pair_window: usize,
) -> Vec<(usize, usize)> {
    let mut pairs: Vec<(usize, usize)> = Vec::new();
    if !packing {
        return pairs;
    }
    let mut by_gpus: BTreeMap<u32, Vec<usize>> = BTreeMap::new();
    for (i, j) in jobs.iter().enumerate() {
        by_gpus.entry(j.num_gpus).or_default().push(i);
    }
    for group in by_gpus.values() {
        for (i, &a) in group.iter().enumerate() {
            for &b in group.iter().skip(i + 1).take(pair_window) {
                pairs.push((a, b));
            }
        }
    }
    pairs
}

/// Build the Gavel allocation LP structure over `jobs` and candidate
/// `pairs`: row 0 is cluster capacity (`Σ g_j x_j + Σ g_p y_p ≤ G`), and
/// only jobs that participate in ≥ 1 pair get a coupling row
/// (`x_j + Σ_{p∋j} y_p ≤ 1`) — every other `x ≤ 1` lives in the native
/// variable bounds, which is what keeps the instance small and sparse.
/// The objective is zeroed; patch it per round with
/// [`allocation_objective_into`].
pub fn build_allocation_lp(
    jobs: &[JobInfo],
    pairs: &[(usize, usize)],
    total_gpus: usize,
) -> SparseLp {
    let mut lp = SparseLp {
        objective: Vec::new(),
        constraints: CscMatrix::zeros(0, 0),
        rhs: Vec::new(),
        upper: Vec::new(),
    };
    build_allocation_lp_into(jobs, pairs, total_gpus, &mut lp);
    lp
}

/// In-place variant of [`build_allocation_lp`]: rebuilds `lp` reusing its
/// CSC / objective / rhs / bound buffers, so carrying a cached instance
/// across an arrival or departure allocates nothing once the buffers have
/// grown to steady-state size.
pub fn build_allocation_lp_into(
    jobs: &[JobInfo],
    pairs: &[(usize, usize)],
    total_gpus: usize,
    lp: &mut SparseLp,
) {
    let n = jobs.len();
    let (job_row, m) = coupling_rows(n, pairs);
    let nv = n + pairs.len();
    let c = &mut lp.constraints;
    c.reset(m);
    for (i, j) in jobs.iter().enumerate() {
        c.push(0, j.num_gpus as f64);
        if job_row[i] != usize::MAX {
            c.push(job_row[i], 1.0);
        }
        c.end_col();
    }
    for &(a, b) in pairs {
        c.push(0, jobs[a].num_gpus as f64);
        c.push(job_row[a], 1.0);
        c.push(job_row[b], 1.0);
        c.end_col();
    }
    lp.objective.clear();
    lp.objective.resize(nv, 0.0);
    lp.rhs.clear();
    lp.rhs.resize(m, 1.0);
    lp.rhs[0] = total_gpus as f64;
    lp.upper.clear();
    lp.upper.resize(nv, 1.0);
}

/// Row layout of [`build_allocation_lp`]: row 0 is cluster capacity, and
/// jobs that participate in ≥ 1 pair get coupling rows `1..` in job order.
/// Returns `(job_row, m)` with `usize::MAX` marking "no coupling row".
fn coupling_rows(n: usize, pairs: &[(usize, usize)]) -> (Vec<usize>, usize) {
    let mut in_pair = vec![false; n];
    for &(a, b) in pairs {
        in_pair[a] = true;
        in_pair[b] = true;
    }
    let mut job_row = vec![usize::MAX; n];
    let mut m = 1usize;
    for (i, &flag) in in_pair.iter().enumerate() {
        if flag {
            job_row[i] = m;
            m += 1;
        }
    }
    (job_row, m)
}

/// Variable / row maps from one allocation-LP window onto its successor —
/// the inputs [`WarmStart::remapped`] needs to carry a basis across an
/// arrival/departure. Structural variables map by job id, pair variables
/// by ordered id pair, the capacity row to itself, and coupling rows by
/// job id; departed entries map to `None`.
pub fn allocation_lp_maps(
    old_ids: &[u64],
    old_pairs: &[(usize, usize)],
    new_jobs: &[JobInfo],
    new_pairs: &[(usize, usize)],
) -> (Vec<Option<usize>>, Vec<Option<usize>>) {
    let new_index: BTreeMap<u64, usize> =
        new_jobs.iter().enumerate().map(|(i, j)| (j.id, i)).collect();
    let new_pair_index: BTreeMap<(u64, u64), usize> = new_pairs
        .iter()
        .enumerate()
        .map(|(p, &(a, b))| ((new_jobs[a].id, new_jobs[b].id), p))
        .collect();
    let (old_job_row, old_m) = coupling_rows(old_ids.len(), old_pairs);
    let (new_job_row, _) = coupling_rows(new_jobs.len(), new_pairs);
    let n_new = new_jobs.len();
    let mut var_map: Vec<Option<usize>> = Vec::with_capacity(old_ids.len() + old_pairs.len());
    for id in old_ids {
        var_map.push(new_index.get(id).copied());
    }
    for &(a, b) in old_pairs {
        let key = (old_ids[a], old_ids[b]);
        var_map.push(new_pair_index.get(&key).copied().map(|p| n_new + p));
    }
    let mut row_map: Vec<Option<usize>> = vec![None; old_m];
    row_map[0] = Some(0);
    for (i, id) in old_ids.iter().enumerate() {
        if old_job_row[i] != usize::MAX {
            row_map[old_job_row[i]] = new_index.get(id).and_then(|&ni| {
                let r = new_job_row[ni];
                (r != usize::MAX).then_some(r)
            });
        }
    }
    (var_map, row_map)
}

/// Write this round's LP objective — per-job weights then per-pair packed
/// weights — into `out` (length `jobs.len() + pairs.len()`). The per-pair
/// throughput lookups are independent, so they shard across the shared
/// worker pool; the written values are identical for any thread budget.
pub fn allocation_objective_into(
    objective: GavelObjective,
    jobs: &[JobInfo],
    pairs: &[(usize, usize)],
    source: &dyn ThroughputSource,
    out: &mut [f64],
) {
    let n = jobs.len();
    assert_eq!(out.len(), n + pairs.len());
    let dp = ParallelismStrategy::DataParallel;
    for (slot, j) in out.iter_mut().zip(jobs) {
        *slot = job_weight(objective, j);
    }
    let pair_weights = WorkerPool::global().map(pairs, 0, 128, |_, &(a, b)| {
        let ja = &jobs[a];
        let jb = &jobs[b];
        source
            .normalized_pair((ja.model, &dp), (jb.model, &dp), ja.num_gpus)
            .map(|(na, nb)| {
                job_weight(objective, ja) * na + job_weight(objective, jb) * nb
            })
            .unwrap_or(0.0)
    });
    out[n..].copy_from_slice(&pair_weights);
}

/// The built LP for one job window, kept across rounds. While the window
/// (job ids + GPU demands), cluster size and pairing config are unchanged,
/// rounds only re-patch the objective and warm-start from the previous
/// basis. A window *change* under the same config rebuilds the instance in
/// place and carries the basis across via remap + dual-simplex repair
/// ([`repair_warm_start`]); only a config change cold-rebuilds.
struct LpCache {
    total_gpus: usize,
    packing: bool,
    pair_window: usize,
    /// Monotone instance generation, bumped on every structural change.
    /// A warm handle is usable only while `warm_generation` matches, so
    /// bases from departed windows are evicted instead of lingering.
    generation: u64,
    structure: Vec<(u64, u32)>,
    pairs: Vec<(usize, usize)>,
    lp: SparseLp,
    warm: Option<WarmStart>,
    warm_generation: u64,
}

/// The Gavel LP scheduler.
pub struct GavelScheduler {
    pub objective: GavelObjective,
    /// Enable packing-pair variables.
    pub packing: bool,
    source: Arc<dyn ThroughputSource>,
    engine: Arc<dyn MatchingEngine>,
    /// Persistent matching service for the migration stage (only exercised
    /// when `migration` is a real matching mode, e.g. Fig. 11's "w/" arm).
    service: MatchingService,
    /// Migration realization (Gavel's own policy is the identity baseline;
    /// Fig. 11's "w/" arm swaps in Tesserae's algorithm).
    pub migration: MigrationMode,
    /// Candidate-pair window: each job pairs with up to this many
    /// equal-GPU neighbours. Gavel's cvxpy formulation is all-pairs
    /// (O(n²)); the window keeps pair growth linear while preserving the
    /// superlinear variable growth of Fig. 2.
    pub pair_window: usize,
    lp_cache: Option<LpCache>,
    lp_rebuilds: usize,
    lp_patches: usize,
    lp_repairs: usize,
    /// Round scratch carried between pipeline stages: the LP's per-job
    /// scores (Schedule) and chosen pair allocations (consumed by Pack).
    round_scores: Vec<f64>,
    round_pairs: Vec<(usize, usize, f64)>,
}

impl GavelScheduler {
    pub fn new(
        objective: GavelObjective,
        packing: bool,
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> GavelScheduler {
        GavelScheduler {
            objective,
            packing,
            source,
            engine,
            service: MatchingService::with_defaults(),
            migration: MigrationMode::GavelBaseline,
            pair_window: 6,
            lp_cache: None,
            lp_rebuilds: 0,
            lp_patches: 0,
            lp_repairs: 0,
            round_scores: Vec::new(),
            round_pairs: Vec::new(),
        }
    }

    /// `(rebuilds, patches)`: how many rounds built the LP from scratch vs
    /// reused the cached instance with only the objective re-patched.
    pub fn lp_stats(&self) -> (usize, usize) {
        (self.lp_rebuilds, self.lp_patches)
    }

    /// How many rounds serviced a window *change* by rebuilding the cached
    /// instance in place and repairing the previous basis (dual simplex)
    /// instead of discarding it and cold-solving.
    pub fn lp_repairs(&self) -> usize {
        self.lp_repairs
    }

    /// Estimate-stage half of the LP round: build (or reuse) the cached
    /// instance for this job window and patch the objective in place.
    /// Weights drift every round even when the window is static, so the
    /// objective is always re-patched.
    fn prepare_lp(&mut self, input: &RoundInput) {
        let jobs = input.active;
        if jobs.is_empty() {
            return;
        }
        crate::obs_span!("lp.prepare", { jobs: jobs.len() });
        // Capacity row over *healthy* GPUs: a failure shrinks `total_gpus`,
        // which is part of the cache config, so the GPU set shrinking (or
        // recovering) forces a cold rebuild — a stale basis sized for the
        // old capacity is never repaired into the new instance.
        let total_gpus = input
            .health
            .map_or_else(|| input.spec.total_gpus(), |h| h.num_healthy());
        let structure: Vec<(u64, u32)> = jobs.iter().map(|j| (j.id, j.num_gpus)).collect();
        let config_ok = self.lp_cache.as_ref().is_some_and(|c| {
            c.total_gpus == total_gpus
                && c.packing == self.packing
                && c.pair_window == self.pair_window
        });
        let same_window =
            config_ok && self.lp_cache.as_ref().is_some_and(|c| c.structure == structure);
        if same_window {
            self.lp_patches += 1;
            metrics::counter_add("lp.window_hits", 1);
        } else if config_ok {
            {
                crate::obs_span!("lp.repair", { job_window: jobs.len() });
                self.repair_cache(jobs, structure);
            }
            self.lp_repairs += 1;
            metrics::counter_add("lp.repairs", 1);
        } else {
            let pairs = candidate_pairs(jobs, self.packing, self.pair_window);
            let lp = build_allocation_lp(jobs, &pairs, total_gpus);
            let generation = self.lp_cache.as_ref().map_or(0, |c| c.generation) + 1;
            self.lp_cache = Some(LpCache {
                total_gpus,
                packing: self.packing,
                pair_window: self.pair_window,
                generation,
                structure,
                pairs,
                lp,
                warm: None,
                warm_generation: generation,
            });
            self.lp_rebuilds += 1;
            metrics::counter_add("lp.cold_rebuilds", 1);
        }
        let objective = self.objective;
        let source = Arc::clone(&self.source);
        let cache = self.lp_cache.as_mut().expect("cache just ensured");
        allocation_objective_into(
            objective,
            jobs,
            &cache.pairs,
            source.as_ref(),
            &mut cache.lp.objective,
        );
    }

    /// Structural change under an unchanged config: rebuild the cached
    /// instance *in place* (reusing the CSC / objective / rhs buffers) and
    /// carry the previous round's basis across via id-based remap plus
    /// dual-simplex repair, instead of discarding it and cold-solving. A
    /// failed repair leaves `warm` empty — the stale basis is evicted
    /// either way, never fed to the solver.
    fn repair_cache(&mut self, jobs: &[JobInfo], structure: Vec<(u64, u32)>) {
        let cache = self
            .lp_cache
            .as_mut()
            .expect("repair_cache requires a config-matched cache");
        let new_pairs = candidate_pairs(jobs, self.packing, self.pair_window);
        let old_ids: Vec<u64> = cache.structure.iter().map(|&(id, _)| id).collect();
        let (var_map, row_map) = allocation_lp_maps(&old_ids, &cache.pairs, jobs, &new_pairs);
        build_allocation_lp_into(jobs, &new_pairs, cache.total_gpus, &mut cache.lp);
        let repaired = cache
            .warm
            .take()
            .filter(|_| cache.warm_generation == cache.generation)
            .and_then(|w| {
                let carried =
                    w.remapped(&var_map, &row_map, cache.lp.num_vars(), cache.lp.num_rows());
                repair_warm_start(&cache.lp, &carried)
            });
        cache.generation += 1;
        cache.warm_generation = cache.generation;
        cache.warm = repaired;
        cache.structure = structure;
        cache.pairs = new_pairs;
    }

    /// Schedule-stage half: solve the prepared LP (warm-started from the
    /// previous basis — repaired first if the window changed); returns
    /// per-job scores and chosen pair allocations.
    fn solve_prepared(&mut self, n: usize) -> (Vec<f64>, Vec<(usize, usize, f64)>) {
        let cache = self
            .lp_cache
            .as_mut()
            .expect("estimate stage prepared the LP");
        let warm = cache
            .warm
            .as_ref()
            .filter(|_| cache.warm_generation == cache.generation);
        crate::obs_span!("lp.solve", {
            vars: cache.lp.num_vars(),
            rows: cache.lp.num_rows(),
            warm: warm.is_some(),
        });
        match solve_sparse_lp(&cache.lp, warm) {
            Ok((sol, warm)) => {
                cache.warm = Some(warm);
                cache.warm_generation = cache.generation;
                let scores = sol.x[..n].to_vec();
                let chosen: Vec<(usize, usize, f64)> = cache
                    .pairs
                    .iter()
                    .enumerate()
                    .filter(|(p, _)| sol.x[n + *p] > 0.25)
                    .map(|(p, &(a, b))| (a, b, sol.x[n + p]))
                    .collect();
                (scores, chosen)
            }
            Err(_) => {
                cache.warm = None;
                (cache.lp.objective[..n].to_vec(), vec![])
            }
        }
    }
}

impl StageProvider for GavelScheduler {
    /// Ensure the cached LP instance matches this round's job window and
    /// patch the (drifted) objective weights in place.
    fn estimate(&mut self, cx: &mut RoundContext) {
        self.round_scores.clear();
        self.round_pairs.clear();
        self.prepare_lp(cx.input);
    }

    /// Solve the LP and realize the fractional allocation: priority score
    /// = LP allocation corrected by rounds already received (Gavel's
    /// round-robin rule), then the consolidated allocation walk.
    fn schedule(&mut self, cx: &mut RoundContext) {
        let jobs = cx.input.active;
        if !jobs.is_empty() {
            let (scores, chosen) = self.solve_prepared(jobs.len());
            self.round_scores = scores;
            self.round_pairs = chosen;
        }
        let scores = &self.round_scores;
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            let sa =
                scores.get(a).copied().unwrap_or(0.0) / (1.0 + jobs[a].rounds_received as f64);
            let sb =
                scores.get(b).copied().unwrap_or(0.0) / (1.0 + jobs[b].rounds_received as f64);
            sb.partial_cmp(&sa).unwrap().then(jobs[a].id.cmp(&jobs[b].id))
        });
        cx.order = order;
        let ordered: Vec<&JobInfo> = cx.order.iter().map(|&i| &jobs[i]).collect();
        let alloc = allocate_masked(cx.input.spec, &ordered, cx.input.health);
        cx.plan = alloc.plan;
        cx.placed = alloc.placed;
        cx.pending = alloc.pending;
        cx.by_id = jobs.iter().map(|j| (j.id, j)).collect();
        let placed_infos: Vec<&JobInfo> = cx.placed.iter().map(|id| cx.by_id[id]).collect();
        cx.strategies = best_isolated_strategies(&placed_infos, self.source.as_ref());
    }

    /// Apply LP-chosen packings where one side is placed and the other
    /// pending.
    fn pack(&mut self, cx: &mut RoundContext) {
        let placed_set: std::collections::BTreeSet<_> = cx.placed.iter().copied().collect();
        let pending_set: std::collections::BTreeSet<_> = cx.pending.iter().copied().collect();
        let mut by_alloc = std::mem::take(&mut self.round_pairs);
        by_alloc.sort_by(|x, y| y.2.partial_cmp(&x.2).unwrap());
        for (a, b, _) in by_alloc {
            let (ja, jb) = (&cx.input.active[a], &cx.input.active[b]);
            let (host, guest) = if placed_set.contains(&ja.id) && pending_set.contains(&jb.id) {
                (ja, jb)
            } else if placed_set.contains(&jb.id) && pending_set.contains(&ja.id) {
                (jb, ja)
            } else {
                continue;
            };
            let gpus = cx.plan.gpus_of(host.id).to_vec();
            if gpus.is_empty() || !cx.plan.gpus_of(guest.id).is_empty() {
                continue;
            }
            if gpus.iter().any(|&g| cx.plan.free_capacity(g) == 0) {
                continue;
            }
            cx.plan.place(guest.id, &gpus);
            cx.strategies.insert(guest.id, ParallelismStrategy::DataParallel);
            cx.packed_pairs.push((host.id, guest.id));
        }
    }

    fn migrate(&mut self, cx: &mut RoundContext) {
        cx.outcome = Some(migrate_masked(
            cx.input.spec,
            cx.input.prev_plan,
            &cx.plan,
            self.migration,
            self.engine.as_ref(),
            &mut self.service,
            cx.input.health,
        ));
    }

    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
        let outcome = cx.outcome.take().expect("migrate stage ran");
        RoundDecision {
            plan: outcome.plan,
            strategies: std::mem::take(&mut cx.strategies),
            packed_pairs: std::mem::take(&mut cx.packed_pairs),
            migrations: outcome.migrations,
            degraded: false,
            timings: DecisionTimings {
                stage_s: cx.stage_s,
                scheduling_s: cx.stage_s[Stage::Estimate.index()]
                    + cx.stage_s[Stage::Schedule.index()],
                packing_s: cx.stage_s[Stage::Pack.index()],
                migration_s: outcome.decide_time_s,
                total_s: 0.0, // driver fills
                matching: outcome.service,
            },
        }
    }

    /// A panicked round may have left the cached LP half-rebuilt (the
    /// in-place repair mutates the instance before swapping structure in);
    /// drop it and the round scratch — the next round cold-rebuilds.
    fn reset_after_failure(&mut self) {
        self.lp_cache = None;
        self.round_scores.clear();
        self.round_pairs.clear();
    }
}

impl Scheduler for GavelScheduler {
    fn name(&self) -> String {
        match (self.objective, self.packing) {
            (GavelObjective::Las, true) => "gavel".into(),
            (GavelObjective::Las, false) => "gavel-nopack".into(),
            (GavelObjective::Ftf, _) => "gavel-ftf".into(),
        }
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        pipeline::run_round(self, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuType, PlacementPlan};
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind;
    use crate::linalg::solve_lp;
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, model: ModelKind, gpus: u32, attained: f64) -> JobInfo {
        JobInfo {
            id,
            model,
            num_gpus: gpus,
            arrival_time: id as f64,
            attained_service: attained,
            total_iters: 10_000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 1000.0,
            iso_tput: 10.0,
        }
    }

    fn gavel(objective: GavelObjective, packing: bool) -> GavelScheduler {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        GavelScheduler::new(objective, packing, source, Arc::new(HungarianEngine))
    }

    #[test]
    fn allocates_within_capacity() {
        let spec = ClusterSpec::new(1, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..6)
            .map(|i| info(i, ModelKind::ResNet50, 1 + (i % 2) as u32, i as f64 * 100.0))
            .collect();
        let prev = PlacementPlan::new(4);
        let mut s = gavel(GavelObjective::Las, true);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        d.plan.validate().unwrap();
        let used: usize = (0..4).filter(|&g| !d.plan.jobs_on(g).is_empty()).count();
        assert!(used > 0);
    }

    #[test]
    fn las_weighting_prefers_unserved_jobs() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        let active = vec![
            info(1, ModelKind::ResNet50, 1, 1_000_000.0),
            info(2, ModelKind::ResNet50, 1, 0.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = gavel(GavelObjective::Las, false);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert!(d.plan.jobs().contains(&2));
    }

    #[test]
    fn packing_variables_enable_sharing() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        let active = vec![
            info(1, ModelKind::PointNet, 1, 0.0),
            info(2, ModelKind::Dcgan, 1, 0.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = gavel(GavelObjective::Las, true);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        // One GPU, two beneficial-to-pack jobs: LP should share.
        assert_eq!(d.plan.jobs().len(), 2, "{:?}", d.plan);
        assert_eq!(d.packed_pairs.len(), 1);
    }

    #[test]
    fn nopack_never_shares() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        let active = vec![
            info(1, ModelKind::PointNet, 1, 0.0),
            info(2, ModelKind::Dcgan, 1, 0.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = gavel(GavelObjective::Las, false);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert_eq!(d.plan.jobs().len(), 1);
    }

    #[test]
    fn decision_time_grows_with_jobs() {
        // The Fig. 2 effect in miniature: more active jobs => larger LP =>
        // superlinear scheduling (LP-solve) time, even on the revised
        // simplex — iterations and per-iteration work both grow with n.
        let spec = ClusterSpec::new(8, 4, GpuType::A100);
        let prev = PlacementPlan::new(32);
        let time_for = |n: u64| {
            let active: Vec<JobInfo> = (0..n)
                .map(|i| info(i, ModelKind::ResNet50, 1, i as f64))
                .collect();
            let mut s = gavel(GavelObjective::Las, true);
            let d = s.decide(&RoundInput {
                now: 0.0,
                round: 0,
                active: &active,
                prev_plan: &prev,
                spec: &spec,
                health: None,
            });
            d.timings.scheduling_s
        };
        let t_small = time_for(32);
        let t_large = time_for(512);
        assert!(
            t_large > 3.0 * t_small,
            "LP time should grow superlinearly: {t_small} vs {t_large}"
        );
    }

    #[test]
    fn lp_cache_patches_unchanged_window_and_rebuilds_on_change() {
        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..12)
            .map(|i| info(i, ModelKind::ResNet50, 1 + (i % 2) as u32, i as f64 * 50.0))
            .collect();
        let prev = PlacementPlan::new(8);
        let mut s = gavel(GavelObjective::Las, true);
        let d1 = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_stats(), (1, 0));
        // Same window, drifted service: the cached instance is re-patched,
        // not rebuilt, and the solve is warm-started.
        let mut drifted = active.clone();
        for j in &mut drifted {
            j.attained_service += 360.0;
            j.rounds_received += 1;
        }
        let d2 = s.decide(&RoundInput {
            now: 360.0,
            round: 1,
            active: &drifted,
            prev_plan: &d1.plan,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_stats(), (1, 1));
        d2.plan.validate().unwrap();
        // A changed window (departure) under the same config is repaired
        // in place — not rebuilt, not counted as a patch.
        let shrunk: Vec<JobInfo> = drifted[1..].to_vec();
        let d3 = s.decide(&RoundInput {
            now: 720.0,
            round: 2,
            active: &shrunk,
            prev_plan: &d2.plan,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_stats(), (1, 1));
        assert_eq!(s.lp_repairs(), 1);
        d3.plan.validate().unwrap();
        // A config change (different cluster size) still cold-rebuilds.
        let spec2 = ClusterSpec::new(3, 4, GpuType::A100);
        let prev2 = PlacementPlan::new(12);
        let d4 = s.decide(&RoundInput {
            now: 1080.0,
            round: 3,
            active: &shrunk,
            prev_plan: &prev2,
            spec: &spec2,
            health: None,
        });
        assert_eq!(s.lp_stats(), (2, 1));
        assert_eq!(s.lp_repairs(), 1);
        d4.plan.validate().unwrap();
    }

    #[test]
    fn gpu_failure_shrinks_lp_capacity_and_cold_rebuilds() {
        use crate::faults::ClusterHealth;
        let spec = ClusterSpec::new(1, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..6)
            .map(|i| info(i, ModelKind::ResNet50, 1, i as f64 * 50.0))
            .collect();
        let prev = PlacementPlan::new(4);
        let mut s = gavel(GavelObjective::Las, true);
        let d1 = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_stats(), (1, 0));
        // One GPU dies: the capacity row shrinks 4 → 3, which is a config
        // change to the LP cache — cold rebuild, never a basis repair.
        let mut health = ClusterHealth::new(4);
        health.fail_gpu(2);
        let d2 = s.decide(&RoundInput {
            now: 360.0,
            round: 1,
            active: &active,
            prev_plan: &d1.plan,
            spec: &spec,
            health: Some(&health),
        });
        assert_eq!(s.lp_stats(), (2, 0));
        assert_eq!(s.lp_repairs(), 0);
        d2.plan.validate().unwrap();
        health.validate_plan(&d2.plan).unwrap();
        assert!(d2.plan.jobs_on(2).is_empty());
        // Recovery restores full capacity: rebuild again.
        health.recover_gpu(2);
        let d3 = s.decide(&RoundInput {
            now: 720.0,
            round: 2,
            active: &active,
            prev_plan: &d2.plan,
            spec: &spec,
            health: Some(&health),
        });
        assert_eq!(s.lp_stats(), (3, 0));
        d3.plan.validate().unwrap();
    }

    #[test]
    fn reset_after_failure_discards_lp_cache() {
        let spec = ClusterSpec::new(1, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..4)
            .map(|i| info(i, ModelKind::ResNet50, 1, i as f64 * 50.0))
            .collect();
        let prev = PlacementPlan::new(4);
        let mut s = gavel(GavelObjective::Las, true);
        let d1 = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_stats(), (1, 0));
        s.reset_after_failure();
        // Same window again: a retained cache would be a patch; the reset
        // forces a cold rebuild instead.
        let d2 = s.decide(&RoundInput {
            now: 360.0,
            round: 1,
            active: &active,
            prev_plan: &d1.plan,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_stats(), (2, 0));
        d2.plan.validate().unwrap();
    }

    #[test]
    fn lp_cache_evicts_stale_window_on_departure() {
        // Satellite: a departure must not leave the cache describing the
        // departed window — the entry is retagged to the new generation and
        // any warm handle is either repaired onto the new instance or
        // dropped, never left stale.
        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..10)
            .map(|i| info(i, ModelKind::ResNet50, 1, i as f64 * 40.0))
            .collect();
        let prev = PlacementPlan::new(8);
        let mut s = gavel(GavelObjective::Las, true);
        let d1 = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        let gen0 = s.lp_cache.as_ref().unwrap().generation;
        let shrunk: Vec<JobInfo> = active.iter().filter(|j| j.id != 3).cloned().collect();
        let _d2 = s.decide(&RoundInput {
            now: 360.0,
            round: 1,
            active: &shrunk,
            prev_plan: &d1.plan,
            spec: &spec,
            health: None,
        });
        assert_eq!(s.lp_repairs(), 1);
        let cache = s.lp_cache.as_ref().unwrap();
        assert!(cache.generation > gen0, "departure must bump the generation");
        assert!(
            !cache.structure.iter().any(|&(id, _)| id == 3),
            "stale window lingered after departure"
        );
        assert_eq!(
            cache.warm_generation, cache.generation,
            "warm handle must be stamped with the live generation"
        );
        assert_eq!(cache.pairs, candidate_pairs(&shrunk, true, 6));
        assert_eq!(cache.lp.num_vars(), shrunk.len() + cache.pairs.len());
    }

    #[test]
    fn repaired_window_solve_matches_cold() {
        // LP-level churn parity: depart a subset of jobs and arrive a new
        // one, carry the basis across with allocation_lp_maps + remap +
        // repair, and check the warm-finished solve matches a cold solve.
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        let jobs = crate::experiments::scalability::synthetic_active_jobs(40, 23);
        let pairs = candidate_pairs(&jobs, true, 6);
        let mut lp = build_allocation_lp(&jobs, &pairs, 64);
        allocation_objective_into(
            GavelObjective::Las,
            &jobs,
            &pairs,
            source.as_ref(),
            &mut lp.objective,
        );
        let (_, warm) = solve_sparse_lp(&lp, None).unwrap();
        let mut next: Vec<JobInfo> = jobs.iter().filter(|j| j.id % 7 != 3).cloned().collect();
        next.push(info(900, ModelKind::ResNet50, 2, 0.0));
        let new_pairs = candidate_pairs(&next, true, 6);
        let old_ids: Vec<u64> = jobs.iter().map(|j| j.id).collect();
        let (var_map, row_map) = allocation_lp_maps(&old_ids, &pairs, &next, &new_pairs);
        let mut lp2 = build_allocation_lp(&next, &new_pairs, 64);
        allocation_objective_into(
            GavelObjective::Las,
            &next,
            &new_pairs,
            source.as_ref(),
            &mut lp2.objective,
        );
        let carried = warm.remapped(&var_map, &row_map, lp2.num_vars(), lp2.num_rows());
        let repaired = repair_warm_start(&lp2, &carried);
        assert!(repaired.is_some(), "gavel-shaped churn should repair");
        let (hot, _) = solve_sparse_lp(&lp2, repaired.as_ref()).unwrap();
        let (cold, _) = solve_sparse_lp(&lp2, None).unwrap();
        assert!(
            (hot.objective - cold.objective).abs() <= 1e-6 * (1.0 + cold.objective.abs()),
            "repaired {} vs cold {}",
            hot.objective,
            cold.objective
        );
    }

    #[test]
    fn revised_allocation_matches_dense_rounding() {
        // Old-vs-new solver parity on a real Gavel-shaped instance: the
        // retained dense tableau solver run on the materialized LP (bounds
        // as rows) must agree with the revised solve — objective within
        // 1e-6 and identical allocations after 1e-6 rounding, including
        // the >0.25 pair-selection rule.
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        let jobs = crate::experiments::scalability::synthetic_active_jobs(48, 17);
        for objective in [GavelObjective::Las, GavelObjective::Ftf] {
            let pairs = candidate_pairs(&jobs, true, 6);
            assert!(!pairs.is_empty());
            let mut lp = build_allocation_lp(&jobs, &pairs, 64);
            allocation_objective_into(
                objective,
                &jobs,
                &pairs,
                source.as_ref(),
                &mut lp.objective,
            );
            let (rev, _) = solve_sparse_lp(&lp, None).unwrap();
            let dense = solve_lp(&lp.to_dense_lp()).unwrap();
            assert!(
                (rev.objective - dense.objective).abs()
                    <= 1e-6 * (1.0 + dense.objective.abs()),
                "{objective:?}: revised {} vs dense {}",
                rev.objective,
                dense.objective
            );
            let n = jobs.len();
            for (j, (a, b)) in rev.x.iter().zip(&dense.x).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-5,
                    "{objective:?}: x[{j}] diverges: {a} vs {b}"
                );
            }
            let chosen_rev: Vec<usize> =
                (0..pairs.len()).filter(|&p| rev.x[n + p] > 0.25).collect();
            let chosen_dense: Vec<usize> =
                (0..pairs.len()).filter(|&p| dense.x[n + p] > 0.25).collect();
            assert_eq!(chosen_rev, chosen_dense, "{objective:?} pair rounding");
        }
    }

    #[test]
    fn warm_round_is_not_slower_than_many_cold_solves() {
        // Not a wall-clock assert (bench_lp owns that); just that the warm
        // path yields a usable plan and the cache holds a warm handle.
        let spec = ClusterSpec::new(4, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..40)
            .map(|i| info(i, ModelKind::Vgg19, 1 + (i % 4) as u32, i as f64))
            .collect();
        let mut prev = PlacementPlan::new(16);
        let mut s = gavel(GavelObjective::Las, true);
        for round in 0..4 {
            let d = s.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev,
                spec: &spec,
                health: None,
            });
            d.plan.validate().unwrap();
            prev = d.plan;
        }
        assert_eq!(s.lp_stats(), (1, 3));
    }
}

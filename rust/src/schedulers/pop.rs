//! POP (Narayanan et al., SOSP'21): speed up Gavel by *partitioning* the
//! allocation problem — split jobs randomly into `k` groups, give each
//! group `1/k` of the GPUs, solve each sub-LP independently, and stitch
//! the sub-plans back together. Fig. 2 / Fig. 14 show POP is faster than
//! Gavel but still superlinear in active jobs — both effects fall out of
//! this construction.
//!
//! The `k` partition LPs solve concurrently on the process-wide shared
//! [`WorkerPool`] (deterministic chunked reduction over `&mut` partition
//! slots — no per-call pool of its own). The per-partition
//! [`GavelScheduler`]s are *retained across rounds*, so each partition
//! keeps its cached LP instance and warm-start basis: a round whose job
//! window is unchanged re-patches `k` objectives and re-solves from `k`
//! previous bases instead of rebuilding everything. Partitions are
//! independent, so the pooled solve is bit-identical to a sequential loop
//! (`parallel = false`, or a thread budget of 1), asserted by
//! `pop_partitions_parallel_matches_sequential`.

use std::sync::Arc;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::estimator::ThroughputSource;
use crate::faults::ClusterHealth;
use crate::matching::MatchingEngine;
use crate::policies::placement::MigrationMode;
use crate::policies::JobInfo;
use crate::util::pool::WorkerPool;

use super::pipeline::{self, RoundContext, StageProvider};
use super::{DecisionTimings, GavelObjective, GavelScheduler, RoundDecision, RoundInput, Scheduler};

/// Estimate-stage output carried to the Schedule stage: the partition
/// split of one round.
struct PopRound {
    k: usize,
    groups: Vec<Vec<JobInfo>>,
    sub_specs: Vec<ClusterSpec>,
    sub_prev: Vec<PlacementPlan>,
    node_base: Vec<usize>,
    /// Per-partition slice of the global GPU health; `None` for partitions
    /// whose slice is fully healthy (keeping those sub-schedulers on the
    /// pre-fault code path, same as the global `health: None` contract).
    sub_health: Vec<Option<ClusterHealth>>,
}

/// POP: k-way partitioned Gavel.
pub struct PopScheduler {
    pub partitions: usize,
    pub objective: GavelObjective,
    pub packing: bool,
    /// Solve partitions on the shared worker pool (bit-identical to the
    /// sequential path; the toggle exists for parity tests and timing
    /// studies).
    pub parallel: bool,
    source: Arc<dyn ThroughputSource>,
    engine: Arc<dyn MatchingEngine>,
    /// Retained per-partition schedulers (rebuilt only when the effective
    /// partition count changes); index p owns group p's LP cache.
    subs: Vec<GavelScheduler>,
    /// Round scratch between pipeline stages.
    round: Option<PopRound>,
    /// Legacy timing buckets absorbed from this round's sub-decisions
    /// (max across partitions — they ran concurrently).
    sub_timings: DecisionTimings,
}

impl PopScheduler {
    pub fn new(
        partitions: usize,
        objective: GavelObjective,
        packing: bool,
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> PopScheduler {
        assert!(partitions >= 1);
        PopScheduler {
            partitions,
            objective,
            packing,
            parallel: true,
            source,
            engine,
            subs: Vec::new(),
            round: None,
            sub_timings: DecisionTimings::default(),
        }
    }

    /// Make sure there are exactly `k` retained sub-schedulers with the
    /// current configuration.
    fn ensure_subs(&mut self, k: usize) {
        let stale = self.subs.len() != k
            || self
                .subs
                .first()
                .is_some_and(|s| s.objective != self.objective || s.packing != self.packing);
        if stale {
            self.subs = (0..k)
                .map(|_| {
                    let mut sub = GavelScheduler::new(
                        self.objective,
                        self.packing,
                        Arc::clone(&self.source),
                        Arc::clone(&self.engine),
                    );
                    sub.migration = MigrationMode::GavelBaseline;
                    sub
                })
                .collect();
        }
    }
}

/// Run each retained sub-scheduler on its input, either sequentially or
/// across the shared worker pool's deterministic chunked map. Results are
/// positionally deterministic and bit-identical between the two paths
/// because partitions share no state.
fn decide_partitions(
    subs: &mut [GavelScheduler],
    inputs: &[RoundInput],
    parallel: bool,
) -> Vec<RoundDecision> {
    let k = inputs.len();
    assert_eq!(subs.len(), k);
    if !parallel || k <= 1 {
        return subs
            .iter_mut()
            .zip(inputs)
            .map(|(sub, input)| sub.decide(input))
            .collect();
    }
    let mut slots: Vec<(&mut GavelScheduler, &RoundInput)> =
        subs.iter_mut().zip(inputs).collect();
    WorkerPool::global().map_mut(&mut slots, 0, 1, |_, slot| slot.0.decide(slot.1))
}

impl StageProvider for PopScheduler {
    /// The partition split: shrink k until a partition can host the
    /// largest job (POP's split assumes granular workloads), partition
    /// jobs round-robin (random split in POP; round-robin over the
    /// id-sorted list is an equivalent unbiased 1/k split here), nodes
    /// contiguously, and slice the previous physical plan per partition so
    /// sub-schedulers can still minimize migrations within their slice.
    fn estimate(&mut self, cx: &mut RoundContext) {
        let input = cx.input;
        let max_job_nodes = input
            .active
            .iter()
            .map(|j| (j.num_gpus as usize).div_ceil(input.spec.gpus_per_node))
            .max()
            .unwrap_or(1);
        let mut k = self.partitions.min(input.spec.num_nodes.max(1));
        while k > 1 && input.spec.num_nodes / k < max_job_nodes {
            k -= 1;
        }
        self.ensure_subs(k);

        let mut groups: Vec<Vec<JobInfo>> = vec![Vec::new(); k];
        for (i, j) in input.active.iter().enumerate() {
            groups[i % k].push(j.clone());
        }
        let nodes_per = input.spec.num_nodes / k;
        let sub_specs: Vec<ClusterSpec> = (0..k)
            .map(|p| {
                let extra = if p == k - 1 {
                    input.spec.num_nodes - nodes_per * k
                } else {
                    0
                };
                ClusterSpec::new(
                    (nodes_per + extra).max(1),
                    input.spec.gpus_per_node,
                    input.spec.gpu_type,
                )
            })
            .collect();
        let node_base: Vec<usize> = (0..k).map(|p| p * nodes_per).collect();
        let sub_prev: Vec<PlacementPlan> = (0..k)
            .map(|p| {
                let spec = &sub_specs[p];
                let mut plan = PlacementPlan::new(spec.total_gpus());
                let base_gpu = node_base[p] * input.spec.gpus_per_node;
                for g in 0..spec.total_gpus() {
                    let src = base_gpu + g;
                    let src_dead = input.health.is_some_and(|h| !h.is_healthy(src));
                    if src < input.prev_plan.num_gpus() && !src_dead {
                        for &j in input.prev_plan.jobs_on(src) {
                            if plan.jobs_on(g).contains(&j) {
                                continue;
                            }
                            plan.place(j, &[g]);
                        }
                    }
                }
                plan
            })
            .collect();
        // Slice the global health into per-partition views so each sub-LP
        // sees only its own dead GPUs (and fully healthy partitions stay
        // on the unmasked path).
        let sub_health: Vec<Option<ClusterHealth>> = (0..k)
            .map(|p| {
                let h = input.health?;
                let spec = &sub_specs[p];
                let base_gpu = node_base[p] * input.spec.gpus_per_node;
                let mut sub = ClusterHealth::new(spec.total_gpus());
                for g in 0..spec.total_gpus() {
                    if !h.is_healthy(base_gpu + g) {
                        sub.fail_gpu(g);
                    }
                }
                (!sub.all_healthy()).then_some(sub)
            })
            .collect();
        self.round = Some(PopRound {
            k,
            groups,
            sub_specs,
            sub_prev,
            node_base,
            sub_health,
        });
    }

    /// Solve the k sub-problems on the shared worker pool (POP's speedup)
    /// and stitch the sub-plans into the global plan.
    fn schedule(&mut self, cx: &mut RoundContext) {
        let input = cx.input;
        let round = self.round.take().expect("estimate stage ran");
        let inputs: Vec<RoundInput> = (0..round.k)
            .map(|p| RoundInput {
                now: input.now,
                round: input.round,
                active: &round.groups[p],
                prev_plan: &round.sub_prev[p],
                spec: &round.sub_specs[p],
                health: round.sub_health[p].as_ref(),
            })
            .collect();
        let results = decide_partitions(&mut self.subs, &inputs, self.parallel);

        let mut timings = DecisionTimings::default();
        for (p, d) in results.into_iter().enumerate() {
            let base_gpu = round.node_base[p] * input.spec.gpus_per_node;
            for j in d.plan.jobs() {
                let gpus: Vec<usize> =
                    d.plan.gpus_of(j).iter().map(|g| g + base_gpu).collect();
                cx.plan.place(j, &gpus);
            }
            cx.strategies.extend(d.strategies);
            cx.packed_pairs.extend(d.packed_pairs);
            // Parallel solve: wall time is the max across partitions;
            // matching-service counts add, solve wall takes the max.
            timings.scheduling_s = timings.scheduling_s.max(d.timings.scheduling_s);
            timings.packing_s = timings.packing_s.max(d.timings.packing_s);
            timings.migration_s = timings.migration_s.max(d.timings.migration_s);
            timings.matching.absorb_parallel(&d.timings.matching);
        }
        self.sub_timings = timings;
    }

    /// Packing happened inside the partition sub-decisions.
    fn pack(&mut self, _cx: &mut RoundContext) {}

    /// Partitions realized their slices physically already; the global
    /// count is the Definition-1 diff against the previous plan.
    fn migrate(&mut self, cx: &mut RoundContext) {
        cx.migrations = cx.plan.migrations_from(cx.input.prev_plan);
    }

    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
        let timings = std::mem::take(&mut self.sub_timings);
        RoundDecision {
            plan: std::mem::replace(
                &mut cx.plan,
                PlacementPlan::new(cx.input.spec.total_gpus()),
            ),
            strategies: std::mem::take(&mut cx.strategies),
            packed_pairs: std::mem::take(&mut cx.packed_pairs),
            migrations: cx.migrations,
            degraded: false,
            timings,
        }
    }

    /// Drop the retained sub-schedulers (each owns an LP cache that a
    /// panicked partition solve may have left inconsistent) plus the round
    /// scratch; `ensure_subs` recreates them next round.
    fn reset_after_failure(&mut self) {
        self.subs.clear();
        self.round = None;
        self.sub_timings = DecisionTimings::default();
    }
}

impl Scheduler for PopScheduler {
    fn name(&self) -> String {
        format!("pop-{}", self.partitions)
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        pipeline::run_round(self, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind;
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, gpus: u32) -> JobInfo {
        JobInfo {
            id,
            model: ModelKind::ResNet50,
            num_gpus: gpus,
            arrival_time: id as f64,
            attained_service: id as f64 * 10.0,
            total_iters: 10_000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 100.0,
            iso_tput: 10.0,
        }
    }

    fn pop(k: usize) -> PopScheduler {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        PopScheduler::new(k, GavelObjective::Las, true, source, Arc::new(HungarianEngine))
    }

    #[test]
    fn stitched_plan_is_valid() {
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..10).map(|i| info(i, 1 + (i % 2) as u32)).collect();
        let prev = PlacementPlan::new(8);
        let mut s = pop(4);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        d.plan.validate().unwrap();
        assert!(!d.plan.jobs().is_empty());
    }

    #[test]
    fn pop_partition_lp_faster_than_full_gavel_lp() {
        // The POP claim at LP granularity: the slowest of the k partition
        // solves (scheduling_s takes the max) is far cheaper than the full
        // LP at the same job count — robust even on the revised simplex.
        let spec = ClusterSpec::new(8, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..512).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(32);
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        let mut g = GavelScheduler::new(
            GavelObjective::Las,
            true,
            Arc::clone(&source),
            Arc::new(HungarianEngine),
        );
        let dg = g.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        let mut p = pop(8);
        let dp = p.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert!(
            dp.timings.scheduling_s < dg.timings.scheduling_s,
            "pop LP {} vs gavel LP {}",
            dp.timings.scheduling_s,
            dg.timings.scheduling_s
        );
    }

    #[test]
    fn single_partition_equals_gavel_shape() {
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..4).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(4);
        let mut s = pop(1);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        d.plan.validate().unwrap();
        assert_eq!(d.plan.jobs().len(), 4);
    }

    #[test]
    fn pop_partitions_parallel_matches_sequential() {
        // Bit-parity between the pooled and sequential partition solves,
        // across several rounds so the retained warm-start state is
        // exercised on both sides.
        let spec = ClusterSpec::new(8, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..48).map(|i| info(i, 1 + (i % 2) as u32)).collect();
        let mut par = pop(4);
        let mut seq = pop(4);
        seq.parallel = false;
        let mut prev_par = PlacementPlan::new(16);
        let mut prev_seq = PlacementPlan::new(16);
        for round in 0..4 {
            // Drift the weights between rounds (warm-start path) and churn
            // one job every other round (rebuild path).
            let drifted: Vec<JobInfo> = active
                .iter()
                .map(|j| {
                    let mut j = j.clone();
                    j.attained_service += round as f64 * 360.0;
                    if round >= 2 && j.id == 7 {
                        j.id = 700 + round;
                    }
                    j
                })
                .collect();
            let dp = par.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &drifted,
                prev_plan: &prev_par,
                spec: &spec,
                health: None,
            });
            let ds = seq.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &drifted,
                prev_plan: &prev_seq,
                spec: &spec,
                health: None,
            });
            assert_eq!(dp.plan, ds.plan, "round {round} plans diverge");
            assert_eq!(dp.migrations, ds.migrations, "round {round} migrations");
            assert_eq!(dp.packed_pairs, ds.packed_pairs, "round {round} pairs");
            assert_eq!(dp.strategies, ds.strategies, "round {round} strategies");
            prev_par = dp.plan;
            prev_seq = ds.plan;
        }
    }

    #[test]
    fn faulted_partitions_keep_jobs_off_dead_gpus() {
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..10).map(|i| info(i, 1)).collect();
        // Dead GPUs land in two different partitions; one partition stays
        // fully healthy and must take the unmasked path.
        let mut health = ClusterHealth::new(8);
        health.fail_gpu(1);
        health.fail_gpu(6);
        let mut s = pop(4);
        let mut prev = PlacementPlan::new(8);
        for round in 0..3u64 {
            let d = s.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev,
                spec: &spec,
                health: Some(&health),
            });
            assert!(!d.degraded);
            d.plan.validate().unwrap();
            health.validate_plan(&d.plan).unwrap();
            assert!(d.plan.jobs_on(1).is_empty(), "round {round} used dead GPU 1");
            assert!(d.plan.jobs_on(6).is_empty(), "round {round} used dead GPU 6");
            assert!(!d.plan.jobs().is_empty());
            prev = d.plan;
        }
    }

    #[test]
    fn retained_partitions_warm_start_across_rounds() {
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..24).map(|i| info(i, 1)).collect();
        let mut s = pop(4);
        let mut prev = PlacementPlan::new(8);
        for round in 0..3 {
            let d = s.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev,
                spec: &spec,
                health: None,
            });
            d.plan.validate().unwrap();
            prev = d.plan;
        }
        // Every partition rebuilt once (round 0) and patched twice.
        for sub in &s.subs {
            assert_eq!(sub.lp_stats(), (1, 2));
        }
    }
}

//! POP (Narayanan et al., SOSP'21): speed up Gavel by *partitioning* the
//! allocation problem — split jobs randomly into `k` groups, give each
//! group `1/k` of the GPUs, solve each sub-LP independently (in parallel
//! threads here), and stitch the sub-plans back together. Fig. 2 / Fig. 14
//! show POP is faster than Gavel but still superlinear in active jobs —
//! both effects fall out of this construction.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::{ClusterSpec, PlacementPlan};
use crate::estimator::ThroughputSource;
use crate::matching::MatchingEngine;
use crate::policies::placement::MigrationMode;
use crate::policies::JobInfo;

use super::{DecisionTimings, GavelObjective, GavelScheduler, RoundDecision, RoundInput, Scheduler};

/// POP: k-way partitioned Gavel.
pub struct PopScheduler {
    pub partitions: usize,
    pub objective: GavelObjective,
    pub packing: bool,
    source: Arc<dyn ThroughputSource>,
    engine: Arc<dyn MatchingEngine>,
}

impl PopScheduler {
    pub fn new(
        partitions: usize,
        objective: GavelObjective,
        packing: bool,
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> PopScheduler {
        assert!(partitions >= 1);
        PopScheduler {
            partitions,
            objective,
            packing,
            source,
            engine,
        }
    }
}

impl Scheduler for PopScheduler {
    fn name(&self) -> String {
        format!("pop-{}", self.partitions)
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        let t_total = Instant::now();
        // A partition must be able to host the largest job (POP's split
        // assumes granular workloads); shrink k until that holds.
        let max_job_nodes = input
            .active
            .iter()
            .map(|j| (j.num_gpus as usize).div_ceil(input.spec.gpus_per_node))
            .max()
            .unwrap_or(1);
        let mut k = self.partitions.min(input.spec.num_nodes.max(1));
        while k > 1 && input.spec.num_nodes / k < max_job_nodes {
            k -= 1;
        }

        // Partition jobs round-robin (random split in POP; round-robin over
        // the id-sorted list is an equivalent unbiased 1/k split here) and
        // nodes contiguously.
        let mut groups: Vec<Vec<JobInfo>> = vec![Vec::new(); k];
        for (i, j) in input.active.iter().enumerate() {
            groups[i % k].push(j.clone());
        }
        let nodes_per = input.spec.num_nodes / k;
        let sub_specs: Vec<ClusterSpec> = (0..k)
            .map(|p| {
                let extra = if p == k - 1 {
                    input.spec.num_nodes - nodes_per * k
                } else {
                    0
                };
                ClusterSpec::new(
                    (nodes_per + extra).max(1),
                    input.spec.gpus_per_node,
                    input.spec.gpu_type,
                )
            })
            .collect();

        // Slice the previous physical plan per partition so sub-schedulers
        // can still minimize migrations within their slice.
        let node_base: Vec<usize> = (0..k).map(|p| p * nodes_per).collect();
        let sub_prev: Vec<PlacementPlan> = (0..k)
            .map(|p| {
                let spec = &sub_specs[p];
                let mut plan = PlacementPlan::new(spec.total_gpus());
                let base_gpu = node_base[p] * input.spec.gpus_per_node;
                for g in 0..spec.total_gpus() {
                    let src = base_gpu + g;
                    if src < input.prev_plan.num_gpus() {
                        for &j in input.prev_plan.jobs_on(src) {
                            if plan.jobs_on(g).contains(&j) {
                                continue;
                            }
                            plan.place(j, &[g]);
                        }
                    }
                }
                plan
            })
            .collect();

        // Solve the k sub-problems in parallel threads (POP's speedup).
        let results: Vec<RoundDecision> = std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for p in 0..k {
                let group = &groups[p];
                let spec = &sub_specs[p];
                let prev = &sub_prev[p];
                let source = Arc::clone(&self.source);
                let engine = Arc::clone(&self.engine);
                let objective = self.objective;
                let packing = self.packing;
                let now = input.now;
                let round = input.round;
                handles.push(scope.spawn(move || {
                    let mut sub = GavelScheduler::new(objective, packing, source, engine);
                    sub.migration = MigrationMode::GavelBaseline;
                    sub.decide(&RoundInput {
                        now,
                        round,
                        active: group,
                        prev_plan: prev,
                        spec,
                    })
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        // Stitch sub-plans into the global plan.
        let mut plan = PlacementPlan::new(input.spec.total_gpus());
        let mut strategies = BTreeMap::new();
        let mut packed_pairs = Vec::new();
        let mut timings = DecisionTimings::default();
        for (p, d) in results.into_iter().enumerate() {
            let base_gpu = node_base[p] * input.spec.gpus_per_node;
            for j in d.plan.jobs() {
                let gpus: Vec<usize> = d.plan.gpus_of(j).iter().map(|g| g + base_gpu).collect();
                plan.place(j, &gpus);
            }
            strategies.extend(d.strategies);
            packed_pairs.extend(d.packed_pairs);
            // Parallel solve: wall time is the max across partitions;
            // matching-service counts add, solve wall takes the max.
            timings.scheduling_s = timings.scheduling_s.max(d.timings.scheduling_s);
            timings.packing_s = timings.packing_s.max(d.timings.packing_s);
            timings.migration_s = timings.migration_s.max(d.timings.migration_s);
            timings.matching.absorb_parallel(&d.timings.matching);
        }
        let migrations = plan.migrations_from(input.prev_plan);
        timings.total_s = t_total.elapsed().as_secs_f64();

        RoundDecision {
            plan,
            strategies,
            packed_pairs,
            migrations,
            timings,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuType;
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind;
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, gpus: u32) -> JobInfo {
        JobInfo {
            id,
            model: ModelKind::ResNet50,
            num_gpus: gpus,
            arrival_time: id as f64,
            attained_service: id as f64 * 10.0,
            total_iters: 10_000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 100.0,
            iso_tput: 10.0,
        }
    }

    fn pop(k: usize) -> PopScheduler {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        PopScheduler::new(k, GavelObjective::Las, true, source, Arc::new(HungarianEngine))
    }

    #[test]
    fn stitched_plan_is_valid() {
        let spec = ClusterSpec::new(4, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..10).map(|i| info(i, 1 + (i % 2) as u32)).collect();
        let prev = PlacementPlan::new(8);
        let mut s = pop(4);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        d.plan.validate().unwrap();
        assert!(!d.plan.jobs().is_empty());
    }

    #[test]
    fn pop_faster_than_gavel_at_scale() {
        let spec = ClusterSpec::new(8, 4, GpuType::A100);
        let active: Vec<JobInfo> = (0..160).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(32);
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        let mut g = GavelScheduler::new(
            GavelObjective::Las,
            true,
            Arc::clone(&source),
            Arc::new(HungarianEngine),
        );
        let dg = g.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        let mut p = pop(8);
        let dp = p.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        assert!(
            dp.timings.total_s < dg.timings.total_s,
            "pop {} vs gavel {}",
            dp.timings.total_s,
            dg.timings.total_s
        );
    }

    #[test]
    fn single_partition_equals_gavel_shape() {
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let active: Vec<JobInfo> = (0..4).map(|i| info(i, 1)).collect();
        let prev = PlacementPlan::new(4);
        let mut s = pop(1);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
        });
        d.plan.validate().unwrap();
        assert_eq!(d.plan.jobs().len(), 4);
    }
}

//! The Tesserae scheduler (§3.2, Listing 1): any scheduling policy for the
//! priority order, then allocate → pack (Algorithm 4) → migrate
//! (Algorithms 2+3). The Tiresias and Tiresias (Single) baselines are
//! configurations of the same engine with packing/migration toggled.

use std::sync::Arc;

use crate::estimator::ThroughputSource;
use crate::matching::{MatchingEngine, MatchingService, ServiceConfig};
use crate::policies::placement::{
    allocate_masked, migrate_masked, pack_with, MigrationMode, PackingConfig,
};
use crate::policies::scheduling::SchedulingPolicy;
use crate::policies::JobInfo;

use super::pipeline::{self, RoundContext, Stage, StageProvider};
use super::{best_isolated_strategies, DecisionTimings, RoundDecision, RoundInput, Scheduler};

/// Tesserae's composable scheduler engine.
pub struct TesseraeScheduler {
    label: String,
    policy: Box<dyn SchedulingPolicy>,
    source: Arc<dyn ThroughputSource>,
    engine: Arc<dyn MatchingEngine>,
    /// Persistent across rounds so the matching service's cost-matrix
    /// cache and dual-price store carry over (the cross-round win).
    service: MatchingService,
    /// `None` disables GPU sharing entirely.
    pub packing: Option<PackingConfig>,
    pub migration: MigrationMode,
}

impl TesseraeScheduler {
    pub fn new(
        label: &str,
        policy: Box<dyn SchedulingPolicy>,
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
        packing: Option<PackingConfig>,
        migration: MigrationMode,
    ) -> TesseraeScheduler {
        TesseraeScheduler {
            label: label.to_string(),
            policy,
            source,
            engine,
            service: MatchingService::with_defaults(),
            packing,
            migration,
        }
    }

    /// Replace the matching-service configuration (e.g.
    /// [`ServiceConfig::sequential_reference`] for the parity tests and
    /// the batched-vs-sequential benches). Drops any cached state.
    pub fn set_service_config(&mut self, cfg: ServiceConfig) {
        self.service = MatchingService::new(cfg);
    }

    /// Tesserae-T: Tiresias (2D-LAS) scheduling + full packing + the
    /// graph-matching migration policy.
    pub fn tesserae_t(
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> TesseraeScheduler {
        Self::new(
            "tesserae-t",
            Box::new(crate::policies::scheduling::TiresiasLas::default()),
            source,
            engine,
            Some(PackingConfig::default()),
            MigrationMode::Tesserae,
        )
    }

    /// Tesserae-FTF: finish-time-fairness scheduling + packing + migration.
    pub fn tesserae_ftf(
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> TesseraeScheduler {
        Self::new(
            "tesserae-ftf",
            Box::new(crate::policies::scheduling::ThemisFtf::default()),
            source,
            engine,
            Some(PackingConfig::default()),
            MigrationMode::Tesserae,
        )
    }

    /// Plain Tiresias: LAS scheduling, no packing, no migration remapping.
    pub fn tiresias(
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> TesseraeScheduler {
        Self::new(
            "tiresias",
            Box::new(crate::policies::scheduling::TiresiasLas::default()),
            source,
            engine,
            None,
            MigrationMode::GavelBaseline,
        )
    }

    /// Tiresias (Single): Tiresias scheduling + Tesserae packing restricted
    /// to 1-GPU jobs (the Lucid/Pollux-style baseline of §6.1).
    pub fn tiresias_single(
        source: Arc<dyn ThroughputSource>,
        engine: Arc<dyn MatchingEngine>,
    ) -> TesseraeScheduler {
        Self::new(
            "tiresias-single",
            Box::new(crate::policies::scheduling::TiresiasLas::default()),
            source,
            engine,
            Some(PackingConfig {
                max_pack_gpus: 1,
                ..Default::default()
            }),
            MigrationMode::Tesserae,
        )
    }
}

impl StageProvider for TesseraeScheduler {
    /// Scheduling policy: priority order (Listing 1 line 3).
    fn estimate(&mut self, cx: &mut RoundContext) {
        cx.order = self.policy.order(cx.input.active);
    }

    /// Allocation without packing (lines 5-12), then each placed job's
    /// best isolated strategy (candidate enumeration sharded per job
    /// across the worker pool; packing overrides individual entries).
    fn schedule(&mut self, cx: &mut RoundContext) {
        let ordered: Vec<&JobInfo> = cx.order.iter().map(|&i| &cx.input.active[i]).collect();
        // Health-masked: dead GPUs never enter a node's free list, so the
        // logical plan (and everything packed onto it) is healthy-only.
        let alloc = allocate_masked(cx.input.spec, &ordered, cx.input.health);
        cx.plan = alloc.plan;
        cx.placed = alloc.placed;
        cx.pending = alloc.pending;
        cx.by_id = cx.input.active.iter().map(|j| (j.id, j)).collect();
        let placed_infos: Vec<&JobInfo> = cx.placed.iter().map(|id| cx.by_id[id]).collect();
        cx.strategies = best_isolated_strategies(&placed_infos, self.source.as_ref());
    }

    /// Packing (lines 13-15).
    fn pack(&mut self, cx: &mut RoundContext) {
        let Some(cfg) = &self.packing else {
            return;
        };
        let placed_infos: Vec<&JobInfo> = cx.placed.iter().map(|id| cx.by_id[id]).collect();
        let pending_infos: Vec<&JobInfo> = cx.pending.iter().map(|id| cx.by_id[id]).collect();
        let pairs = pack_with(
            &placed_infos,
            &pending_infos,
            self.source.as_ref(),
            cfg,
            self.engine.as_ref(),
            &mut self.service,
        );
        for p in pairs {
            let gpus = cx.plan.gpus_of(p.placed).to_vec();
            cx.plan.place(p.pending, &gpus);
            cx.strategies.insert(p.placed, p.placed_strategy.clone());
            cx.strategies.insert(p.pending, p.pending_strategy.clone());
            cx.packed_pairs.push((p.placed, p.pending));
        }
    }

    /// Migration minimization (line 16). Drains the round's service stats
    /// (packing included) into the outcome.
    fn migrate(&mut self, cx: &mut RoundContext) {
        cx.outcome = Some(migrate_masked(
            cx.input.spec,
            cx.input.prev_plan,
            &cx.plan,
            self.migration,
            self.engine.as_ref(),
            &mut self.service,
            cx.input.health,
        ));
    }

    fn commit(&mut self, cx: &mut RoundContext) -> RoundDecision {
        let outcome = cx.outcome.take().expect("migrate stage ran");
        RoundDecision {
            plan: outcome.plan,
            strategies: std::mem::take(&mut cx.strategies),
            packed_pairs: std::mem::take(&mut cx.packed_pairs),
            migrations: outcome.migrations,
            degraded: false,
            timings: DecisionTimings {
                stage_s: cx.stage_s,
                scheduling_s: cx.stage_s[Stage::Estimate.index()]
                    + cx.stage_s[Stage::Schedule.index()],
                packing_s: cx.stage_s[Stage::Pack.index()],
                migration_s: outcome.decide_time_s,
                total_s: 0.0, // driver fills
                matching: outcome.service,
            },
        }
    }
}

impl Scheduler for TesseraeScheduler {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn decide(&mut self, input: &RoundInput) -> RoundDecision {
        pipeline::run_round(self, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ClusterSpec, GpuType, PlacementPlan};
    use crate::estimator::OracleEstimator;
    use crate::jobs::ModelKind;
    use crate::matching::HungarianEngine;
    use crate::profiler::Profiler;

    fn info(id: u64, model: ModelKind, gpus: u32, attained: f64) -> JobInfo {
        JobInfo {
            id,
            model,
            num_gpus: gpus,
            arrival_time: id as f64,
            attained_service: attained,
            total_iters: 10_000.0,
            completed_iters: 0.0,
            rounds_received: 0,
            now: 1000.0,
            iso_tput: 10.0,
        }
    }

    fn make(
        sched: fn(Arc<dyn ThroughputSource>, Arc<dyn MatchingEngine>) -> TesseraeScheduler,
    ) -> TesseraeScheduler {
        let source: Arc<dyn ThroughputSource> =
            Arc::new(OracleEstimator::new(Profiler::new(GpuType::A100, 42)));
        sched(source, Arc::new(HungarianEngine))
    }

    #[test]
    fn tesserae_t_packs_pending_jobs() {
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let active = vec![
            info(1, ModelKind::PointNet, 1, 0.0),
            info(2, ModelKind::Dcgan, 1, 0.0),
            info(3, ModelKind::ResNet50, 1, 0.0),
            info(4, ModelKind::PointNet, 1, 0.0),
        ];
        let prev = PlacementPlan::new(2);
        let mut s = make(TesseraeScheduler::tesserae_t);
        let d = s.decide(&RoundInput {
            now: 1000.0,
            round: 1,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        d.plan.validate().unwrap();
        // 2 GPUs, 4 single-GPU jobs: two placed + up to two packed.
        assert!(d.plan.jobs().len() >= 2);
        assert!(!d.packed_pairs.is_empty(), "expected packing on full cluster");
        assert!(d.plan.jobs().len() == 2 + d.packed_pairs.len());
    }

    #[test]
    fn tiresias_never_packs() {
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let active = vec![
            info(1, ModelKind::PointNet, 1, 0.0),
            info(2, ModelKind::Dcgan, 1, 0.0),
            info(3, ModelKind::ResNet50, 1, 0.0),
        ];
        let prev = PlacementPlan::new(2);
        let mut s = make(TesseraeScheduler::tiresias);
        let d = s.decide(&RoundInput {
            now: 1000.0,
            round: 1,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert!(d.packed_pairs.is_empty());
        assert_eq!(d.plan.jobs().len(), 2);
    }

    #[test]
    fn las_priority_decides_who_runs() {
        let spec = ClusterSpec::new(1, 1, GpuType::A100);
        // Job 2 has much lower attained service -> gets the single GPU.
        let active = vec![
            info(1, ModelKind::ResNet50, 1, 100_000.0),
            info(2, ModelKind::Dcgan, 1, 10.0),
        ];
        let prev = PlacementPlan::new(1);
        let mut s = make(TesseraeScheduler::tiresias);
        let d = s.decide(&RoundInput {
            now: 1000.0,
            round: 1,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert!(d.plan.jobs().contains(&2));
    }

    #[test]
    fn migration_stability_across_identical_rounds() {
        // Same active set in consecutive rounds: the second decision must
        // produce zero migrations even though the allocator is free to
        // relabel GPUs.
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let active = vec![
            info(1, ModelKind::ResNet50, 2, 50.0),
            info(2, ModelKind::Dcgan, 1, 30.0),
            info(3, ModelKind::PointNet, 1, 20.0),
        ];
        let mut s = make(TesseraeScheduler::tesserae_t);
        let prev = PlacementPlan::new(4);
        let d1 = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        let d2 = s.decide(&RoundInput {
            now: 360.0,
            round: 1,
            active: &active,
            prev_plan: &d1.plan,
            spec: &spec,
            health: None,
        });
        assert_eq!(d2.migrations, 0, "plan1 {:?} plan2 {:?}", d1.plan, d2.plan);
    }

    #[test]
    fn timings_populated() {
        let spec = ClusterSpec::new(1, 2, GpuType::A100);
        let active = vec![info(1, ModelKind::PointNet, 1, 0.0)];
        let prev = PlacementPlan::new(2);
        let mut s = make(TesseraeScheduler::tesserae_t);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        assert!(d.timings.total_s > 0.0);
        assert!(d.timings.total_s >= d.timings.migration_s);
        // Per-stage wall clocks are populated by the pipeline driver and
        // account for the round (the driver debug-asserts the tolerance;
        // here we only check the invariant directions).
        let staged: f64 = d.timings.stage_s.iter().sum();
        assert!(staged > 0.0 && staged <= d.timings.total_s);
        assert!(
            (d.timings.scheduling_s
                - d.timings.stage(Stage::Estimate)
                - d.timings.stage(Stage::Schedule))
            .abs()
                < 1e-12
        );
        assert!((d.timings.packing_s - d.timings.stage(Stage::Pack)).abs() < 1e-12);
        // The migration stage generated matching instances and the drained
        // service stats rode along on the decision.
        assert!(d.timings.matching.instances > 0);
        assert!(d.timings.matching.solved <= d.timings.matching.instances);
    }

    #[test]
    fn sequential_reference_config_matches_default_service() {
        use crate::matching::ServiceConfig;
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let active = vec![
            info(1, ModelKind::ResNet50, 2, 50.0),
            info(2, ModelKind::Dcgan, 1, 30.0),
            info(3, ModelKind::PointNet, 1, 20.0),
            info(4, ModelKind::Dcgan, 1, 10.0),
        ];
        let mut fast = make(TesseraeScheduler::tesserae_t);
        let mut slow = make(TesseraeScheduler::tesserae_t);
        slow.set_service_config(ServiceConfig::sequential_reference());
        let mut prev_fast = PlacementPlan::new(4);
        let mut prev_slow = PlacementPlan::new(4);
        for round in 0..4u64 {
            let df = fast.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev_fast,
                spec: &spec,
                health: None,
            });
            let ds = slow.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev_slow,
                spec: &spec,
                health: None,
            });
            assert_eq!(df.plan, ds.plan, "round {round} plans diverged");
            assert_eq!(df.migrations, ds.migrations);
            assert_eq!(df.packed_pairs, ds.packed_pairs);
            prev_fast = df.plan;
            prev_slow = ds.plan;
        }
    }

    #[test]
    fn faulted_cluster_schedules_around_dead_gpus() {
        let spec = ClusterSpec::new(2, 2, GpuType::A100);
        let mut health = crate::faults::ClusterHealth::new(4);
        health.fail_gpu(0);
        let active = vec![
            info(1, ModelKind::ResNet50, 2, 50.0),
            info(2, ModelKind::Dcgan, 1, 30.0),
            info(3, ModelKind::PointNet, 1, 20.0),
        ];
        let mut s = make(TesseraeScheduler::tesserae_t);
        let mut prev = PlacementPlan::new(4);
        for round in 0..3u64 {
            let d = s.decide(&RoundInput {
                now: round as f64 * 360.0,
                round,
                active: &active,
                prev_plan: &prev,
                spec: &spec,
                health: Some(&health),
            });
            assert!(!d.degraded);
            d.plan.validate().unwrap();
            health.validate_plan(&d.plan).unwrap();
            assert!(d.plan.jobs_on(0).is_empty(), "round {round} used a dead GPU");
            prev = d.plan;
        }
        // Steady state on a faulted cluster is still migration-free.
        let d = s.decide(&RoundInput {
            now: 3.0 * 360.0,
            round: 3,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: Some(&health),
        });
        assert_eq!(d.migrations, 0, "{:?} vs {prev:?}", d.plan);
    }

    #[test]
    fn llm_gets_nontrivial_strategy() {
        let spec = ClusterSpec::new(2, 4, GpuType::A100);
        let active = vec![info(1, ModelKind::Gpt3_3B, 8, 0.0)];
        let prev = PlacementPlan::new(8);
        let mut s = make(TesseraeScheduler::tesserae_t);
        let d = s.decide(&RoundInput {
            now: 0.0,
            round: 0,
            active: &active,
            prev_plan: &prev,
            spec: &spec,
            health: None,
        });
        let strat = d.strategies.get(&1).unwrap();
        assert!(
            matches!(strat, crate::jobs::ParallelismStrategy::Pipeline(_))
                || *strat == crate::jobs::ParallelismStrategy::DataParallel
        );
    }
}

//! Compressed-sparse-column (CSC) matrix storage.
//!
//! This is the constraint-matrix substrate for the revised-simplex LP core
//! (`super::revised`). Gavel-shaped allocation LPs are extremely sparse —
//! one dense capacity row plus per-job coupling rows with ≤ 3 nonzeros per
//! column — so the simplex never touches an `m × n` dense array: pricing
//! walks columns, and the basis factorization gathers columns on demand.

use super::matrix::Matrix;

/// Immutable CSC matrix: column `j`'s nonzeros are
/// `row_idx[col_ptr[j]..col_ptr[j + 1]]` / `values[...]`, with row indices
/// strictly increasing within a column.
#[derive(Debug, Clone, PartialEq)]
pub struct CscMatrix {
    rows: usize,
    cols: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<usize>,
    values: Vec<f64>,
}

impl CscMatrix {
    /// An all-zero matrix (every column empty).
    pub fn zeros(rows: usize, cols: usize) -> CscMatrix {
        CscMatrix {
            rows,
            cols,
            col_ptr: vec![0; cols + 1],
            row_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored (nonzero) entries.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Column `j` as parallel `(row_indices, values)` slices.
    #[inline]
    pub fn col(&self, j: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.col_ptr[j], self.col_ptr[j + 1]);
        (&self.row_idx[lo..hi], &self.values[lo..hi])
    }

    /// Dot product of column `j` with a dense row-space vector.
    #[inline]
    pub fn col_dot(&self, j: usize, y: &[f64]) -> f64 {
        let (rows, vals) = self.col(j);
        rows.iter().zip(vals).map(|(&i, &v)| y[i] * v).sum()
    }

    /// `out += scale * column j` (scatter into a dense row-space vector).
    #[inline]
    pub fn col_axpy(&self, j: usize, scale: f64, out: &mut [f64]) {
        let (rows, vals) = self.col(j);
        for (&i, &v) in rows.iter().zip(vals) {
            out[i] += scale * v;
        }
    }

    /// Build from a dense matrix, dropping exact zeros.
    pub fn from_dense(a: &Matrix) -> CscMatrix {
        let mut b = CscBuilder::new(a.rows(), a.cols());
        for j in 0..a.cols() {
            for i in 0..a.rows() {
                let v = a.get(i, j);
                if v != 0.0 {
                    b.push(i, v);
                }
            }
            b.end_col();
        }
        b.finish()
    }

    /// Materialize as a dense matrix (tests and the dense-parity path).
    pub fn to_dense(&self) -> Matrix {
        let mut a = Matrix::zeros(self.rows, self.cols);
        for j in 0..self.cols {
            let (rows, vals) = self.col(j);
            for (&i, &v) in rows.iter().zip(vals) {
                a.set(i, j, v);
            }
        }
        a
    }

    /// `A x` for a dense `x` of length `cols()`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec shape mismatch");
        let mut out = vec![0.0; self.rows];
        for (j, &xj) in x.iter().enumerate() {
            if xj != 0.0 {
                self.col_axpy(j, xj, &mut out);
            }
        }
        out
    }

    /// Reopen this matrix for an in-place rebuild with `rows` rows: the
    /// column/value buffers are kept (capacity and all) but logically
    /// emptied, so rebuilding a same-shaped instance round over round is
    /// allocation-free once the buffers have grown to steady-state size.
    /// Push columns with [`CscMatrix::push`] / [`CscMatrix::end_col`]
    /// exactly as with [`CscBuilder`].
    pub fn reset(&mut self, rows: usize) {
        self.rows = rows;
        self.cols = 0;
        self.col_ptr.clear();
        self.col_ptr.push(0);
        self.row_idx.clear();
        self.values.clear();
    }

    /// Append a nonzero to the current (open) column of an in-place
    /// rebuild started by [`CscMatrix::reset`]. Rows must be pushed in
    /// strictly increasing order within a column.
    pub fn push(&mut self, row: usize, value: f64) {
        assert!(row < self.rows, "row {row} out of bounds ({})", self.rows);
        let col_start = self.col_ptr[self.cols];
        if self.row_idx.len() > col_start {
            let prev = self.row_idx[self.row_idx.len() - 1];
            assert!(prev < row, "rows must increase within a column");
        }
        self.row_idx.push(row);
        self.values.push(value);
    }

    /// Close the current column of an in-place rebuild (empty columns are
    /// fine).
    pub fn end_col(&mut self) {
        self.cols += 1;
        self.col_ptr.push(self.row_idx.len());
    }
}

/// Incremental column-by-column CSC builder. Rows must be pushed in
/// strictly increasing order within each column; `end_col` closes the
/// current column (empty columns are fine).
#[derive(Debug)]
pub struct CscBuilder {
    rows: usize,
    cols: usize,
    col_ptr: Vec<usize>,
    row_idx: Vec<usize>,
    values: Vec<f64>,
}

impl CscBuilder {
    pub fn new(rows: usize, cols_hint: usize) -> CscBuilder {
        let mut col_ptr = Vec::with_capacity(cols_hint + 1);
        col_ptr.push(0);
        CscBuilder {
            rows,
            cols: 0,
            col_ptr,
            row_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    /// Append a nonzero to the current (open) column.
    pub fn push(&mut self, row: usize, value: f64) {
        assert!(row < self.rows, "row {row} out of bounds ({})", self.rows);
        let col_start = self.col_ptr[self.cols];
        if self.row_idx.len() > col_start {
            let prev = self.row_idx[self.row_idx.len() - 1];
            assert!(prev < row, "rows must increase within a column");
        }
        self.row_idx.push(row);
        self.values.push(value);
    }

    /// Close the current column.
    pub fn end_col(&mut self) {
        self.cols += 1;
        self.col_ptr.push(self.row_idx.len());
    }

    pub fn finish(self) -> CscMatrix {
        CscMatrix {
            rows: self.rows,
            cols: self.cols,
            col_ptr: self.col_ptr,
            row_idx: self.row_idx,
            values: self.values,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn example() -> Matrix {
        Matrix::from_rows(&[
            &[1.0, 0.0, 2.0],
            &[0.0, 0.0, 3.0],
            &[4.0, 5.0, 0.0],
        ])
    }

    #[test]
    fn dense_roundtrip() {
        let a = example();
        let s = CscMatrix::from_dense(&a);
        assert_eq!(s.nnz(), 5);
        assert_eq!(s.to_dense(), a);
    }

    #[test]
    fn builder_matches_from_dense() {
        let mut b = CscBuilder::new(3, 3);
        b.push(0, 1.0);
        b.push(2, 4.0);
        b.end_col();
        b.push(2, 5.0);
        b.end_col();
        b.push(0, 2.0);
        b.push(1, 3.0);
        b.end_col();
        assert_eq!(b.finish(), CscMatrix::from_dense(&example()));
    }

    #[test]
    fn col_access_and_dot() {
        let s = CscMatrix::from_dense(&example());
        let (rows, vals) = s.col(2);
        assert_eq!(rows, &[0, 1]);
        assert_eq!(vals, &[2.0, 3.0]);
        let y = [1.0, 10.0, 100.0];
        assert_eq!(s.col_dot(0, &y), 1.0 + 400.0);
        assert_eq!(s.col_dot(2, &y), 2.0 + 30.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = example();
        let s = CscMatrix::from_dense(&a);
        let x = vec![2.0, -1.0, 0.5];
        assert_eq!(s.matvec(&x), a.matvec(&x));
    }

    #[test]
    fn empty_columns_are_fine() {
        let mut b = CscBuilder::new(2, 3);
        b.end_col();
        b.push(1, 7.0);
        b.end_col();
        b.end_col();
        let s = b.finish();
        assert_eq!(s.cols(), 3);
        assert_eq!(s.col(0), (&[][..], &[][..]));
        assert_eq!(s.col(1), (&[1][..], &[7.0][..]));
        assert_eq!(s.nnz(), 1);
    }

    #[test]
    #[should_panic(expected = "rows must increase")]
    fn builder_rejects_unsorted_rows() {
        let mut b = CscBuilder::new(3, 1);
        b.push(2, 1.0);
        b.push(1, 1.0);
    }

    #[test]
    fn in_place_rebuild_matches_builder_and_reuses_buffers() {
        let mut s = CscMatrix::from_dense(&example());
        let cap_rows = s.row_idx.capacity();
        let cap_vals = s.values.capacity();

        // Rebuild a different (smaller) instance in place.
        s.reset(2);
        s.push(1, 7.0);
        s.end_col();
        s.end_col();
        let mut b = CscBuilder::new(2, 2);
        b.push(1, 7.0);
        b.end_col();
        b.end_col();
        assert_eq!(s, b.finish());
        assert_eq!(s.row_idx.capacity(), cap_rows, "rebuild must not shrink buffers");
        assert_eq!(s.values.capacity(), cap_vals);

        // And rebuild the original again: full round-trip.
        let a = example();
        s.reset(a.rows());
        for j in 0..a.cols() {
            for i in 0..a.rows() {
                if a.get(i, j) != 0.0 {
                    s.push(i, a.get(i, j));
                }
            }
            s.end_col();
        }
        assert_eq!(s, CscMatrix::from_dense(&a));
    }

    #[test]
    #[should_panic(expected = "rows must increase")]
    fn in_place_rebuild_rejects_unsorted_rows() {
        let mut s = CscMatrix::zeros(3, 0);
        s.reset(3);
        s.push(2, 1.0);
        s.push(1, 1.0);
    }
}

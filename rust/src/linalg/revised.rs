//! Sparse revised-simplex LP solver with bounded variables and warm starts.
//!
//! This is the scalable substrate behind the Gavel / POP baselines. The
//! dense tableau solver (`super::lp`) carries the full `m × (n + m)`
//! tableau through every pivot; Gavel's allocation LPs are almost entirely
//! sparse (one dense capacity row plus coupling rows with ≤ 3 nonzeros per
//! column) and their `x_j ≤ 1` box rows used to dominate the tableau. The
//! revised method stores the constraints once in CSC form, keeps only an
//! LU factorization of the current `m × m` basis (updated by eta vectors,
//! periodically refactorized), and handles `0 ≤ x_j ≤ u_j` natively so box
//! constraints cost bound flips instead of rows:
//!
//! maximize    cᵀx
//! subject to  A x ≤ b,  0 ≤ x ≤ u,  b ≥ 0   (u_j = +∞ allowed)
//!
//! Determinism mirrors the dense solver: Dantzig pricing (most favorable
//! reduced cost, lowest index on ties) with a Bland's-rule fallback once
//! degenerate stalling is detected, and lowest-variable-index tie-breaks
//! in the ratio test — so repeated solves of one instance pivot
//! identically, and the Bland fallback guarantees termination.
//!
//! [`WarmStart`] captures the optimal basis + nonbasic bound statuses of a
//! solve. Re-solving after an objective change (the Gavel round-over-round
//! case: job weights drift, constraint structure unchanged) restarts from
//! that basis — still primal feasible — and typically needs a handful of
//! pivots instead of thousands. An incompatible or infeasible warm start
//! silently falls back to a cold start, so callers may always pass one.

use super::lp::{Lp, LpError, LpSolution};
use super::matrix::Matrix;
use super::sparse::CscMatrix;

/// LP instance with sparse constraints and native variable upper bounds.
#[derive(Debug, Clone)]
pub struct SparseLp {
    /// Objective coefficients (maximized), length n.
    pub objective: Vec<f64>,
    /// Structural constraint matrix, m × n (`A x ≤ b`).
    pub constraints: CscMatrix,
    /// Right-hand sides, length m; must be non-negative.
    pub rhs: Vec<f64>,
    /// Per-variable upper bounds, length n; `f64::INFINITY` for unbounded.
    pub upper: Vec<f64>,
}

impl SparseLp {
    /// Wrap a dense standard-form LP (no finite bounds).
    pub fn from_dense(lp: &Lp) -> SparseLp {
        SparseLp {
            objective: lp.objective.clone(),
            constraints: CscMatrix::from_dense(&lp.constraints),
            rhs: lp.rhs.clone(),
            upper: vec![f64::INFINITY; lp.objective.len()],
        }
    }

    /// Materialize as a dense standard-form LP with every finite upper
    /// bound appended as an explicit `x_j ≤ u_j` row — the formulation the
    /// dense tableau solver accepts. Parity tests solve both sides.
    pub fn to_dense_lp(&self) -> Lp {
        let n = self.objective.len();
        let m = self.rhs.len();
        let bounded: Vec<usize> = (0..n).filter(|&j| self.upper[j].is_finite()).collect();
        let dense = self.constraints.to_dense();
        let mut a = Matrix::zeros(m + bounded.len(), n);
        for r in 0..m {
            for c in 0..n {
                a.set(r, c, dense.get(r, c));
            }
        }
        let mut rhs = self.rhs.clone();
        for (extra, &j) in bounded.iter().enumerate() {
            a.set(m + extra, j, 1.0);
            rhs.push(self.upper[j]);
        }
        Lp {
            objective: self.objective.clone(),
            constraints: a,
            rhs,
        }
    }

    pub fn num_vars(&self) -> usize {
        self.objective.len()
    }

    pub fn num_rows(&self) -> usize {
        self.rhs.len()
    }
}

/// Basis snapshot from a completed solve: which of the `n + m` variables
/// (structural then slack) are basic, and which nonbasic variables rest at
/// their upper bound. Opaque to callers; feed it back into
/// [`solve_sparse_lp`] to warm-start the next solve of a same-shaped
/// instance.
#[derive(Debug, Clone)]
pub struct WarmStart {
    n: usize,
    m: usize,
    basis: Vec<usize>,
    at_upper: Vec<bool>,
}

impl WarmStart {
    /// Carry this basis onto a *successor* instance whose variables and
    /// rows are a remapping of the current ones (the arrival/departure
    /// case). `var_map[j]` / `row_map[i]` give the new index of old
    /// structural variable `j` / old row `i`, or `None` if it departed.
    ///
    /// Departed basic variables are dropped; freed basis positions are
    /// refilled with the lowest-index unused slacks, so the result is
    /// always a structurally complete basis for the `new_n × new_m`
    /// instance. It is usually *primal infeasible* (the window changed) —
    /// feed it through [`repair_warm_start`] before solving.
    pub fn remapped(
        &self,
        var_map: &[Option<usize>],
        row_map: &[Option<usize>],
        new_n: usize,
        new_m: usize,
    ) -> WarmStart {
        assert_eq!(var_map.len(), self.n, "var_map length mismatch");
        assert_eq!(row_map.len(), self.m, "row_map length mismatch");
        let nv = new_n + new_m;
        let mut at_upper = vec![false; nv];
        for (j, &up) in self.at_upper.iter().take(self.n).enumerate() {
            if up {
                if let Some(nj) = var_map[j] {
                    debug_assert!(nj < new_n, "var_map target out of range");
                    at_upper[nj] = true;
                }
            }
        }
        // Slacks never rest at an upper bound (theirs is infinite).
        let mut in_basis = vec![false; nv];
        let mut basis = Vec::with_capacity(new_m);
        for &v in &self.basis {
            let mapped = if v < self.n {
                var_map[v]
            } else {
                row_map[v - self.n].map(|r| {
                    debug_assert!(r < new_m, "row_map target out of range");
                    new_n + r
                })
            };
            if let Some(nv_idx) = mapped {
                if !in_basis[nv_idx] && basis.len() < new_m {
                    in_basis[nv_idx] = true;
                    basis.push(nv_idx);
                }
            }
        }
        for r in 0..new_m {
            if basis.len() == new_m {
                break;
            }
            let s = new_n + r;
            if !in_basis[s] {
                in_basis[s] = true;
                basis.push(s);
            }
        }
        for &v in &basis {
            at_upper[v] = false;
        }
        WarmStart {
            n: new_n,
            m: new_m,
            basis,
            at_upper,
        }
    }

    fn compatible(&self, n: usize, m: usize) -> bool {
        if self.n != n || self.m != m || self.basis.len() != m {
            return false;
        }
        if self.at_upper.len() != n + m {
            return false;
        }
        let mut seen = vec![false; n + m];
        for &v in &self.basis {
            if v >= n + m || seen[v] {
                return false;
            }
            seen[v] = true;
        }
        true
    }
}

/// Reduced-cost / pivot tolerance (matches the dense solver's `EPS`).
const EPS: f64 = 1e-9;
/// Below this a factorization pivot counts as singular.
const PIVOT_TOL: f64 = 1e-10;
/// Eta-file length that triggers a refactorization (and an exact
/// recomputation of the basic values, bounding drift).
const REFACTOR_EVERY: usize = 64;
/// Bound violation beyond which a warm-start basis is rejected.
const WARM_FEAS_TOL: f64 = 1e-6;
/// Residual bound violation the dual-simplex repair drives the basis
/// below. Strictly tighter than [`WARM_FEAS_TOL`] so a repaired basis
/// always clears the warm-start feasibility gate in [`solve_sparse_lp`].
const REPAIR_FEAS_TOL: f64 = 1e-7;
/// Minimum |pivot row entry| the repair accepts for an entering column.
const REPAIR_PIVOT_TOL: f64 = 1e-7;

/// Sparse LU factors of a basis matrix, `P B = L U` with partial pivoting.
/// Built left-looking with a dense accumulator: O(m² + fill) per
/// factorization, which the near-triangular Gavel bases keep tiny.
struct LuFactors {
    m: usize,
    /// Column `k` of `L` (unit diagonal implicit): `(original_row,
    /// multiplier)` for rows pivoted *after* step `k`.
    l_cols: Vec<Vec<(usize, f64)>>,
    /// Column `j` of `U` above the diagonal: `(step k < j, value)`.
    u_cols: Vec<Vec<(usize, f64)>>,
    u_diag: Vec<f64>,
    /// Step `k` → original row chosen as pivot.
    pivot_row: Vec<usize>,
    /// Original row → step at which it was pivoted.
    rank_of_row: Vec<usize>,
}

/// Scatter basis column `var` (structural CSC column or slack unit vector)
/// into `(stamp, work)` generation `gen`.
fn scatter_basis_col(
    lp: &SparseLp,
    var: usize,
    gen: u32,
    stamp: &mut [u32],
    work: &mut [f64],
) {
    let n = lp.objective.len();
    if var < n {
        let (rows, vals) = lp.constraints.col(var);
        for (&i, &v) in rows.iter().zip(vals) {
            stamp[i] = gen;
            work[i] = v;
        }
    } else {
        let i = var - n;
        stamp[i] = gen;
        work[i] = 1.0;
    }
}

fn factorize(lp: &SparseLp, basis: &[usize]) -> Result<LuFactors, LpError> {
    let m = basis.len();
    let mut f = LuFactors {
        m,
        l_cols: Vec::with_capacity(m),
        u_cols: Vec::with_capacity(m),
        u_diag: Vec::with_capacity(m),
        pivot_row: Vec::with_capacity(m),
        rank_of_row: vec![usize::MAX; m],
    };
    let mut work = vec![0.0f64; m];
    let mut stamp = vec![0u32; m];
    let mut gen = 0u32;
    for (step, &var) in basis.iter().enumerate() {
        gen += 1;
        scatter_basis_col(lp, var, gen, &mut stamp, &mut work);
        // Left-looking elimination by the previous pivots, in step order.
        let mut ucol = Vec::new();
        for k in 0..step {
            let pr = f.pivot_row[k];
            let xk = if stamp[pr] == gen { work[pr] } else { 0.0 };
            if xk == 0.0 {
                continue;
            }
            ucol.push((k, xk));
            for &(i, l) in &f.l_cols[k] {
                if stamp[i] == gen {
                    work[i] -= l * xk;
                } else {
                    stamp[i] = gen;
                    work[i] = -l * xk;
                }
            }
        }
        // Partial pivoting over the not-yet-pivoted rows (lowest original
        // row wins ties, keeping the factorization deterministic).
        let mut pr = usize::MAX;
        let mut best = 0.0f64;
        for i in 0..m {
            if f.rank_of_row[i] == usize::MAX && stamp[i] == gen {
                let a = work[i].abs();
                if a > best {
                    best = a;
                    pr = i;
                }
            }
        }
        if pr == usize::MAX || best < PIVOT_TOL {
            return Err(LpError::BadInput(format!(
                "singular basis at factorization step {step}"
            )));
        }
        let diag = work[pr];
        let mut lcol = Vec::new();
        for i in 0..m {
            if i != pr && f.rank_of_row[i] == usize::MAX && stamp[i] == gen && work[i] != 0.0 {
                lcol.push((i, work[i] / diag));
            }
        }
        f.pivot_row.push(pr);
        f.rank_of_row[pr] = step;
        f.u_diag.push(diag);
        f.u_cols.push(ucol);
        f.l_cols.push(lcol);
    }
    Ok(f)
}

impl LuFactors {
    /// Solve `B z = x` (FTRAN). `x` is indexed by original row and is
    /// consumed as scratch; the result is indexed by basis position.
    fn ftran(&self, mut x: Vec<f64>) -> Vec<f64> {
        let m = self.m;
        let mut y = vec![0.0; m];
        for k in 0..m {
            let v = x[self.pivot_row[k]];
            if v != 0.0 {
                for &(i, l) in &self.l_cols[k] {
                    x[i] -= l * v;
                }
            }
            y[k] = v;
        }
        for j in (0..m).rev() {
            let zj = y[j] / self.u_diag[j];
            y[j] = zj;
            if zj != 0.0 {
                for &(k, u) in &self.u_cols[j] {
                    y[k] -= u * zj;
                }
            }
        }
        y
    }

    /// Solve `Bᵀ y = c` (BTRAN). `c` is indexed by basis position; the
    /// result is indexed by original row.
    fn btran(&self, c: &[f64]) -> Vec<f64> {
        let m = self.m;
        let mut w = vec![0.0; m];
        for j in 0..m {
            let mut s = c[j];
            for &(k, u) in &self.u_cols[j] {
                s -= u * w[k];
            }
            w[j] = s / self.u_diag[j];
        }
        for k in (0..m).rev() {
            let mut s = w[k];
            for &(i, l) in &self.l_cols[k] {
                s -= l * w[self.rank_of_row[i]];
            }
            w[k] = s;
        }
        let mut y = vec![0.0; m];
        for k in 0..m {
            y[self.pivot_row[k]] = w[k];
        }
        y
    }
}

/// One product-form update: replacing basis position `r` with a column
/// whose FTRAN image was `w` multiplies the basis by `E = I + (w − e_r)
/// e_rᵀ`, so `E⁻¹` is applied after the base FTRAN and `E⁻ᵀ` before the
/// base BTRAN.
struct Eta {
    r: usize,
    wr: f64,
    /// Positions `≠ r` with nonzero `w`.
    entries: Vec<(usize, f64)>,
}

/// The factorized basis: base LU plus the eta file accumulated since the
/// last refactorization.
struct FactorizedBasis {
    lu: LuFactors,
    etas: Vec<Eta>,
}

impl FactorizedBasis {
    fn fresh(lp: &SparseLp, basis: &[usize]) -> Result<FactorizedBasis, LpError> {
        Ok(FactorizedBasis {
            lu: factorize(lp, basis)?,
            etas: Vec::new(),
        })
    }

    fn ftran(&self, x: Vec<f64>) -> Vec<f64> {
        let mut z = self.lu.ftran(x);
        for e in &self.etas {
            let zr = z[e.r] / e.wr;
            z[e.r] = zr;
            if zr != 0.0 {
                for &(i, w) in &e.entries {
                    z[i] -= w * zr;
                }
            }
        }
        z
    }

    fn btran(&self, c: &[f64]) -> Vec<f64> {
        let mut c = c.to_vec();
        for e in self.etas.iter().rev() {
            let mut dot = e.wr * c[e.r];
            for &(i, w) in &e.entries {
                dot += w * c[i];
            }
            c[e.r] -= (dot - c[e.r]) / e.wr;
        }
        self.lu.btran(&c)
    }

    fn push_eta(&mut self, r: usize, w: &[f64]) {
        let entries: Vec<(usize, f64)> = w
            .iter()
            .enumerate()
            .filter(|&(i, &v)| i != r && v != 0.0)
            .map(|(i, &v)| (i, v))
            .collect();
        self.etas.push(Eta {
            r,
            wr: w[r],
            entries,
        });
    }
}

#[inline]
fn upper_of(lp: &SparseLp, var: usize) -> f64 {
    if var < lp.objective.len() {
        lp.upper[var]
    } else {
        f64::INFINITY
    }
}

#[inline]
fn cost_of(lp: &SparseLp, var: usize) -> f64 {
    if var < lp.objective.len() {
        lp.objective[var]
    } else {
        0.0
    }
}

/// Exact basic values for the current statuses:
/// `x_B = B⁻¹ (b − Σ_{j nonbasic at upper} u_j A_j)`.
fn basic_values(
    lp: &SparseLp,
    factors: &FactorizedBasis,
    at_upper: &[bool],
) -> Vec<f64> {
    let n = lp.objective.len();
    let mut rhs = lp.rhs.clone();
    for (j, &up) in at_upper.iter().take(n).enumerate() {
        if up {
            lp.constraints.col_axpy(j, -lp.upper[j], &mut rhs);
        }
    }
    factors.ftran(rhs)
}

/// Factorize `basis` and compute its basic values; errors if the basis is
/// singular or any basic value violates its bounds by more than
/// [`WARM_FEAS_TOL`] (the warm-start rejection path).
fn install_basis(
    lp: &SparseLp,
    basis: &[usize],
    at_upper: &[bool],
) -> Result<(FactorizedBasis, Vec<f64>), LpError> {
    let factors = FactorizedBasis::fresh(lp, basis)?;
    let x_b = basic_values(lp, &factors, at_upper);
    for (pos, &var) in basis.iter().enumerate() {
        let ub = upper_of(lp, var);
        if x_b[pos] < -WARM_FEAS_TOL || x_b[pos] > ub + WARM_FEAS_TOL {
            return Err(LpError::BadInput(format!(
                "basis infeasible: position {pos} value {} outside [0, {ub}]",
                x_b[pos]
            )));
        }
    }
    Ok((factors, x_b))
}

/// One augmenting-path step of the row ↔ basis-column bipartite matching
/// used by [`patch_structural_singularity`]. Deterministic: support rows
/// are scanned in CSC (ascending) order.
fn augment_cover(
    pos: usize,
    support: &[Vec<usize>],
    match_row: &mut [usize],
    match_pos: &mut [usize],
    seen: &mut [bool],
) -> bool {
    for &r in &support[pos] {
        if seen[r] {
            continue;
        }
        seen[r] = true;
        let prev = match_row[r];
        if prev == usize::MAX || augment_cover(prev, support, match_row, match_pos, seen) {
            match_row[r] = pos;
            match_pos[pos] = r;
            return true;
        }
    }
    false
}

/// Swap structurally redundant basis members for the slacks of uncovered
/// rows, so the basis matrix has no zero row / duplicated support.
///
/// [`WarmStart::remapped`] refills freed basis slots with the
/// lowest-index unused slacks — it has no view of the constraint matrix,
/// so after a departure the coupling row whose covering pair variable
/// left can end up covered by *no* basis column (a structurally singular
/// basis that would force the cold fallback). Here, with the LP in hand,
/// a maximum bipartite matching between rows and basis columns (on the
/// nonzero support pattern) identifies the uncovered rows and the
/// redundant basis positions in one pass; each uncovered row gets its own
/// slack swapped in. Maximality guarantees an unmatched row's slack is
/// not already basic (the length-1 augmenting path would contradict it).
/// The result is structurally nonsingular; `FactorizedBasis::fresh`
/// still backstops numeric singularity.
fn patch_structural_singularity(lp: &SparseLp, basis: &mut [usize], at_upper: &mut [bool]) {
    let n = lp.objective.len();
    let m = lp.rhs.len();
    let support: Vec<Vec<usize>> = basis
        .iter()
        .map(|&v| {
            if v < n {
                lp.constraints.col(v).0.to_vec()
            } else {
                vec![v - n]
            }
        })
        .collect();
    let mut match_row = vec![usize::MAX; m];
    let mut match_pos = vec![usize::MAX; m];
    let mut seen = vec![false; m];
    for pos in 0..m {
        seen.fill(false);
        augment_cover(pos, &support, &mut match_row, &mut match_pos, &mut seen);
    }
    let mut unmatched_rows = (0..m).filter(|&r| match_row[r] == usize::MAX);
    for pos in 0..m {
        if match_pos[pos] != usize::MAX {
            continue;
        }
        let r = unmatched_rows
            .next()
            .expect("unmatched rows and positions pair off");
        let slack = n + r;
        debug_assert!(
            !basis.contains(&slack),
            "max matching left a basic slack's row uncovered"
        );
        let leaving = basis[pos];
        at_upper[leaving] = false; // freed member rests at its lower bound
        basis[pos] = slack;
        at_upper[slack] = false;
    }
}

/// Restore primal feasibility of a (remapped) warm basis with a bounded
/// dual simplex, without cold-solving. This is the arrival/departure
/// repair path: after [`WarmStart::remapped`] carried the previous round's
/// basis onto the perturbed instance, a handful of dual pivots replace the
/// thousands of primal pivots a cold solve would need.
///
/// Best-effort by design: the dual phase only chases feasibility (it
/// tolerates dual infeasibility, picking the min-|ratio| entering column
/// as a deterministic heuristic), because the returned handle is then fed
/// into [`solve_sparse_lp`]'s warm path, which re-verifies feasibility and
/// finishes to optimality with primal pivots. Any trouble — singular
/// basis, no eligible entering column, tiny pivots, iteration cap —
/// returns `None`, and the caller cold-solves. Optimality and parity
/// therefore never depend on this routine succeeding.
pub fn repair_warm_start(lp: &SparseLp, warm: &WarmStart) -> Option<WarmStart> {
    let n = lp.objective.len();
    let m = lp.rhs.len();
    if !warm.compatible(n, m) || lp.constraints.rows() != m || lp.constraints.cols() != n {
        return None;
    }
    let nv = n + m;
    let mut basis = warm.basis.clone();
    let mut at_upper = warm.at_upper.clone();
    for (j, up) in at_upper.iter_mut().enumerate() {
        if *up && !upper_of(lp, j).is_finite() {
            *up = false;
        }
    }
    for &v in &basis {
        at_upper[v] = false;
    }
    patch_structural_singularity(lp, &mut basis, &mut at_upper);
    let mut factors = FactorizedBasis::fresh(lp, &basis).ok()?;
    let mut x_b = basic_values(lp, &factors, &at_upper);
    let mut in_basis_pos = vec![usize::MAX; nv];
    for (pos, &v) in basis.iter().enumerate() {
        in_basis_pos[v] = pos;
    }

    let max_iters = (4 * (m + n)).max(32);
    let mut c_b = vec![0.0; m];
    let mut e_r = vec![0.0; m];
    for _ in 0..max_iters {
        // Watchdog iteration checkpoint (no-op unless a stage deadline is
        // armed on this thread).
        crate::recovery::watchdog::checkpoint();
        // Leaving row: the most-violated basic value (Dantzig-style dual
        // pricing; deterministic — strict `>` keeps the lowest position on
        // ties).
        let mut r = usize::MAX;
        let mut worst = REPAIR_FEAS_TOL;
        let mut to_upper = false;
        for (pos, &xb) in x_b.iter().enumerate() {
            if -xb > worst {
                worst = -xb;
                r = pos;
                to_upper = false;
            }
            let ub = upper_of(lp, basis[pos]);
            if xb - ub > worst {
                worst = xb - ub;
                r = pos;
                to_upper = true;
            }
        }
        if r == usize::MAX {
            return Some(WarmStart {
                n,
                m,
                basis,
                at_upper,
            });
        }

        // Pivot row ρ = B⁻ᵀ e_r and duals y for the ratio test.
        for (pos, &v) in basis.iter().enumerate() {
            c_b[pos] = cost_of(lp, v);
        }
        let y = factors.btran(&c_b);
        for e in e_r.iter_mut() {
            *e = 0.0;
        }
        e_r[r] = 1.0;
        let rho = factors.btran(&e_r);

        // Entering column: among nonbasics whose step direction reduces
        // the violation (sign analysis below), minimize |d_j / α_j| — the
        // classic dual ratio — breaking ties toward the larger |α| (better
        // conditioned pivot), then the lowest index (scan order).
        let mut q = usize::MAX;
        let mut best_ratio = f64::INFINITY;
        let mut best_alpha = 0.0f64;
        for j in 0..nv {
            if in_basis_pos[j] != usize::MAX {
                continue;
            }
            if upper_of(lp, j) <= 0.0 {
                continue; // fixed at zero
            }
            let alpha = if j < n {
                lp.constraints.col_dot(j, &rho)
            } else {
                rho[j - n]
            };
            if alpha.abs() < REPAIR_PIVOT_TOL {
                continue;
            }
            // The leaving value moves by −σ t α (t ≥ 0; σ = +1 entering
            // from lower, −1 from upper). Violation below zero needs the
            // value to rise (σα < 0); above the upper bound, to fall
            // (σα > 0).
            let sigma_alpha = if at_upper[j] { -alpha } else { alpha };
            let eligible = if to_upper {
                sigma_alpha > 0.0
            } else {
                sigma_alpha < 0.0
            };
            if !eligible {
                continue;
            }
            let d = if j < n {
                lp.objective[j] - lp.constraints.col_dot(j, &y)
            } else {
                -y[j - n]
            };
            let ratio = (d / alpha).abs();
            let replace = ratio < best_ratio - EPS
                || (ratio < best_ratio + EPS && alpha.abs() > best_alpha.abs() + EPS);
            if replace {
                best_ratio = best_ratio.min(ratio);
                best_alpha = alpha;
                q = j;
            }
        }
        if q == usize::MAX {
            return None; // dual ray / nothing usable: cold-solve instead
        }

        // Pivot: recompute α through the factorization (exact w.r.t. the
        // eta file), step the leaving variable exactly onto its violated
        // bound, and swap q in.
        let sigma = if at_upper[q] { -1.0 } else { 1.0 };
        let mut col = vec![0.0; m];
        if q < n {
            lp.constraints.col_axpy(q, 1.0, &mut col);
        } else {
            col[q - n] = 1.0;
        }
        let w = factors.ftran(col);
        let alpha = w[r];
        if alpha.abs() < REPAIR_PIVOT_TOL {
            return None;
        }
        let delta = if to_upper {
            x_b[r] - upper_of(lp, basis[r])
        } else {
            x_b[r]
        };
        let t = delta / (sigma * alpha);
        if !t.is_finite() || t < -EPS {
            return None;
        }
        let t = t.max(0.0);
        for (pos, &wp) in w.iter().enumerate() {
            x_b[pos] -= t * sigma * wp;
        }
        let entering_value = if sigma > 0.0 { t } else { upper_of(lp, q) - t };
        let leaving = basis[r];
        at_upper[leaving] = to_upper && upper_of(lp, leaving).is_finite();
        in_basis_pos[leaving] = usize::MAX;
        basis[r] = q;
        in_basis_pos[q] = r;
        at_upper[q] = false;
        x_b[r] = entering_value;
        factors.push_eta(r, &w);
        if factors.etas.len() >= REFACTOR_EVERY {
            factors = FactorizedBasis::fresh(lp, &basis).ok()?;
            x_b = basic_values(lp, &factors, &at_upper);
        }
    }
    None
}

/// Solve a bounded LP with the sparse revised simplex, optionally from a
/// previous solve's [`WarmStart`]. Returns the solution plus the handle
/// for the next round.
pub fn solve_sparse_lp(
    lp: &SparseLp,
    warm: Option<&WarmStart>,
) -> Result<(LpSolution, WarmStart), LpError> {
    let n = lp.objective.len();
    let m = lp.rhs.len();
    if lp.constraints.rows() != m || lp.constraints.cols() != n {
        return Err(LpError::BadInput(format!(
            "constraint matrix {}x{} does not match rhs {} / objective {}",
            lp.constraints.rows(),
            lp.constraints.cols(),
            m,
            n
        )));
    }
    if lp.upper.len() != n {
        return Err(LpError::BadInput("upper-bound vector length mismatch".into()));
    }
    if lp.rhs.iter().any(|&b| b < 0.0 || b.is_nan()) {
        return Err(LpError::BadInput("rhs must be non-negative".into()));
    }
    if lp.upper.iter().any(|&u| u < 0.0 || u.is_nan()) {
        return Err(LpError::BadInput("upper bounds must be non-negative".into()));
    }

    let nv = n + m;

    // Adopt the warm basis when compatible; otherwise (or if it turns out
    // singular / infeasible below) cold-start from the all-slack basis.
    let mut basis: Vec<usize> = (n..nv).collect();
    let mut at_upper = vec![false; nv];
    let mut warm_adopted = false;
    if let Some(ws) = warm {
        if ws.compatible(n, m) {
            basis.copy_from_slice(&ws.basis);
            at_upper.copy_from_slice(&ws.at_upper);
            for j in 0..nv {
                if at_upper[j] && !upper_of(lp, j).is_finite() {
                    at_upper[j] = false;
                }
            }
            for &v in &basis {
                at_upper[v] = false;
            }
            warm_adopted = true;
        }
    }

    let (mut factors, mut x_b) = match install_basis(lp, &basis, &at_upper) {
        Ok(state) => state,
        Err(_) if warm_adopted => {
            basis = (n..nv).collect();
            at_upper = vec![false; nv];
            install_basis(lp, &basis, &at_upper)?
        }
        Err(e) => return Err(e),
    };

    let mut in_basis_pos = vec![usize::MAX; nv];
    for (pos, &v) in basis.iter().enumerate() {
        in_basis_pos[v] = pos;
    }

    let max_iters = 50 * (m + n).max(64);
    let bland_after = 10 * (m + n);
    let mut iters = 0usize;

    loop {
        // Watchdog iteration checkpoint (no-op unless a stage deadline is
        // armed on this thread).
        crate::recovery::watchdog::checkpoint();
        // Duals for the current basis.
        let c_b: Vec<f64> = basis.iter().map(|&v| cost_of(lp, v)).collect();
        let y = factors.btran(&c_b);

        // Pricing: Dantzig (most favorable |reduced cost|, lowest index on
        // ties), Bland fallback (lowest favorable index) once stalling is
        // possible — the same discipline as the dense solver.
        let use_bland = iters > bland_after;
        let mut enter: Option<usize> = None;
        let mut best = EPS;
        for j in 0..nv {
            if in_basis_pos[j] != usize::MAX {
                continue;
            }
            let u_j = upper_of(lp, j);
            if u_j <= 0.0 {
                continue; // fixed at zero
            }
            let d = if j < n {
                lp.objective[j] - lp.constraints.col_dot(j, &y)
            } else {
                -y[j - n]
            };
            let favorable = if at_upper[j] { d < -EPS } else { d > EPS };
            if !favorable {
                continue;
            }
            if use_bland {
                enter = Some(j);
                break;
            }
            if d.abs() > best {
                best = d.abs();
                enter = Some(j);
            }
        }
        let Some(q) = enter else {
            // Optimal: extract structural values from statuses.
            let mut x = vec![0.0; n];
            for (j, xj) in x.iter_mut().enumerate() {
                if in_basis_pos[j] != usize::MAX {
                    *xj = x_b[in_basis_pos[j]].clamp(0.0, lp.upper[j]);
                } else if at_upper[j] {
                    *xj = lp.upper[j];
                }
            }
            let objective = lp.objective.iter().zip(&x).map(|(c, v)| c * v).sum();
            return Ok((
                LpSolution {
                    x,
                    objective,
                    iterations: iters,
                },
                WarmStart {
                    n,
                    m,
                    basis,
                    at_upper,
                },
            ));
        };

        // Direction: entering from its lower bound moves up (σ = +1), from
        // its upper bound down (σ = −1); basic values respond by −σ t w.
        let sigma = if at_upper[q] { -1.0 } else { 1.0 };
        let mut col = vec![0.0; m];
        if q < n {
            lp.constraints.col_axpy(q, 1.0, &mut col);
        } else {
            col[q - n] = 1.0;
        }
        let w = factors.ftran(col);

        // Ratio test. The entering variable's own range u_q seeds the
        // step; a basic row beats it on ties (`< t + EPS`), and ties among
        // rows go to the lowest basic variable index (Bland).
        let mut t_best = upper_of(lp, q);
        let mut leave: Option<(usize, bool)> = None;
        for (pos, &wp) in w.iter().enumerate() {
            let dir = sigma * wp;
            let (ratio, to_upper) = if dir > EPS {
                (x_b[pos].max(0.0) / dir, false)
            } else if dir < -EPS {
                let ub = upper_of(lp, basis[pos]);
                if !ub.is_finite() {
                    continue;
                }
                ((ub - x_b[pos]).max(0.0) / (-dir), true)
            } else {
                continue;
            };
            let replace = match leave {
                None => ratio < t_best + EPS,
                Some((cur, _)) => {
                    ratio < t_best - EPS
                        || (ratio < t_best + EPS && basis[pos] < basis[cur])
                }
            };
            if replace {
                t_best = t_best.min(ratio);
                leave = Some((pos, to_upper));
            }
        }
        if !t_best.is_finite() {
            return Err(LpError::Unbounded);
        }
        iters += 1;
        if iters > max_iters {
            return Err(LpError::Stalled);
        }

        match leave {
            None => {
                // Bound flip: q jumps to its opposite bound, no pivot.
                let t = t_best;
                if t != 0.0 {
                    for (pos, &wp) in w.iter().enumerate() {
                        x_b[pos] -= t * sigma * wp;
                    }
                }
                at_upper[q] = !at_upper[q];
            }
            Some((r, to_upper)) => {
                let t = t_best.max(0.0);
                for (pos, &wp) in w.iter().enumerate() {
                    x_b[pos] -= t * sigma * wp;
                }
                let entering_value = if sigma > 0.0 {
                    t
                } else {
                    upper_of(lp, q) - t
                };
                let leaving = basis[r];
                at_upper[leaving] = to_upper;
                in_basis_pos[leaving] = usize::MAX;
                basis[r] = q;
                in_basis_pos[q] = r;
                at_upper[q] = false;
                x_b[r] = entering_value;
                factors.push_eta(r, &w);
                if factors.etas.len() >= REFACTOR_EVERY {
                    factors = FactorizedBasis::fresh(lp, &basis)?;
                    x_b = basic_values(lp, &factors, &at_upper);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solve_lp;
    use crate::util::prop::{approx_eq, forall};
    use crate::util::rng::Pcg64;

    fn unbounded_above(objective: Vec<f64>, rows: &[&[f64]], rhs: Vec<f64>) -> SparseLp {
        let n = objective.len();
        SparseLp {
            objective,
            constraints: CscMatrix::from_dense(&Matrix::from_rows(rows)),
            rhs,
            upper: vec![f64::INFINITY; n],
        }
    }

    #[test]
    fn textbook_two_vars() {
        // max 3x + 5y s.t. x<=4, 2y<=12, 3x+2y<=18 -> x=2, y=6, obj=36.
        let lp = unbounded_above(
            vec![3.0, 5.0],
            &[&[1.0, 0.0], &[0.0, 2.0], &[3.0, 2.0]],
            vec![4.0, 12.0, 18.0],
        );
        let (s, _) = solve_sparse_lp(&lp, None).unwrap();
        assert!((s.objective - 36.0).abs() < 1e-8);
        assert!((s.x[0] - 2.0).abs() < 1e-8);
        assert!((s.x[1] - 6.0).abs() < 1e-8);
    }

    #[test]
    fn native_bounds_solve_without_rows() {
        // max 2x + y s.t. x + y <= 2, x <= 1, y <= 2 (bounds, not rows)
        // -> x = 1, y = 1, obj = 3; x rests at its upper bound.
        let lp = SparseLp {
            objective: vec![2.0, 1.0],
            constraints: CscMatrix::from_dense(&Matrix::from_rows(&[&[1.0, 1.0]])),
            rhs: vec![2.0],
            upper: vec![1.0, 2.0],
        };
        let (s, _) = solve_sparse_lp(&lp, None).unwrap();
        assert!((s.objective - 3.0).abs() < 1e-8, "obj {}", s.objective);
        assert!((s.x[0] - 1.0).abs() < 1e-8);
        assert!((s.x[1] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn detects_unbounded() {
        let lp = unbounded_above(vec![1.0, 0.0], &[&[0.0, 1.0]], vec![1.0]);
        assert_eq!(solve_sparse_lp(&lp, None).unwrap_err(), LpError::Unbounded);
    }

    #[test]
    fn bounded_zero_column_is_not_unbounded() {
        // Same shape, but the zero column has a finite bound: the optimum
        // saturates it with a bound flip.
        let lp = SparseLp {
            objective: vec![1.0, 0.0],
            constraints: CscMatrix::from_dense(&Matrix::from_rows(&[&[0.0, 1.0]])),
            rhs: vec![1.0],
            upper: vec![3.0, f64::INFINITY],
        };
        let (s, _) = solve_sparse_lp(&lp, None).unwrap();
        assert!((s.objective - 3.0).abs() < 1e-9);
        assert!((s.x[0] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Beale's cycling example; the Bland fallback must terminate at
        // obj = 0.05 exactly as the dense solver does.
        let lp = unbounded_above(
            vec![0.75, -150.0, 0.02, -6.0],
            &[
                &[0.25, -60.0, -0.04, 9.0],
                &[0.5, -90.0, -0.02, 3.0],
                &[0.0, 0.0, 1.0, 0.0],
            ],
            vec![0.0, 0.0, 1.0],
        );
        let (s, _) = solve_sparse_lp(&lp, None).unwrap();
        assert!((s.objective - 0.05).abs() < 1e-8, "obj {}", s.objective);
    }

    #[test]
    fn bad_input_rejected() {
        let lp = SparseLp {
            objective: vec![1.0],
            constraints: CscMatrix::zeros(1, 1),
            rhs: vec![-1.0],
            upper: vec![1.0],
        };
        assert!(matches!(solve_sparse_lp(&lp, None), Err(LpError::BadInput(_))));
        let lp2 = SparseLp {
            objective: vec![1.0],
            constraints: CscMatrix::zeros(1, 1),
            rhs: vec![1.0],
            upper: vec![-0.5],
        };
        assert!(matches!(solve_sparse_lp(&lp2, None), Err(LpError::BadInput(_))));
    }

    /// Random Gavel-shaped fractional knapsack: unique optimum a.s., so
    /// the revised solution must match the dense tableau solution
    /// componentwise after 1e-6 rounding — the PR's parity criterion.
    #[test]
    fn knapsack_matches_dense_componentwise() {
        forall(
            "revised == dense on knapsacks (x and objective)",
            29,
            40,
            |r| {
                let n = 2 + r.below(14) as usize;
                let p: Vec<f64> = (0..n).map(|_| r.range_f64(0.1, 4.0)).collect();
                let g: Vec<f64> = (0..n).map(|_| r.range_f64(0.5, 8.0)).collect();
                let cap = r.range_f64(1.0, g.iter().sum::<f64>());
                (p, g, cap)
            },
            |(p, g, cap)| {
                let n = p.len();
                let lp = SparseLp {
                    objective: p.clone(),
                    constraints: CscMatrix::from_dense(&Matrix::from_vec(1, n, g.clone())),
                    rhs: vec![*cap],
                    upper: vec![1.0; n],
                };
                let (rev, _) = solve_sparse_lp(&lp, None).map_err(|e| e.to_string())?;
                let dense = solve_lp(&lp.to_dense_lp()).map_err(|e| e.to_string())?;
                approx_eq(rev.objective, dense.objective, 1e-6)?;
                for (j, (a, b)) in rev.x.iter().zip(&dense.x).enumerate() {
                    if (a - b).abs() > 1e-6 {
                        return Err(format!("x[{j}] diverges: {a} vs {b}"));
                    }
                }
                Ok(())
            },
        );
    }

    /// Randomized sparse / degenerate / upper-bounded instances: both
    /// solvers claim optimality, so the objectives must agree within 1e-6
    /// even when alternate optima exist, and the revised solution must be
    /// feasible for its own constraints.
    #[test]
    fn random_instances_match_dense_objective() {
        forall(
            "revised == dense objective on random sparse LPs",
            31,
            60,
            |r| {
                let n = 1 + r.below(8) as usize;
                let m = 1 + r.below(6) as usize;
                let c: Vec<f64> = (0..n).map(|_| r.range_f64(0.0, 2.0)).collect();
                let mut a = Matrix::zeros(m, n);
                for i in 0..m {
                    for j in 0..n {
                        if r.f64() < 0.6 {
                            a.set(i, j, r.range_f64(0.0, 2.0));
                        }
                    }
                }
                // Mix degenerate rows (b = 0) with slack ones, and finite
                // with infinite bounds.
                let b: Vec<f64> = (0..m)
                    .map(|_| if r.f64() < 0.25 { 0.0 } else { r.range_f64(0.5, 5.0) })
                    .collect();
                let u: Vec<f64> = (0..n)
                    .map(|_| if r.f64() < 0.5 { f64::INFINITY } else { r.range_f64(0.2, 2.0) })
                    .collect();
                SparseLp {
                    objective: c,
                    constraints: CscMatrix::from_dense(&a),
                    rhs: b,
                    upper: u,
                }
            },
            |lp| {
                let rev = solve_sparse_lp(lp, None);
                let dense = solve_lp(&lp.to_dense_lp());
                match (rev, dense) {
                    (Ok((r, _)), Ok(d)) => {
                        approx_eq(r.objective, d.objective, 1e-6)?;
                        // Feasibility of the revised solution.
                        let ax = lp.constraints.matvec(&r.x);
                        for (i, (&lhs, &b)) in ax.iter().zip(&lp.rhs).enumerate() {
                            if lhs > b + 1e-6 {
                                return Err(format!("row {i} violated: {lhs} > {b}"));
                            }
                        }
                        for (j, &x) in r.x.iter().enumerate() {
                            if x < -1e-9 || x > lp.upper[j] + 1e-9 {
                                return Err(format!("x[{j}] = {x} out of bounds"));
                            }
                        }
                        Ok(())
                    }
                    (Err(LpError::Unbounded), Err(LpError::Unbounded)) => Ok(()),
                    (r, d) => Err(format!(
                        "solvers disagree: revised {:?} vs dense {:?}",
                        r.map(|(s, _)| s.objective),
                        d.map(|s| s.objective)
                    )),
                }
            },
        );
    }

    #[test]
    fn warm_start_after_objective_change_matches_cold() {
        let mut rng = Pcg64::new(77);
        let n = 24;
        let g: Vec<f64> = (0..n).map(|_| rng.range_f64(0.5, 8.0)).collect();
        let mut lp = SparseLp {
            objective: (0..n).map(|_| rng.range_f64(0.1, 4.0)).collect(),
            constraints: CscMatrix::from_dense(&Matrix::from_vec(1, n, g.clone())),
            rhs: vec![g.iter().sum::<f64>() * 0.4],
            upper: vec![1.0; n],
        };
        let (cold0, warm) = solve_sparse_lp(&lp, None).unwrap();
        // Same instance warm-started: optimal immediately, zero pivots.
        let (resolved, warm) = solve_sparse_lp(&lp, Some(&warm)).unwrap();
        assert_eq!(resolved.iterations, 0);
        assert!((resolved.objective - cold0.objective).abs() < 1e-9);
        // Drift the objective (the Gavel round-over-round case) and check
        // the warm solve agrees with a cold solve.
        let mut warm = warm;
        for round in 0..5 {
            for c in lp.objective.iter_mut() {
                *c *= rng.range_f64(0.8, 1.25);
            }
            let (hot, next_warm) = solve_sparse_lp(&lp, Some(&warm)).unwrap();
            let (cold, _) = solve_sparse_lp(&lp, None).unwrap();
            assert!(
                (hot.objective - cold.objective).abs()
                    <= 1e-8 * (1.0 + cold.objective.abs()),
                "round {round}: warm {} vs cold {}",
                hot.objective,
                cold.objective
            );
            warm = next_warm;
        }
    }

    #[test]
    fn incompatible_warm_start_falls_back_to_cold() {
        let lp = unbounded_above(
            vec![3.0, 5.0],
            &[&[1.0, 0.0], &[0.0, 2.0], &[3.0, 2.0]],
            vec![4.0, 12.0, 18.0],
        );
        // A warm start from a different-shaped LP must be ignored.
        let other = SparseLp {
            objective: vec![1.0],
            constraints: CscMatrix::from_dense(&Matrix::from_vec(1, 1, vec![1.0])),
            rhs: vec![1.0],
            upper: vec![1.0],
        };
        let (_, foreign) = solve_sparse_lp(&other, None).unwrap();
        let (s, _) = solve_sparse_lp(&lp, Some(&foreign)).unwrap();
        assert!((s.objective - 36.0).abs() < 1e-8);
    }

    #[test]
    fn refactorization_path_is_exercised() {
        // Enough structure that the solve needs > REFACTOR_EVERY pivots:
        // a staircase of coupled rows with generic costs.
        let mut rng = Pcg64::new(3);
        let n = 140;
        let m = 70;
        let mut a = Matrix::zeros(m, n);
        for i in 0..m {
            a.set(i, 2 * i, 1.0);
            a.set(i, 2 * i + 1, 1.0);
            if i + 1 < m {
                a.set(i, 2 * (i + 1), rng.range_f64(0.1, 1.0));
            }
        }
        let lp = SparseLp {
            objective: (0..n).map(|_| rng.range_f64(0.5, 2.0)).collect(),
            constraints: CscMatrix::from_dense(&a),
            rhs: (0..m).map(|_| rng.range_f64(0.5, 2.0)).collect(),
            upper: vec![1.0; n],
        };
        let (rev, _) = solve_sparse_lp(&lp, None).unwrap();
        let dense = solve_lp(&lp.to_dense_lp()).unwrap();
        assert!(
            (rev.objective - dense.objective).abs() <= 1e-6 * (1.0 + dense.objective.abs()),
            "revised {} vs dense {}",
            rev.objective,
            dense.objective
        );
    }

    /// Drop structural variable `j`: returns the shrunken LP plus the
    /// var/row maps for [`WarmStart::remapped`].
    fn drop_var(lp: &SparseLp, j: usize) -> (SparseLp, Vec<Option<usize>>, Vec<Option<usize>>) {
        let n = lp.num_vars();
        let m = lp.num_rows();
        let dense = lp.constraints.to_dense();
        let mut a = Matrix::zeros(m, n - 1);
        let mut objective = Vec::with_capacity(n - 1);
        let mut upper = Vec::with_capacity(n - 1);
        let mut var_map = vec![None; n];
        let mut nj = 0usize;
        for col in 0..n {
            if col == j {
                continue;
            }
            for row in 0..m {
                a.set(row, nj, dense.get(row, col));
            }
            objective.push(lp.objective[col]);
            upper.push(lp.upper[col]);
            var_map[col] = Some(nj);
            nj += 1;
        }
        let row_map = (0..m).map(Some).collect();
        (
            SparseLp {
                objective,
                constraints: CscMatrix::from_dense(&a),
                rhs: lp.rhs.clone(),
                upper,
            },
            var_map,
            row_map,
        )
    }

    /// Append a fresh structural variable with the given column / cost /
    /// bound; old variables and rows map identically.
    fn add_var(
        lp: &SparseLp,
        col: &[f64],
        cost: f64,
        ub: f64,
    ) -> (SparseLp, Vec<Option<usize>>, Vec<Option<usize>>) {
        let n = lp.num_vars();
        let m = lp.num_rows();
        assert_eq!(col.len(), m);
        let dense = lp.constraints.to_dense();
        let mut a = Matrix::zeros(m, n + 1);
        for r in 0..m {
            for c in 0..n {
                a.set(r, c, dense.get(r, c));
            }
            a.set(r, n, col[r]);
        }
        let mut objective = lp.objective.clone();
        objective.push(cost);
        let mut upper = lp.upper.clone();
        upper.push(ub);
        (
            SparseLp {
                objective,
                constraints: CscMatrix::from_dense(&a),
                rhs: lp.rhs.clone(),
                upper,
            },
            (0..n).map(Some).collect(),
            (0..m).map(Some).collect(),
        )
    }

    fn gavel_like(rng: &mut Pcg64, n: usize) -> SparseLp {
        // Capacity row plus a coupling row per pair of adjacent jobs — the
        // same shape Gavel's allocation LP has.
        let m = 1 + n / 2;
        let mut a = Matrix::zeros(m, n);
        for j in 0..n {
            a.set(0, j, rng.range_f64(0.5, 8.0));
            a.set(1 + j / 2, j, 1.0);
        }
        let mut rhs = vec![0.0; m];
        rhs[0] = (0..n).map(|j| a.get(0, j)).sum::<f64>() * 0.4;
        for r in rhs.iter_mut().skip(1) {
            *r = 1.0;
        }
        SparseLp {
            objective: (0..n).map(|_| rng.range_f64(0.1, 4.0)).collect(),
            constraints: CscMatrix::from_dense(&a),
            rhs,
            upper: vec![1.0; n],
        }
    }

    #[test]
    fn remapped_identity_is_immediately_optimal() {
        let mut rng = Pcg64::new(9);
        let lp = gavel_like(&mut rng, 16);
        let (cold, warm) = solve_sparse_lp(&lp, None).unwrap();
        let id_vars: Vec<Option<usize>> = (0..lp.num_vars()).map(Some).collect();
        let id_rows: Vec<Option<usize>> = (0..lp.num_rows()).map(Some).collect();
        let same = warm.remapped(&id_vars, &id_rows, lp.num_vars(), lp.num_rows());
        let repaired = repair_warm_start(&lp, &same).expect("identity remap repairs trivially");
        let (hot, _) = solve_sparse_lp(&lp, Some(&repaired)).unwrap();
        assert_eq!(hot.iterations, 0, "identity remap should need no pivots");
        assert!((hot.objective - cold.objective).abs() < 1e-9);
    }

    #[test]
    fn repair_after_departure_matches_cold() {
        let mut rng = Pcg64::new(41);
        let lp = gavel_like(&mut rng, 24);
        let (_, warm) = solve_sparse_lp(&lp, None).unwrap();
        for j in [0usize, 7, 23] {
            let (shrunk, var_map, row_map) = drop_var(&lp, j);
            let carried = warm.remapped(&var_map, &row_map, shrunk.num_vars(), shrunk.num_rows());
            let repaired = repair_warm_start(&shrunk, &carried);
            let (hot, _) = solve_sparse_lp(&shrunk, repaired.as_ref()).unwrap();
            let (cold, _) = solve_sparse_lp(&shrunk, None).unwrap();
            assert!(
                (hot.objective - cold.objective).abs() <= 1e-8 * (1.0 + cold.objective.abs()),
                "drop {j}: repaired {} vs cold {}",
                hot.objective,
                cold.objective
            );
            let dense = solve_lp(&shrunk.to_dense_lp()).unwrap();
            assert!(
                (hot.objective - dense.objective).abs() <= 1e-6 * (1.0 + dense.objective.abs()),
                "drop {j}: repaired {} vs dense {}",
                hot.objective,
                dense.objective
            );
        }
    }

    #[test]
    fn repair_after_arrival_matches_cold() {
        let mut rng = Pcg64::new(43);
        let lp = gavel_like(&mut rng, 24);
        let m = lp.num_rows();
        let (_, warm) = solve_sparse_lp(&lp, None).unwrap();
        let mut col = vec![0.0; m];
        col[0] = rng.range_f64(0.5, 8.0);
        col[m - 1] = 1.0;
        let (grown, var_map, row_map) = add_var(&lp, &col, 3.5, 1.0);
        let carried = warm.remapped(&var_map, &row_map, grown.num_vars(), grown.num_rows());
        let repaired = repair_warm_start(&grown, &carried);
        let (hot, _) = solve_sparse_lp(&grown, repaired.as_ref()).unwrap();
        let (cold, _) = solve_sparse_lp(&grown, None).unwrap();
        assert!(
            (hot.objective - cold.objective).abs() <= 1e-8 * (1.0 + cold.objective.abs()),
            "repaired {} vs cold {}",
            hot.objective,
            cold.objective
        );
    }

    /// A remapped basis that leaves a coupling row covered by no basis
    /// column (the post-departure shape `remapped`'s lowest-index slack
    /// refill produces) is structurally singular; the repair's matching
    /// patch must swap the right slack in and still succeed rather than
    /// bail to the cold fallback.
    #[test]
    fn repair_patches_structurally_singular_basis() {
        // Row 0 capacity, row 1 a coupling row; x0 covers both rows,
        // x1 only the capacity row.
        let lp = SparseLp {
            objective: vec![2.0, 1.0],
            constraints: CscMatrix::from_dense(&Matrix::from_rows(&[
                &[3.0, 2.0],
                &[1.0, 0.0],
            ])),
            rhs: vec![4.0, 1.0],
            upper: vec![1.0, 1.0],
        };
        // Basis {x1, slack0}: both columns live in row 0 only — row 1 is
        // a zero row, so factorization alone would fail.
        let broken = WarmStart {
            n: 2,
            m: 2,
            basis: vec![1, 2],
            at_upper: vec![false; 4],
        };
        let repaired = repair_warm_start(&lp, &broken)
            .expect("matching patch must rescue the uncovered row");
        let (hot, _) = solve_sparse_lp(&lp, Some(&repaired)).unwrap();
        let (cold, _) = solve_sparse_lp(&lp, None).unwrap();
        assert!(
            (hot.objective - cold.objective).abs() <= 1e-8 * (1.0 + cold.objective.abs()),
            "patched repair {} vs cold {}",
            hot.objective,
            cold.objective
        );
    }

    /// Randomized churn: every remap+repair(+warm-finish) result must
    /// match the cold sparse solve and the dense oracle within 1e-6.
    #[test]
    fn repair_matches_cold_and_dense_under_random_churn() {
        forall(
            "repair == cold == dense under churn",
            57,
            40,
            |r| {
                let n = 6 + 2 * r.below(8) as usize;
                let seed = r.below(1 << 30);
                (n, seed)
            },
            |&(n, seed)| {
                let mut rng = Pcg64::new(seed ^ 0x5eed);
                let lp = gavel_like(&mut rng, n);
                let (_, mut warm) = solve_sparse_lp(&lp, None).map_err(|e| e.to_string())?;
                let mut cur = lp;
                for step in 0..4 {
                    // Alternate a departure with an arrival.
                    let (next, var_map, row_map) = if step % 2 == 0 {
                        let j = rng.below(cur.num_vars() as u64) as usize;
                        drop_var(&cur, j)
                    } else {
                        let m = cur.num_rows();
                        let mut col = vec![0.0; m];
                        col[0] = rng.range_f64(0.5, 8.0);
                        col[1 + rng.below((m - 1) as u64) as usize] = 1.0;
                        add_var(&cur, &col, rng.range_f64(0.1, 4.0), 1.0)
                    };
                    let carried =
                        warm.remapped(&var_map, &row_map, next.num_vars(), next.num_rows());
                    let repaired = repair_warm_start(&next, &carried);
                    let (hot, next_warm) =
                        solve_sparse_lp(&next, repaired.as_ref()).map_err(|e| e.to_string())?;
                    let (cold, _) = solve_sparse_lp(&next, None).map_err(|e| e.to_string())?;
                    approx_eq(hot.objective, cold.objective, 1e-6)?;
                    let dense = solve_lp(&next.to_dense_lp()).map_err(|e| e.to_string())?;
                    approx_eq(hot.objective, dense.objective, 1e-6)?;
                    warm = next_warm;
                    cur = next;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn repair_rejects_incompatible_warm_start() {
        let mut rng = Pcg64::new(5);
        let lp = gavel_like(&mut rng, 8);
        let other = gavel_like(&mut rng, 12);
        let (_, foreign) = solve_sparse_lp(&other, None).unwrap();
        assert!(repair_warm_start(&lp, &foreign).is_none());
    }

    #[test]
    fn from_dense_roundtrip_agrees() {
        let dense = Lp {
            objective: vec![3.0, 5.0],
            constraints: Matrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0], &[3.0, 2.0]]),
            rhs: vec![4.0, 12.0, 18.0],
        };
        let (s, _) = solve_sparse_lp(&SparseLp::from_dense(&dense), None).unwrap();
        let d = solve_lp(&dense).unwrap();
        assert!((s.objective - d.objective).abs() < 1e-8);
    }
}

//! Linear-algebra substrate: dense matrices, Cholesky factorization (for
//! the Gaussian-process estimator), a dense tableau simplex (retained as
//! the parity oracle) and the sparse revised-simplex LP core that the
//! Gavel / POP baselines solve through. Implemented from scratch — the
//! offline crate set has no linear algebra crates.

pub mod lp;
pub mod matrix;
pub mod revised;
pub mod sparse;

pub use lp::{solve_lp, Lp, LpError, LpSolution};
pub use matrix::Matrix;
pub use revised::{repair_warm_start, solve_sparse_lp, SparseLp, WarmStart};
pub use sparse::{CscBuilder, CscMatrix};

/// Cholesky factorization of a symmetric positive-definite matrix:
/// returns lower-triangular `L` with `L Lᵀ = A`. Errors if `A` is not SPD
/// (within jitter tolerance).
pub fn cholesky(a: &Matrix) -> Result<Matrix, String> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return Err(format!("matrix not positive definite at pivot {i} ({sum})"));
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Ok(l)
}

/// Solve `L y = b` for lower-triangular `L` (forward substitution).
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.get(i, k) * y[k];
        }
        y[i] = sum / l.get(i, i);
    }
    y
}

/// Solve `Lᵀ x = y` for lower-triangular `L` (back substitution).
pub fn solve_lower_t(l: &Matrix, y: &[f64]) -> Vec<f64> {
    let n = l.rows();
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in i + 1..n {
            sum -= l.get(k, i) * x[k];
        }
        x[i] = sum / l.get(i, i);
    }
    x
}

/// Solve the SPD system `A x = b` via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> Result<Vec<f64>, String> {
    let l = cholesky(a)?;
    Ok(solve_lower_t(&l, &solve_lower(&l, b)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{approx_eq, forall};

    #[test]
    fn cholesky_known() {
        // A = [[4,2],[2,3]] -> L = [[2,0],[1,sqrt(2)]]
        let a = Matrix::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert!((l.get(0, 0) - 2.0).abs() < 1e-12);
        assert!((l.get(1, 0) - 1.0).abs() < 1e-12);
        assert!((l.get(1, 1) - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(l.get(0, 1), 0.0);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn spd_solve_roundtrip_property() {
        forall(
            "solve_spd(A, A x) == x",
            99,
            40,
            |r| {
                let n = 1 + r.below(8) as usize;
                // A = M Mᵀ + n·I is SPD.
                let m = Matrix::random(n, n, r);
                let mut a = m.matmul(&m.transpose());
                for i in 0..n {
                    a.set(i, i, a.get(i, i) + n as f64);
                }
                let x: Vec<f64> = (0..n).map(|_| r.range_f64(-2.0, 2.0)).collect();
                (a, x)
            },
            |(a, x)| {
                let b = a.matvec(x);
                let got = solve_spd(a, &b).map_err(|e| e.to_string())?;
                for (g, want) in got.iter().zip(x) {
                    approx_eq(*g, *want, 1e-8)?;
                }
                Ok(())
            },
        );
    }
}
